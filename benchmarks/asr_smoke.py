"""Gating ASR smoke: three-modality serving + enc-dec paged prefill.

Drives the ``AsrEngine`` (PR 9) as the third modality behind one
``EngineRouter`` and gates on the subsystem's core promises:

* **Three-modality stream** — one router multiplexing a
  ``DiffusionEngine``, an LM ``ContinuousBatcher``, and an
  ``AsrEngine`` over one shared bus keeps every per-rid lifecycle
  invariant from ``streaming_smoke`` intact, interleaves modalities
  (not three serial phases), and the audio prefix cache adopts a
  repeated audio chain (no re-encode for the duplicate).
* **Fused enc-dec prefill wins** — the fused paged decoder prefill
  emits bit-identical transcripts to the retained decode-step scan at
  strictly fewer kernel launches (the gated row leads with the launch
  count so ``benchmarks/compare.py`` treats it as tight lower-better).
* **Failover without loss** — with 2 ASR replicas and one killed
  mid-encode by a deterministic ``FaultInjector``, every transcript is
  bit-identical to a single-replica run of the same seeds: migrated
  requests re-enter via ``Progress(phase="resume")``, re-adopting the
  published cross chain where one exists and re-encoding otherwise.

Run:  PYTHONPATH=src python benchmarks/asr_smoke.py [--json PATH]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig, reduced
from repro.configs.whisper_large_v3 import config as WHISPER
from repro.engine import (TINY_SD, AsrEngine, DiffusionEngine, EngineRouter,
                          FaultInjector, Finished, FleetManager,
                          GenerateRequest, Progress, ReplicaSpec,
                          TranscribeRequest, init_pipeline)
from repro.models.frontend import synthetic_audio
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

try:                          # package import (python -m ...)
    from benchmarks.streaming_smoke import check_event_invariants
except ImportError:           # script run: sys.path[0] is benchmarks/
    from streaming_smoke import check_event_invariants

ASR_CFG = reduced(WHISPER, d_model=64, head_dim=16, d_ff=128,
                  vocab_size=96, encoder_seq=32)
LM_CFG = ModelConfig(name="smoke-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)

# Fleet faults are injected deterministically; the watchdog threshold
# is parked high so real CPU timing noise cannot evict a healthy
# replica and flake the gate.
NO_WATCHDOG = 1e9


def _audio(seed: int):
    return synthetic_audio(jax.random.PRNGKey(seed), ASR_CFG)


def _transcribe(rid: int, seed: int, max_new: int = 6):
    rng = np.random.RandomState(seed)
    return TranscribeRequest(rid=rid, audio=_audio(seed),
                             prompt=rng.randint(1, 90, size=5).tolist(),
                             max_new=max_new)


def _transcripts(log) -> dict:
    return {e.rid: list(e.result.out) for e in log
            if isinstance(e, Finished)
            and isinstance(e.result, TranscribeRequest)}


def smoke_three_modality_stream() -> list[str]:
    """One router, one bus, three engines: diffusion + LM + ASR."""
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    asr_params = init_lm(jax.random.PRNGKey(0), ASR_CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)

    asr = AsrEngine(asr_params, ASR_CFG, slots=1, max_len=32,
                    audio_chunk=16, prefill_chunk=4)
    router = EngineRouter(
        diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
        lm=ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=16),
        asr=asr)

    router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                  steps=2, seed=0))
    router.submit(Request(rid=10, prompt=[3, 1, 4, 1, 5], max_new=6))
    router.submit(Request(rid=11, prompt=[2, 7, 1, 8], max_new=6))
    # rid 21 repeats rid 20's audio; with one ASR slot it queues until
    # 20 retires and must adopt the published cross chain.
    router.submit(_transcribe(20, seed=5))
    router.submit(_transcribe(21, seed=5))
    router.submit(_transcribe(22, seed=6))

    log = list(router.stream())
    rids = (0, 10, 11, 20, 21, 22)
    check_event_invariants(log, expect_finished=rids)
    out = _transcripts(log)
    assert out[20] == out[21], \
        f"adopted audio diverged: {out[21]} vs {out[20]}"
    assert asr.audio_hits >= 1, "repeated audio never hit the cache"
    assert asr.runtime.cross_prefix.hits > 0
    # Interleave: a non-ASR event must land inside the ASR event span.
    asr_ix = [i for i, e in enumerate(log) if e.rid >= 20]
    assert any(log[i].rid < 20 for i in range(asr_ix[0], asr_ix[-1])), \
        "stream did not interleave ASR with the other modalities"
    rows = [f"asr_smoke/three_modality,{len(rids)}/{len(rids)} terminal "
            f"on one bus,diffusion+lm+asr interleaved; "
            f"{asr.encode_quanta} encode quanta",
            f"asr_smoke/audio_cache,{asr.audio_hits} hit of 1 repeated "
            f"audio,adopted chain skipped "
            f"{-(-ASR_CFG.encoder_seq // 16)} encode quanta"]
    print(rows[0])
    print(rows[1])
    return rows


def smoke_fused_prefill_launches() -> list[str]:
    """Fused enc-dec decoder prefill: bit-exact vs the decode-step
    scan, strictly fewer launches (tight lower-better gate)."""
    params = init_lm(jax.random.PRNGKey(0), ASR_CFG)
    outs, launches = [], []
    for fused in (True, False):
        eng = AsrEngine(params, ASR_CFG, slots=1, max_len=32,
                        audio_chunk=32, prefill_chunk=4,
                        audio_share=False, fused_prefill=fused)
        assert eng.fused_prefill is fused
        reqs = [_transcribe(i, seed=3 + i, max_new=5) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs.append([list(r.out) for r in reqs])
        launches.append(eng.prefill_launches)
    assert outs[0] == outs[1], \
        f"fused prefill diverged from scan: {outs[0]} vs {outs[1]}"
    assert launches[0] < launches[1], \
        f"fused did not reduce launches: {launches[0]} vs {launches[1]}"
    rows = [f"asr_smoke/fused_prefill,{launches[0]} launches,"
            f"scan {launches[1]}; transcripts bit-exact"]
    print(rows[0])
    return rows


def smoke_fleet_failover_bit_exact() -> list[str]:
    """2 ASR replicas, one killed mid-run: zero loss, transcripts
    bit-identical to a single-replica run of the same seeds."""
    params = init_lm(jax.random.PRNGKey(0), ASR_CFG)

    def build():
        return AsrEngine(params, ASR_CFG, slots=2, max_len=32,
                         audio_chunk=16, prefill_chunk=4)

    def workload():
        return [_transcribe(i, seed=10 + i) for i in range(6)]

    ref = FleetManager([ReplicaSpec("solo", build)],
                       watchdog_threshold=NO_WATCHDOG)
    for r in workload():
        ref.submit(r)
    ref_out = _transcripts(ref.stream())
    assert len(ref_out) == 6

    fleet = FleetManager([ReplicaSpec(f"r{i}", build) for i in range(2)],
                         injector=FaultInjector().kill("r1", 3),
                         watchdog_threshold=NO_WATCHDOG)
    for r in workload():
        fleet.submit(r)
    log = list(fleet.stream())
    stats = fleet.stats()

    check_event_invariants(log, expect_finished=tuple(ref_out))
    out = _transcripts(log)
    assert not stats["lost"], f"lost requests: {stats['lost']}"
    assert out == ref_out, \
        f"transcripts diverged after migration: {out} vs {ref_out}"
    assert stats["migrations"] > 0, \
        "kill landed on an idle replica: smoke exercised nothing"
    resumed = {e.rid for e in log
               if isinstance(e, Progress) and e.phase == "resume"}
    assert resumed, "no Progress(resume) after eviction"
    rows = [f"asr_smoke/failover,6/6 bit-exact across replica kill,"
            f"{stats['migrations']} migrated "
            f"({sorted(resumed)} resumed) 0 lost"]
    print(rows[0])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    all_rows = (smoke_three_modality_stream()
                + smoke_fused_prefill_launches()
                + smoke_fleet_failover_bit_exact())
    if a.json:
        try:
            from benchmarks.common import write_bench_json
        except ImportError:
            from common import write_bench_json
        write_bench_json(a.json, "serving", all_rows, bench="asr_smoke")
