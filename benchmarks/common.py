"""Shared benchmark plumbing: SD graph enumeration + paper constants."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.accounting import MatmulOp
from repro.diffusion.pipeline import SD_TURBO, generate, init_pipeline
from repro.models.unet import SD15_UNET, apply_unet, init_unet

# Paper ground truth ----------------------------------------------------
TABLE1 = {  # model -> {fmt: fraction}
    "q3_k": {"f32": 0.307, "f16": 0.590, "q3_k": 0.103},
    "q8_0": {"f32": 0.218, "f16": 0.620, "q8_0": 0.163},
}
FIG67_E2E = {  # model -> {device: seconds}
    "q3_k": {"ARM Cortex-A72": 809.7, "IMAX3 (VPK180 FPGA)": 790.3,
             "IMAX3 (28nm ASIC)": 754.5, "Intel Xeon w5-2465X": 59.3,
             "NVIDIA GTX 1080 Ti": 16.2},
    "q8_0": {"ARM Cortex-A72": 625.1, "IMAX3 (VPK180 FPGA)": 654.7,
             "IMAX3 (28nm ASIC)": 558.0},
}


@functools.lru_cache(maxsize=None)
def sd_turbo_sites(batch: int = 1) -> tuple[MatmulOp, ...]:
    """Every dot-product site in the full SD-Turbo pipeline (1 step)."""
    sites: list[MatmulOp] = []
    qlinear.set_recorder(lambda **kw: sites.append(MatmulOp(**kw)))
    try:
        key = jax.random.PRNGKey(0)
        params = jax.eval_shape(
            lambda k: init_pipeline(k, SD_TURBO), key)
        jax.eval_shape(lambda p, t, k: generate(p, SD_TURBO, t, k),
                       params, jax.ShapeDtypeStruct((batch, 77), jnp.int32),
                       key)
    finally:
        qlinear.set_recorder(None)
    return tuple(sites)


@functools.lru_cache(maxsize=None)
def unet_sites(batch: int = 1) -> tuple[MatmulOp, ...]:
    """Dot-product sites of one U-Net denoising call (Table I scope:
    the paper profiles the diffusion core)."""
    sites: list[MatmulOp] = []
    qlinear.set_recorder(lambda **kw: sites.append(MatmulOp(**kw)))
    try:
        key = jax.random.PRNGKey(0)
        up = jax.eval_shape(lambda k: init_unet(k, SD15_UNET), key)
        jax.eval_shape(
            lambda p, x, t, c: apply_unet(p, SD15_UNET, x, t, c), up,
            jax.ShapeDtypeStruct((batch, 64, 64, 4), jnp.bfloat16),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch, 77, 768), jnp.bfloat16))
    finally:
        qlinear.set_recorder(None)
    return tuple(sites)


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"
