"""Shared benchmark plumbing: SD graph enumeration, paper constants,
and the machine-readable result schema the CI perf-trajectory harness
persists (``BENCH_<suite>.json`` artifacts)."""
from __future__ import annotations

import functools
import json
import os
import platform
import sys

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.accounting import MatmulOp
from repro.diffusion.pipeline import SD_TURBO, generate, init_pipeline
from repro.models.unet import SD15_UNET, apply_unet, init_unet

# Paper ground truth ----------------------------------------------------
TABLE1 = {  # model -> {fmt: fraction}
    "q3_k": {"f32": 0.307, "f16": 0.590, "q3_k": 0.103},
    "q8_0": {"f32": 0.218, "f16": 0.620, "q8_0": 0.163},
}
FIG67_E2E = {  # model -> {device: seconds}
    "q3_k": {"ARM Cortex-A72": 809.7, "IMAX3 (VPK180 FPGA)": 790.3,
             "IMAX3 (28nm ASIC)": 754.5, "Intel Xeon w5-2465X": 59.3,
             "NVIDIA GTX 1080 Ti": 16.2},
    "q8_0": {"ARM Cortex-A72": 625.1, "IMAX3 (VPK180 FPGA)": 654.7,
             "IMAX3 (28nm ASIC)": 558.0},
}


@functools.lru_cache(maxsize=None)
def sd_turbo_sites(batch: int = 1) -> tuple[MatmulOp, ...]:
    """Every dot-product site in the full SD-Turbo pipeline (1 step)."""
    sites: list[MatmulOp] = []
    qlinear.set_recorder(lambda **kw: sites.append(MatmulOp(**kw)))
    try:
        key = jax.random.PRNGKey(0)
        params = jax.eval_shape(
            lambda k: init_pipeline(k, SD_TURBO), key)
        jax.eval_shape(lambda p, t, k: generate(p, SD_TURBO, t, k),
                       params, jax.ShapeDtypeStruct((batch, 77), jnp.int32),
                       key)
    finally:
        qlinear.set_recorder(None)
    return tuple(sites)


@functools.lru_cache(maxsize=None)
def unet_sites(batch: int = 1) -> tuple[MatmulOp, ...]:
    """Dot-product sites of one U-Net denoising call (Table I scope:
    the paper profiles the diffusion core)."""
    sites: list[MatmulOp] = []
    qlinear.set_recorder(lambda **kw: sites.append(MatmulOp(**kw)))
    try:
        key = jax.random.PRNGKey(0)
        up = jax.eval_shape(lambda k: init_unet(k, SD15_UNET), key)
        jax.eval_shape(
            lambda p, x, t, c: apply_unet(p, SD15_UNET, x, t, c), up,
            jax.ShapeDtypeStruct((batch, 64, 64, 4), jnp.bfloat16),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch, 77, 768), jnp.bfloat16))
    finally:
        qlinear.set_recorder(None)
    return tuple(sites)


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"


# Perf-trajectory result schema -----------------------------------------
#
# Every benchmark can persist its printed ``name,value,detail`` rows as
# one JSON record via ``--json PATH``; CI uploads the per-suite files as
# ``BENCH_<suite>.json`` artifacts so run-over-run perf is diffable.
# The schema is deliberately tiny and versioned; ``validate_record`` is
# the single source of truth (unit-tested, used by consumers).

BENCH_SCHEMA_VERSION = 1


def parse_row(row: str, bench: str = "") -> dict:
    """Split one printed benchmark row — ``name,value[,detail]`` —
    into a schema entry.  ``detail`` may itself contain commas."""
    parts = row.split(",", 2)
    if len(parts) < 2 or not parts[0]:
        raise ValueError(f"malformed benchmark row: {row!r}")
    return {"bench": bench, "name": parts[0], "value": parts[1],
            "detail": parts[2] if len(parts) > 2 else ""}


def bench_record(suite: str, entries: list[dict]) -> dict:
    """Assemble the versioned perf-trajectory record for one suite."""
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "suite": suite,
        "env": {
            "python": platform.python_version(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": sys.platform,
        },
        "entries": entries,
    }


def validate_record(obj: dict) -> None:
    """Raise ``ValueError`` unless ``obj`` is a well-formed perf
    record (the contract CI artifacts and trajectory consumers rely
    on)."""
    if not isinstance(obj, dict):
        raise ValueError("record must be a dict")
    if obj.get("schema_version") != BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"schema_version {obj.get('schema_version')!r} != "
            f"{BENCH_SCHEMA_VERSION}")
    if not isinstance(obj.get("suite"), str) or not obj["suite"]:
        raise ValueError("suite must be a non-empty string")
    if not isinstance(obj.get("env"), dict):
        raise ValueError("env must be a dict")
    entries = obj.get("entries")
    if not isinstance(entries, list):
        raise ValueError("entries must be a list")
    for e in entries:
        if not isinstance(e, dict):
            raise ValueError(f"entry must be a dict: {e!r}")
        for field in ("bench", "name", "value", "detail"):
            if not isinstance(e.get(field), str):
                raise ValueError(f"entry field {field!r} must be a "
                                 f"string: {e!r}")
        if not e["name"]:
            raise ValueError(f"entry name must be non-empty: {e!r}")


def write_bench_json(path: str, suite: str, rows: list[str],
                     bench: str) -> None:
    """Append one benchmark's rows to the suite's JSON record at
    ``path`` (created if absent, merged if present — several
    benchmarks of one CI job share a file).  Entries from an earlier
    run of the *same* benchmark are replaced, not accumulated, so
    re-running against a stale file (persisted workspace, local dev
    loop) cannot mix two runs' numbers in one record.  The merged
    record is validated before writing so a malformed file fails the
    job, not the artifact consumer."""
    entries = [parse_row(r, bench=bench) for r in rows]
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        validate_record(rec)
        if rec["suite"] != suite:
            raise ValueError(f"suite mismatch: file has "
                             f"{rec['suite']!r}, got {suite!r}")
        rec["entries"] = [e for e in rec["entries"]
                          if e["bench"] != bench] + entries
    else:
        rec = bench_record(suite, entries)
    validate_record(rec)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")
