"""Perf-trajectory comparator: diff two ``BENCH_<suite>.json`` records.

CI persists every suite's benchmark rows as a versioned JSON artifact
(``benchmarks/common.py`` schema).  This tool closes the loop: given
the previous run's artifact and the current one, it matches entries by
``(bench, name)``, extracts the leading numeric from each free-form
value string, and reports the delta per metric — failing (exit 1) on
regressions beyond a threshold.

Metrics are classified from their name + unit text:

* **direction** — ``req/s`` / ``tok/s`` / ``hit`` / ``speedup`` are
  higher-better; ``latency`` / ``ttft`` / seconds / ``quanta`` /
  ``bytes`` / ``makespan`` / ``launches`` are lower-better.  Metrics
  with no recognizable direction are reported but never gate.
* **noise class** — wall-clock metrics (seconds, req/s, tok/s) flap on
  shared CI runners, so they gate at the loose ``--time-threshold``
  (default 50%); counter metrics (quanta, bytes, launches) are
  deterministic for a given code version, so they gate at the tight
  ``--count-threshold`` (default 5%).

A missing baseline file is NOT an error (first run of the trajectory,
expired artifact retention): the comparator notes it and exits 0 —
the trajectory starts from the current run.

Per-metric threshold overrides (optional ``--config FILE``)::

    {"overrides": [
      {"pattern": "serving_cache/*hit*", "threshold": 0.0},
      {"pattern": "phase_seconds*", "threshold": 0.8}
    ]}

Patterns are shell globs (fnmatch) tried against ``bench/name`` first,
then the bare metric name; the FIRST matching override wins and
replaces the default tight/loose limit for that metric.  With no
config (or no match) the defaults above apply unchanged.

Run:  python benchmarks/compare.py --baseline OLD.json --current NEW.json
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import re
import sys

try:                          # package import (python -m ...)
    from benchmarks.common import validate_record
except ImportError:           # script run: sys.path[0] is benchmarks/
    from common import validate_record

# A number not glued to a word ("p50", "q8_0" are labels, not values).
_NUM = re.compile(r"(?<![\w.])-?\d+(?:\.\d+)?(?:e-?\d+)?", re.IGNORECASE)

# Token lists scanned against "<name> <value>" lowercased.  Order
# matters: the first hit wins, so higher-better rate units are listed
# before the bare seconds tokens they would otherwise collide with
# ("req/s" contains "s").
_HIGHER = ("req/s", "tok/s", "throughput", "hit", "speedup", "scaling")
_LOWER = ("latency", "ttft", "makespan", "quanta", "launches", "bytes",
          "kb", "mb", " ms", " s,", "s)", "time")
_COUNTERS = ("quanta", "launches", "bytes", "kb", "mb", "makespan")


def _leading_number(value: str) -> float | None:
    m = _NUM.search(value)
    return float(m.group()) if m else None


def classify(name: str, value: str) -> tuple[str, str]:
    """-> (direction: higher|lower|unknown, noise: time|count).
    Direction keys on the metric leaf + value text, not the bench
    prefix ("engine_throughput/latency" is a latency, not a
    throughput)."""
    text = f"{name.rsplit('/', 1)[-1]} {value}".lower()
    direction = "unknown"
    for tok in _HIGHER:
        if tok in text:
            direction = "higher"
            break
    else:
        for tok in _LOWER:
            if tok in text or text.rstrip().endswith("s"):
                direction = "lower"
                break
    noise = "count" if any(t in text for t in _COUNTERS) else "time"
    return direction, noise


def _index(rec: dict) -> dict[tuple[str, str], dict]:
    return {(e["bench"], e["name"]): e for e in rec["entries"]}


def load_overrides(config: dict) -> list[tuple[str, float]]:
    """Validate a ``--config`` document into ``(pattern, threshold)``
    pairs, preserving order (first match wins)."""
    out = []
    for o in config.get("overrides", []):
        if not isinstance(o, dict) or "pattern" not in o \
                or "threshold" not in o:
            raise ValueError(
                f"override needs 'pattern' and 'threshold': {o!r}")
        thr = float(o["threshold"])
        if thr < 0:
            raise ValueError(f"threshold must be >= 0: {o!r}")
        out.append((str(o["pattern"]), thr))
    return out


def _override_limit(overrides, bench: str, name: str) -> float | None:
    for pattern, thr in overrides:
        if fnmatch.fnmatch(f"{bench}/{name}", pattern) \
                or fnmatch.fnmatch(name, pattern):
            return thr
    return None


def compare_records(base: dict, cur: dict, time_threshold: float,
                    count_threshold: float,
                    overrides: list[tuple[str, float]] = (),
                    ) -> tuple[list[str], list[str]]:
    """-> (report lines, regression lines).  Pure so it is unit-testable
    without touching the filesystem."""
    report, regressions = [], []
    bi, ci = _index(base), _index(cur)
    for key in sorted(set(bi) | set(ci)):
        bench, name = key
        if key not in bi:
            report.append(f"  NEW     {name}: {ci[key]['value']}")
            continue
        if key not in ci:
            report.append(f"  GONE    {name} (was {bi[key]['value']})")
            continue
        old, new = bi[key]["value"], ci[key]["value"]
        ov, nv = _leading_number(old), _leading_number(new)
        if ov is None or nv is None:
            if old != new:
                report.append(f"  text    {name}: {old!r} -> {new!r}")
            continue
        direction, noise = classify(name, new)
        if ov == 0:
            rel = 0.0 if nv == 0 else float("inf")
        else:
            rel = (nv - ov) / abs(ov)
        arrow = f"{ov:g} -> {nv:g} ({rel:+.1%})"
        if direction == "unknown":
            report.append(f"  ?       {name}: {arrow}")
            continue
        worse = rel < 0 if direction == "higher" else rel > 0
        limit = _override_limit(overrides, bench, name)
        which = "override" if limit is not None else noise
        if limit is None:
            limit = (count_threshold if noise == "count"
                     else time_threshold)
        if worse and abs(rel) > limit:
            regressions.append(
                f"  REGRESS {name}: {arrow} [{direction}-better, "
                f"{which} threshold {limit:.0%}]")
        elif worse:
            report.append(f"  ~       {name}: {arrow} (within "
                          f"{limit:.0%} {which} threshold)")
        else:
            report.append(f"  ok      {name}: {arrow}")
    return report, regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="previous run's BENCH_<suite>.json (missing "
                         "file is fine: the trajectory starts here)")
    ap.add_argument("--current", required=True)
    ap.add_argument("--time-threshold", type=float, default=0.5,
                    help="max relative regression for wall-clock "
                         "metrics (noisy on shared runners)")
    ap.add_argument("--count-threshold", type=float, default=0.05,
                    help="max relative regression for deterministic "
                         "counter metrics (quanta/bytes/launches)")
    ap.add_argument("--config", default=None,
                    help="optional JSON file with per-metric threshold "
                         "overrides ({'overrides': [{'pattern': "
                         "'bench/name-glob', 'threshold': 0.1}]}); "
                         "defaults apply when absent or unmatched")
    a = ap.parse_args()

    overrides: list[tuple[str, float]] = []
    if a.config is not None:
        with open(a.config) as f:
            overrides = load_overrides(json.load(f))

    if not os.path.exists(a.baseline):
        print(f"compare: no baseline at {a.baseline} — first run of "
              f"the trajectory, nothing to diff")
        return 0
    with open(a.baseline) as f:
        base = json.load(f)
    with open(a.current) as f:
        cur = json.load(f)
    validate_record(base)
    validate_record(cur)
    if base["suite"] != cur["suite"]:
        print(f"compare: suite mismatch ({base['suite']!r} vs "
              f"{cur['suite']!r})")
        return 1

    report, regressions = compare_records(
        base, cur, a.time_threshold, a.count_threshold, overrides)
    print(f"perf trajectory [{cur['suite']}]: "
          f"{len(cur['entries'])} metrics vs baseline")
    for line in report:
        print(line)
    for line in regressions:
        print(line)
    if regressions:
        print(f"compare: {len(regressions)} regression(s) beyond "
              f"threshold")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
