"""Analytical device models for the paper's five platforms (Table II).

This container has no ARM board, FPGA, Xeon, or GPU, so the paper's
measured systems are reproduced as calibrated analytical models:
every dot-product site enumerated from the *real* SD-Turbo graph
(`repro.core.accounting`) is costed as

    time(op, fmt) = max(flops / throughput[fmt],
                        weight_bytes(fmt) / mem_bw)

with per-dtype effective throughputs calibrated once against the
paper's own numbers (Table I fractions + Fig 6/7 E2E latencies) and
then *held fixed* across all benchmarks.  Offload systems (IMAX3)
additionally model host execution of the non-offloaded share and DMA
transfer of quantized operands (LOAD/DRAIN in Fig 11).

Throughputs are "effective GGML throughput", not peaks — they absorb
framework overheads, which is why they're calibrated rather than taken
from spec sheets.  Power numbers are the paper's (Table II).
"""
from __future__ import annotations

import dataclasses

from repro.core.accounting import MatmulOp

GIGA = 1e9


@dataclasses.dataclass(frozen=True)
class CPUDevice:
    """Host CPU device (ARM / Xeon) with per-dtype throughput."""
    name: str
    throughput: dict            # fmt -> effective FLOP/s
    mem_bw: float               # bytes/s
    power: float                # W
    cores: int = 2

    def matmul_time(self, op: MatmulOp, fmt: str) -> float:
        t = self.throughput.get(fmt, self.throughput["f32"])
        return max(op.flops / t, op.weight_bytes(fmt) / self.mem_bw)


@dataclasses.dataclass(frozen=True)
class IMAXDevice:
    """IMAX3 accelerator: host runs F32/F16, IMAX runs quantized kernels.

    Quantized ops additionally pay DMA LOAD (quantized weights +
    activations to LMM) and DRAIN (results back) on the FPGA prototype;
    the ASIC projection scales EXEC by the 145->840 MHz ratio
    (the paper's measured 5.8x).
    """
    name: str
    host: CPUDevice
    exec_rate: dict             # quantized fmt -> effective FLOP/s (1 lane)
    dma_bw: float               # bytes/s to the DMA buffer
    power: dict                 # fmt -> W while executing that kernel
    lanes: int = 1
    host_cores: int = 2

    def matmul_time(self, op: MatmulOp, fmt: str) -> float:
        if not fmt.startswith("q"):
            return self.host.matmul_time(op, fmt)
        return (self.exec_time(op, fmt, self.lanes)
                + self.dma_time(op, fmt))

    def exec_time(self, op: MatmulOp, fmt: str, lanes: int) -> float:
        rate = self.exec_rate[fmt]
        eff_lanes = min(lanes, self.host_cores)  # paper §V.A: host bound
        return op.flops / (rate * max(eff_lanes, 1))

    def dma_time(self, op: MatmulOp, fmt: str) -> float:
        if self.dma_bw == 0:
            return 0.0
        load = op.weight_bytes(fmt) + op.act_bytes(8)   # q8 activations
        drain = op.m * op.n * 4 * op.count              # f32 results
        return (load + drain) / self.dma_bw


# ---------------------------------------------------------------- zoo
# Calibrated against Table I fractions + Fig 6/7 E2E numbers.

ARM_A72 = CPUDevice(
    name="ARM Cortex-A72",
    throughput={"f32": 2.6 * GIGA, "f16": 4.1 * GIGA,
                "q8_0": 11.0 * GIGA, "q3_k": 5.0 * GIGA},
    mem_bw=8e9, power=1.5, cores=2)

XEON_W5 = CPUDevice(
    name="Intel Xeon w5-2465X",
    throughput={"f32": 40 * GIGA, "f16": 60 * GIGA,
                "q8_0": 90 * GIGA, "q3_k": 70 * GIGA},
    mem_bw=60e9, power=200.0, cores=16)

GTX_1080TI = CPUDevice(
    name="NVIDIA GTX 1080 Ti",
    throughput={"f32": 160 * GIGA, "f16": 205 * GIGA,
                "q8_0": 300 * GIGA, "q3_k": 230 * GIGA},
    mem_bw=484e9, power=250.0, cores=3584)

# IMAX3 FPGA @145 MHz: 64 PEs x 2 (MAC) x 2 (SIMD) x 145e6 ~ 37 GOPS
# peak; effective calibrated below.  Q3_K maps 51/64 PEs, Q8_0 46/64.
IMAX3_FPGA = IMAXDevice(
    name="IMAX3 (VPK180 FPGA)",
    host=ARM_A72,
    exec_rate={"q8_0": 9.5 * GIGA, "q3_k": 8.7 * GIGA},
    dma_bw=1.2e9,
    power={"f32": 180.0, "f16": 180.0, "q8_0": 180.0, "q3_k": 180.0},
    lanes=1)

_ASIC_SPEEDUP = 840 / 145  # paper: 5.8x from static timing analysis

IMAX3_ASIC = IMAXDevice(
    name="IMAX3 (28nm ASIC)",
    host=ARM_A72,
    exec_rate={"q8_0": 9.5 * GIGA * _ASIC_SPEEDUP,
               "q3_k": 8.7 * GIGA * _ASIC_SPEEDUP},
    dma_bw=12e9,   # on-die integration removes the PCIe/AXI bottleneck
    power={"f32": 1.5, "f16": 1.5, "q8_0": 47.7, "q3_k": 52.8},
    lanes=1)

DEVICES = {d.name: d for d in
           (ARM_A72, XEON_W5, GTX_1080TI, IMAX3_FPGA, IMAX3_ASIC)}


def e2e_time(assigned, device) -> float:
    return sum(device.matmul_time(op, fmt) for op, fmt in assigned)


def pdp(assigned, device) -> float:
    """Power-Delay Product with per-phase power (paper eq. 1)."""
    total = 0.0
    for op, fmt in assigned:
        t = device.matmul_time(op, fmt)
        if isinstance(device, IMAXDevice):
            p = (device.power[fmt] if fmt.startswith("q")
                 else device.host.power)
        else:
            p = device.power
        total += t * p
    return total
