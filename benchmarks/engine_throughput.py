"""Engine throughput microbench: requests/sec on mixed workloads.

Submits a mixed sampler/step workload (turbo-1, ddim-2, ddim-4,
euler-2, plus a CFG-guided ddim-4 group) to a ``DiffusionEngine`` and
reports cold (incl. compile) and steady-state requests/sec together
with the jit trace count — the compile cache means the steady pass
must add zero traces.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py \
          [--requests 12] [--max-batch 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.engine import (TINY_SD, DiffusionEngine, GenerateRequest,
                          init_pipeline)

# (sampler, steps, guidance_scale) round-robin mix.
MIX = [("turbo", 1, 1.0), ("ddim", 2, 1.0), ("ddim", 4, 1.0),
       ("euler", 2, 1.0), ("ddim", 4, 7.5)]


def _submit(engine: DiffusionEngine, toks, n: int, rid0: int) -> None:
    for i in range(n):
        sampler, steps, g = MIX[i % len(MIX)]
        engine.submit(GenerateRequest(
            rid=rid0 + i, tokens=toks, sampler=sampler, steps=steps,
            guidance_scale=g, seed=rid0 + i))


def run(verbose: bool = True, n_requests: int = 12,
        max_batch: int = 4) -> list[str]:
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)
    engine = DiffusionEngine(params, TINY_SD, max_batch=max_batch)

    rows = []
    for label, rid0 in (("cold", 0), ("steady", n_requests)):
        traces0 = engine.traces
        _submit(engine, toks, n_requests, rid0)
        t0 = time.time()
        engine.run()
        jax.block_until_ready(engine.finished[-1].image)
        dt = time.time() - t0
        row = (f"engine_throughput/{label},{n_requests / dt:.2f} req/s,"
               f"{dt:.2f}s for {n_requests} reqs (max_batch={max_batch}),"
               f"traces +{engine.traces - traces0}")
        rows.append(row)
        if verbose:
            print(row)
    assert engine.traces - traces0 == 0, "steady-state pass retraced"
    assert len(engine.finished) == 2 * n_requests
    assert all(bool(jnp.isfinite(r.image.astype(jnp.float32)).all())
               for r in engine.finished)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    a = ap.parse_args()
    run(n_requests=a.requests, max_batch=a.max_batch)
