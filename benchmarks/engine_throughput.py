"""Engine throughput microbench: requests/sec on mixed workloads.

Part 1 (``run``): submits a mixed sampler/step workload (turbo-1,
ddim-2, ddim-4, euler-2, plus a CFG-guided ddim-4 group) to a
``DiffusionEngine`` and reports cold (incl. compile) and steady-state
requests/sec together with the jit trace count — the compile cache
means the steady pass must add zero traces.

Part 2 (``run_streaming``): drives a mixed diffusion + LM workload
through an ``EngineRouter`` and reports, from the typed event
timestamps on the stream,

* **time-to-first-event** — TTFT (first ``TokenDelta``) for LM
  requests, time-to-first-preview (first ``PreviewLatent``) for
  diffusion requests,
* **p50/p95 per-request latency** (submit -> ``Finished``),
* requests/sec for the whole mixed stream.

Run:  PYTHONPATH=src python benchmarks/engine_throughput.py \
          [--requests 12] [--max-batch 4]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, DiffusionEngine, EngineRouter, Finished,
                          GenerateRequest, PreviewLatent, TokenDelta,
                          init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

# (sampler, steps, guidance_scale) round-robin mix.
MIX = [("turbo", 1, 1.0), ("ddim", 2, 1.0), ("ddim", 4, 1.0),
       ("euler", 2, 1.0), ("ddim", 4, 7.5)]

LM_CFG = ModelConfig(name="bench-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)


def _submit(engine: DiffusionEngine, toks, n: int, rid0: int) -> None:
    for i in range(n):
        sampler, steps, g = MIX[i % len(MIX)]
        engine.submit(GenerateRequest(
            rid=rid0 + i, tokens=toks, sampler=sampler, steps=steps,
            guidance_scale=g, seed=rid0 + i))


def _pct(xs: list[float], q: float) -> float:
    if not xs:              # e.g. --requests 1 leaves no LM requests
        return float("nan")
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))]


def run(verbose: bool = True, n_requests: int = 12,
        max_batch: int = 4) -> list[str]:
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)
    engine = DiffusionEngine(params, TINY_SD, max_batch=max_batch)

    rows = []
    for label, rid0 in (("cold", 0), ("steady", n_requests)):
        traces0 = engine.traces
        _submit(engine, toks, n_requests, rid0)
        t0 = time.time()
        engine.run()
        jax.block_until_ready(engine.finished[-1].image)
        dt = time.time() - t0
        row = (f"engine_throughput/{label},{n_requests / dt:.2f} req/s,"
               f"{dt:.2f}s for {n_requests} reqs (max_batch={max_batch}),"
               f"traces +{engine.traces - traces0}")
        rows.append(row)
        if verbose:
            print(row)
    assert engine.traces - traces0 == 0, "steady-state pass retraced"
    assert len(engine.finished) == 2 * n_requests
    assert all(bool(jnp.isfinite(r.image.astype(jnp.float32)).all())
               for r in engine.finished)
    rows += run_streaming(verbose=verbose, n_requests=n_requests,
                          max_batch=max_batch)
    return rows


def run_streaming(verbose: bool = True, n_requests: int = 8,
                  max_batch: int = 2) -> list[str]:
    """Mixed diffusion + LM workload through the router; latency
    metrics from the event timestamps on the merged stream."""
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)

    n_sd = (n_requests + 1) // 2
    n_lm = n_requests - n_sd
    gen = 8
    diff = DiffusionEngine(sd_params, TINY_SD, max_batch=max_batch)
    lm = ContinuousBatcher(
        lm_params, LM_CFG, slots=2,
        max_len=ContinuousBatcher.required_len(n_lm, 2, 8, gen))
    router = EngineRouter(diffusion=diff, lm=lm)

    submit_ts: dict[int, float] = {}
    is_lm: dict[int, bool] = {}
    for i in range(n_sd):
        submit_ts[i] = router.bus.clock()
        is_lm[i] = False
        router.submit(GenerateRequest(
            rid=i, tokens=toks, sampler="ddim", steps=4, seed=i,
            preview_every=1))
    for i in range(n_sd, n_sd + n_lm):
        submit_ts[i] = router.bus.clock()
        is_lm[i] = True
        router.submit(Request(rid=i, prompt=[(i * 7) % 90 + 1] * 8,
                              max_new=gen))

    t0 = time.time()
    first_ev: dict[int, float] = {}
    fin_ts: dict[int, float] = {}
    for e in router.stream():
        if isinstance(e, (TokenDelta, PreviewLatent)) \
                and e.rid not in first_ev:
            first_ev[e.rid] = e.ts
        elif isinstance(e, Finished):
            fin_ts[e.rid] = e.ts
    dt = time.time() - t0

    assert sorted(fin_ts) == sorted(submit_ts), "stream lost requests"
    ttft = [first_ev[r] - submit_ts[r] for r in first_ev if is_lm[r]]
    ttfp = [first_ev[r] - submit_ts[r] for r in first_ev if not is_lm[r]]
    lat = [fin_ts[r] - submit_ts[r] for r in fin_ts]
    rows = [
        f"engine_throughput/stream,{len(fin_ts) / dt:.2f} req/s,"
        f"{n_sd} diffusion + {n_lm} lm interleaved in {dt:.2f}s",
        f"engine_throughput/first_event,ttft p50 {_pct(ttft, .5):.3f}s,"
        f"first-preview p50 {_pct(ttfp, .5):.3f}s",
        f"engine_throughput/latency,p50 {_pct(lat, .5):.3f}s,"
        f"p95 {_pct(lat, .95):.3f}s per request",
    ]
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    rows = run(n_requests=a.requests, max_batch=a.max_batch)
    if a.json:
        try:                      # package import (python -m ...)
            from benchmarks.common import write_bench_json
        except ImportError:       # script run: sys.path[0] is benchmarks/
            from common import write_bench_json
        write_bench_json(a.json, "unit", rows, bench="engine_throughput")
