"""Fig 11 reproduction: IMAX processing-time breakdown (EXEC / LOAD /
DRAIN / CONF) for the Q3_K and Q8_0 kernels on the FPGA.

LOAD = quantized weights + Q8 activations into the DMA buffer/LMM;
DRAIN = f32 results back; EXEC = PE-array compute; CONF/REGV/RANGE =
per-dispatch configuration (modeled as a fixed per-call overhead).

Asserted qualitative structure from the figure: the Q8_0 kernel is
more LOAD-heavy than Q3_K (8.5 vs 3.4 bits/weight) — the transfer
volume the paper blames for Q8_0's FPGA slowdown — and configuration
overhead is negligible.
"""
from __future__ import annotations

from repro.core.accounting import assign_formats
from repro.core.policy import get_policy

from benchmarks import common
from benchmarks.device_model import IMAX3_FPGA

CONF_PER_CALL = 2e-4  # s — IMAX reconfiguration per kernel dispatch


def phases(assigned) -> dict[str, float]:
    dev = IMAX3_FPGA
    out = {"EXEC": 0.0, "LOAD": 0.0, "DRAIN": 0.0, "CONF": 0.0}
    for op, fmt in assigned:
        if not fmt.startswith("q"):
            continue
        out["EXEC"] += dev.exec_time(op, fmt, dev.lanes)
        load = op.weight_bytes(fmt) + op.act_bytes(8)
        drain = op.m * op.n * 4 * op.count
        out["LOAD"] += load / dev.dma_bw
        out["DRAIN"] += drain / dev.dma_bw
        out["CONF"] += CONF_PER_CALL
    return out


def run(verbose: bool = True) -> list[str]:
    rows = []
    shares = {}
    for model in ("q3_k", "q8_0"):
        assigned = assign_formats(common.sd_turbo_sites(),
                                  get_policy(model))
        ph = phases(assigned)
        tot = sum(ph.values())
        shares[model] = {k: v / tot for k, v in ph.items()}
        for k, v in ph.items():
            rows.append(common.csv_row(
                f"fig11/{model}/{k}", v * 1e6,
                f"share={shares[model][k]:.2f}"))
            if verbose:
                print(rows[-1])
    assert shares["q8_0"]["LOAD"] > shares["q3_k"]["LOAD"], \
        "Q8_0 must be more LOAD-heavy (8.5 vs 3.4 bpw)"
    return rows


if __name__ == "__main__":
    run()
