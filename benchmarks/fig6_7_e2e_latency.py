"""Fig 6/7 reproduction: end-to-end image-generation latency per device.

Full SD-Turbo pipeline (CLIP + UNet 1 step + VAE decode), every
dot-product costed on each device model; IMAX devices offload the
quantized share and pay DMA (FPGA) per the paper's architecture.

Known divergence (documented): the paper's Q8_0 and Q3_K model *files*
quantize different tensor subsets (visible in their Table I F32 rows:
21.8% vs 30.7%), which our uniform-coverage policies do not replicate;
ARM/ASIC absolute numbers for the Q8_0 model are therefore ~20-35%
high while every qualitative ordering (FPGA≈ARM for Q3_K, FPGA>ARM
for Q8_0 due to transfer volume, ASIC recovering it, Xeon/GPU far
ahead) is reproduced.
"""
from __future__ import annotations

from repro.core.accounting import assign_formats
from repro.core.policy import get_policy

from benchmarks import common
from benchmarks.device_model import DEVICES, e2e_time

TOL_REL = {"q3_k": 0.20, "q8_0": 0.45}


def run(verbose: bool = True) -> list[str]:
    rows = []
    sites = common.sd_turbo_sites()
    for model in ("q3_k", "q8_0"):
        assigned = assign_formats(sites, get_policy(model))
        times = {name: e2e_time(assigned, dev)
                 for name, dev in DEVICES.items()}
        for dev, want in common.FIG67_E2E[model].items():
            got = times[dev]
            rel = abs(got - want) / want
            ok = rel <= TOL_REL[model]
            rows.append(common.csv_row(
                f"fig6_7/{model}/{dev}", got * 1e6,
                f"e2e={got:.1f}s paper={want:.1f}s rel={rel:.2f} "
                f"{'OK' if ok else 'DIVERGES'}"))
            if verbose:
                print(rows[-1])
            assert ok, (model, dev, got, want)
        # Qualitative claims from the paper's discussion.
        if model == "q3_k":
            assert times["IMAX3 (VPK180 FPGA)"] < times["ARM Cortex-A72"]
        else:
            assert times["IMAX3 (VPK180 FPGA)"] > times["ARM Cortex-A72"], \
                "paper: Q8_0 transfer volume makes FPGA slower than ARM"
        assert times["IMAX3 (28nm ASIC)"] < times["IMAX3 (VPK180 FPGA)"]
        assert times["Intel Xeon w5-2465X"] < times["IMAX3 (28nm ASIC)"]
        assert times["NVIDIA GTX 1080 Ti"] < times["Intel Xeon w5-2465X"]
    return rows


if __name__ == "__main__":
    run()
