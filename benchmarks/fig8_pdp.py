"""Fig 8 reproduction: Power-Delay Product per device.

PDP = execution time x per-phase power (paper eq. 1: the host phase is
billed at host power, the IMAX phase at the synthesis-estimated kernel
power — 47.7 W for Q8_0 / 46 units, 52.8 W for Q3_K / 51 units).

Asserted paper claims:
  * the low-power ARM A72 has the lowest PDP;
  * projected IMAX3 ASIC PDP beats the Xeon for both models;
  * for Q3_K, IMAX3 ASIC PDP also beats the GTX 1080 Ti.
"""
from __future__ import annotations

from repro.core.accounting import assign_formats
from repro.core.policy import get_policy

from benchmarks import common
from benchmarks.device_model import DEVICES, pdp


def run(verbose: bool = True) -> list[str]:
    rows = []
    sites = common.sd_turbo_sites()
    for model in ("q3_k", "q8_0"):
        assigned = assign_formats(sites, get_policy(model))
        vals = {name: pdp(assigned, dev) for name, dev in DEVICES.items()}
        for dev, v in sorted(vals.items(), key=lambda kv: kv[1]):
            rows.append(common.csv_row(f"fig8/{model}/{dev}", v * 1e6,
                                       f"pdp={v:.0f}J"))
            if verbose:
                print(rows[-1])
        assert min(vals, key=vals.get) == "ARM Cortex-A72"
        assert vals["IMAX3 (28nm ASIC)"] < vals["Intel Xeon w5-2465X"]
        if model == "q3_k":
            assert vals["IMAX3 (28nm ASIC)"] < vals["NVIDIA GTX 1080 Ti"]
    return rows


if __name__ == "__main__":
    run()
