"""Fig 9/10 reproduction: quantized-kernel time vs active IMAX lanes.

The paper's finding (§V.A): kernel time improves up to 2 lanes then
saturates — the dual-core host CPU that feeds the lanes becomes the
bottleneck (eff_lanes = min(lanes, host_cores)).  We sweep 1..8 lanes
on both kernel types and assert the knee sits at the host core count.
"""
from __future__ import annotations

import dataclasses

from repro.core.accounting import assign_formats
from repro.core.policy import get_policy

from benchmarks import common
from benchmarks.device_model import IMAX3_FPGA


def kernel_time(device, assigned, lanes: int) -> float:
    return sum(device.exec_time(op, fmt, lanes) + device.dma_time(op, fmt)
               for op, fmt in assigned if fmt.startswith("q"))


def run(verbose: bool = True) -> list[str]:
    rows = []
    sites = common.sd_turbo_sites()
    for model in ("q3_k", "q8_0"):
        assigned = assign_formats(sites, get_policy(model))
        times = []
        for lanes in range(1, 9):
            dev = dataclasses.replace(IMAX3_FPGA, lanes=lanes)
            t = kernel_time(dev, assigned, lanes)
            times.append(t)
            rows.append(common.csv_row(
                f"fig9_10/{model}/lanes={lanes}", t * 1e6,
                f"kernel={t:.2f}s"))
            if verbose:
                print(rows[-1])
        # 1 -> 2 lanes improves; >= host_cores saturates.
        assert times[1] < times[0] * 0.75, "2-lane speedup missing"
        for l in range(2, 8):
            assert times[l] >= times[1] * 0.999, \
                "scaling beyond host cores should saturate (paper §V.A)"
    return rows


if __name__ == "__main__":
    run()
