"""Gating fleet smoke: N-replica scaling + zero-loss failover.

Drives a ``FleetManager`` fronting in-process engine replicas (each an
``EngineRouter`` over a ``DiffusionEngine`` and an LM
``ContinuousBatcher``) and gates on the fleet subsystem's three core
promises:

* **Failover without loss** — with 3 replicas and one replica killed
  mid-run by a deterministic ``FaultInjector``, every admitted request
  still reaches a terminal event, and every finished request's output
  (LM token sequence / diffusion image) is **bit-identical** to a
  single-replica run of the same seeds: LM requests resume via
  re-prefill of prompt + generated-so-far, diffusion requests rerun
  from their seed.  Migrated requests re-enter via
  ``Progress(phase="resume")`` — never a second ``Admitted``.
* **Event-ordering invariants survive the fleet** — the per-rid
  lifecycle invariants asserted by ``streaming_smoke`` hold on the one
  shared bus even across an eviction + migration.
* **Capacity recovers** — with ``replace_evicted=True`` (PR 9) the
  same kill respawns a fresh replica from the evicted spec's build:
  the fleet ends the run at full replica strength, the replacement
  absorbs real quanta, and every transcript stays bit-exact.
* **Throughput scales** — on a mixed LM workload, the 3-replica
  parallel makespan (the max over replicas of quanta each ran — wall
  time in a real deployment where replicas step concurrently) is
  strictly below the 1-replica makespan, i.e. 3-replica req/s exceeds
  1-replica req/s.

Run:  PYTHONPATH=src python benchmarks/fleet_smoke.py [--json PATH]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, DiffusionEngine, EngineRouter,
                          FaultInjector, Finished, FleetManager,
                          GenerateRequest, Progress, ReplicaSpec,
                          init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

try:                          # package import (python -m ...)
    from benchmarks.streaming_smoke import check_event_invariants
except ImportError:           # script run: sys.path[0] is benchmarks/
    from streaming_smoke import check_event_invariants

LM_CFG = ModelConfig(name="smoke-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)

# Kill/slow detection is exercised deterministically via the injector;
# the watchdog threshold is parked high so real CPU timing noise
# (compiles landing at different quanta per replica) cannot evict a
# healthy replica and flake the gate.
NO_WATCHDOG = 1e9


def _params():
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    return sd_params, lm_params


def _mixed_workload():
    """Mixed, seed-determined workload: rids 0-3 diffusion (one with
    preview streaming, so a segmented in-flight batch can be caught by
    the eviction), rids 10-17 LM."""
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (TINY_SD.text_len,), 0,
                              TINY_SD.clip_cfg().vocab_size)
    reqs = [GenerateRequest(rid=i, tokens=toks, sampler="ddim", steps=2,
                            seed=i, preview_every=1 if i == 3 else 0)
            for i in range(4)]
    rng = np.random.RandomState(7)
    reqs += [Request(rid=10 + i,
                     prompt=rng.randint(1, 90, size=4).tolist(),
                     max_new=5)
             for i in range(8)]
    return reqs


def _outputs(log) -> dict:
    """rid -> comparable terminal payload (token list / image array)."""
    out = {}
    for e in log:
        if isinstance(e, Finished):
            r = e.result
            out[e.rid] = (list(r.out) if hasattr(r, "out")
                          else np.asarray(r.image))
    return out


def smoke_failover_bit_exact() -> list[str]:
    sd_params, lm_params = _params()

    def build():
        return EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=2),
            lm=ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=32,
                                 fused_prefill=False))

    # Single-replica reference run of the same seeds.
    ref = FleetManager([ReplicaSpec("solo", build)],
                       watchdog_threshold=NO_WATCHDOG)
    for req in _mixed_workload():
        ref.submit(req)
    ref_out = _outputs(ref.stream())
    assert len(ref_out) == 12, f"reference lost requests: {ref_out.keys()}"

    # 3 replicas, one killed mid-run (its 3rd quantum: work is in
    # flight and partly decoded by then).
    fleet = FleetManager([ReplicaSpec(f"r{i}", build) for i in range(3)],
                         injector=FaultInjector().kill("r1", 3),
                         watchdog_threshold=NO_WATCHDOG)
    for req in _mixed_workload():
        fleet.submit(req)
    log = list(fleet.stream())
    stats = fleet.stats()

    by_rid = check_event_invariants(log, expect_finished=tuple(ref_out))
    out = _outputs(log)
    assert not stats["lost"], f"lost requests: {stats['lost']}"
    assert set(out) == set(ref_out), \
        f"terminal set mismatch: {set(out) ^ set(ref_out)}"
    for rid, want in ref_out.items():
        got = out[rid]
        if isinstance(want, list):
            assert got == want, f"rid {rid}: tokens diverged after " \
                f"migration: {got} vs {want}"
        else:
            assert np.array_equal(np.asarray(got), want), \
                f"rid {rid}: image not bit-identical after migration"
    assert ("r1", "injected kill of r1 at step 3") in stats["evictions"]
    assert stats["migrations"] > 0, \
        "kill landed on an idle replica: smoke exercised nothing"
    resumed = {e.rid for e in log
               if isinstance(e, Progress) and e.phase == "resume"}
    assert resumed, "no Progress(resume) after eviction"
    del by_rid
    rows = [f"fleet_smoke/failover,12/12 bit-exact across replica kill,"
            f"{stats['migrations']} migrated ({sorted(resumed)} resumed) "
            f"0 lost"]
    print(rows[0])
    return rows


def smoke_capacity_recovery() -> list[str]:
    """Replacement (PR 9): with ``replace_evicted=True`` an injected
    kill respawns a fresh replica from the evicted spec's build — the
    fleet ends the run at full strength, the replacement absorbs real
    work, and every request still finishes bit-exact."""
    _, lm_params = _params()
    n_replicas, n_req = 3, 18

    def build():
        return ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=16,
                                 fused_prefill=False)

    def reqs():
        rng = np.random.RandomState(11)
        return [Request(rid=i, prompt=rng.randint(1, 90, size=4).tolist(),
                        max_new=5)
                for i in range(n_req)]

    ref = FleetManager([ReplicaSpec("solo", build)],
                       watchdog_threshold=NO_WATCHDOG)
    for r in reqs():
        ref.submit(r)
    ref_out = _outputs(ref.stream())

    fleet = FleetManager(
        [ReplicaSpec(f"c{i}", build) for i in range(n_replicas)],
        injector=FaultInjector().kill("c1", 2),
        watchdog_threshold=NO_WATCHDOG, replace_evicted=True)
    for r in reqs():
        fleet.submit(r)
    out = _outputs(fleet.stream())
    stats = fleet.stats()

    assert out == ref_out, "replacement run diverged from reference"
    assert not stats["lost"], f"lost requests: {stats['lost']}"
    assert ("c1", "c1~0") in stats["replacements"], stats["replacements"]
    live = [r for r in stats["replicas"] if r["state"] != "EVICTED"]
    assert len(live) == n_replicas, \
        f"capacity not recovered: {len(live)}/{n_replicas} live replicas"
    repl = next(r for r in stats["replicas"] if r["name"] == "c1~0")
    assert repl["steps"] > 0, "replacement replica absorbed no work"
    rows = [f"fleet_smoke/capacity_recovery,{len(live)}/{n_replicas} "
            f"replicas live after kill,replacement c1~0 ran "
            f"{repl['steps']} quanta; {n_req}/{n_req} bit-exact"]
    print(rows[0])
    return rows


def smoke_throughput_scaling() -> list[str]:
    """Parallel makespan (max per-replica quanta — wall time when
    replicas step concurrently) must strictly drop from 1 to 3
    replicas on the same workload, i.e. fleet req/s scales."""
    _, lm_params = _params()
    n_req = 12

    def makespan(n_replicas: int) -> int:
        def build():
            return ContinuousBatcher(lm_params, LM_CFG, slots=2,
                                     max_len=16, fused_prefill=False)
        fleet = FleetManager(
            [ReplicaSpec(f"n{i}", build) for i in range(n_replicas)],
            watchdog_threshold=NO_WATCHDOG)
        rng = np.random.RandomState(3)
        for i in range(n_req):
            fleet.submit(Request(
                rid=i, prompt=rng.randint(1, 90, size=4).tolist(),
                max_new=5))
        done = fleet.run()
        assert len(done) == n_req
        return max(r["steps"] for r in fleet.stats()["replicas"])

    m1, m3 = makespan(1), makespan(3)
    # req/s at a nominal 10 ms quantum, for the human-readable detail.
    # The value leads with the speedup ratio so the trajectory
    # comparator (benchmarks/compare.py) gates on it directly.
    rps1, rps3 = n_req / (m1 * 0.01), n_req / (m3 * 0.01)
    rows = [f"fleet_smoke/scaling,{rps3 / rps1:.2f}x speedup at 3 "
            f"replicas,makespan {m3} quanta vs {m1}; "
            f"req/s {rps3:.0f} vs {rps1:.0f}"]
    print(rows[0])
    assert m3 < m1, (
        f"3-replica fleet must beat 1 replica on parallel makespan "
        f"(got {m3} vs {m1} quanta)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    all_rows = (smoke_failover_bit_exact() + smoke_capacity_recovery()
                + smoke_throughput_scaling())
    if a.json:
        try:
            from benchmarks.common import write_bench_json
        except ImportError:
            from common import write_bench_json
        write_bench_json(a.json, "serving", all_rows, bench="fleet_smoke")
