"""§V.A analogue: kernel-level wall-clock of the quantized dot-product
paths on this host (XLA path + Pallas interpret sanity) and the 5-bit
scale approximation error (the paper's OP_CVT53 claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ops, ref

from benchmarks.common import csv_row


def _bench(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose: bool = True) -> list[str]:
    rows = []
    m, k, n = 64, 2048, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, k), jnp.float32) * .05
    w8 = quant.quantize_q8_0(w)
    w4 = quant.quantize_q4_0(w)
    w3 = quant.quantize_q3_k(w)
    w3i = quant.quantize_q3_k(w, scale_bits=5)

    f_dense = jax.jit(lambda a, b: a @ b.T)
    f_q8 = jax.jit(lambda a, t: ops.quantized_matmul(a, t, force="xla"))
    f_q4 = jax.jit(lambda a, t: ops.quantized_matmul(a, t, force="xla"))
    f_q3 = jax.jit(lambda a, t: ops.quantized_matmul(a, t, force="xla"))
    rows.append(csv_row("kernel/dense_f32", _bench(f_dense, x, w)))
    rows.append(csv_row("kernel/q8_0_xla", _bench(f_q8, x, w8)))
    rows.append(csv_row("kernel/q4_0_xla", _bench(f_q4, x, w4)))
    rows.append(csv_row("kernel/q3_k_xla", _bench(f_q3, x, w3)))

    # Correctness anchors (oracle + paper's scale-approximation claim).
    y_ref = ref.q8_matmul_ref(x, w8)       # exact oracle of the path
    y_q8 = f_q8(x, w8)
    err8 = float(jnp.linalg.norm(y_q8 - y_ref) / jnp.linalg.norm(y_ref))
    y3 = ref.q3k_matmul_ref(x, w3)
    y3i = ref.q3k_matmul_ref(x, w3i)
    yd = x @ w.T
    e6 = float(jnp.linalg.norm(y3 - yd) / jnp.linalg.norm(yd))
    e5 = float(jnp.linalg.norm(y3i - yd) / jnp.linalg.norm(yd))
    rows.append(csv_row("kernel/q8_path_relerr", err8 * 1e6,
                        f"relerr={err8:.2e}"))
    rows.append(csv_row("kernel/q3k_scale6_relerr", e6 * 1e6,
                        f"relerr={e6:.4f}"))
    rows.append(csv_row("kernel/q3k_scale5_relerr", e5 * 1e6,
                        f"relerr={e5:.4f}"))
    if verbose:
        for r in rows:
            print(r)
    assert err8 < 1e-5
    # Paper: approximating scales to 5 bits has almost no effect.
    assert e5 < e6 * 1.15, (e5, e6)
    return rows


if __name__ == "__main__":
    run()
