"""Gating observability smoke: spans/counters vs engine ground truth.

Exercises the `repro.obs` telemetry layer end-to-end against the
serving stack and **gates** on the three consistency promises the
layer contracts on:

* **Zero-perturbation** — the same virtual-clock workload run with
  ``metrics=None`` and with a full ``Telemetry`` (registry + tracer)
  produces an *identical* event sequence (type, rid, timestamp) and
  bit-identical outputs (LM token lists / diffusion images).  The
  virtual clock is derived from scheduler quanta counters, so any
  instrumentation overhead that leaked into scheduling or timing
  would shift an event and fail the gate.  Wall-clock overhead is
  reported as a non-gating row.
* **Counter/histogram reconciliation** — ``phase_seconds`` histogram
  counts equal the engine's own quantum counters
  (``prefill_quanta``/``decode_quanta``, diffusion step quanta);
  ``events_total`` / ``tokens_emitted_total`` /
  ``requests_terminal_total`` equal what the bus log says; the
  cost-model ``cost_model_rel_error`` histogram is populated once a
  calibrated model observes real quanta.
* **Span-tree/event consistency** — per rid the tracer holds exactly
  one root ``request`` span whose outcome matches the terminal event,
  a ``queue_wait`` span iff admitted, and per-phase child spans whose
  counts equal the per-request step counters (``prefill_steps`` /
  ``decode_steps`` for LM; ``clip``/``unet_step``/``vae``/``fused``
  quanta for diffusion), all contained in the root interval.

Plus the exporters: the JSON snapshot is validated against
``benchmarks.common.validate_record`` (the CI perf-trajectory
schema), the Prometheus text exposition is spot-checked, and the
Chrome trace JSON is re-loaded and structurally checked.  A fleet
section gates the health-transition / dispatch / migration counters
across an injected replica kill.

Run:  PYTHONPATH=src python benchmarks/obs_smoke.py \
          [--json PATH] [--trace-out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, CostModel, DiffusionEngine,
                          FaultInjector, Finished, FleetManager,
                          GenerateRequest, PreviewLatent, ReplicaSpec,
                          TokenDelta, calibrate, init_pipeline)
from repro.models.transformer import init_lm
from repro.obs import Telemetry, TraceRecorder
from repro.serving import ContinuousBatcher, Request

try:                          # package import (python -m ...)
    from benchmarks.common import validate_record
    from benchmarks.streaming_smoke import check_event_invariants
except ImportError:           # script run: sys.path[0] is benchmarks/
    from common import validate_record
    from streaming_smoke import check_event_invariants

LM_CFG = ModelConfig(name="smoke-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)

NO_WATCHDOG = 1e9            # injector-driven faults only (no timing)


def _event_sig(log, min_rid=0):
    """Comparable event signature: (type, rid, ts) per event.  The ts
    comes from the quanta-derived virtual clock, so any
    instrumentation-induced scheduling perturbation shows up here."""
    return [(type(e).__name__, e.rid, e.ts) for e in log
            if e.rid >= min_rid]


def _run_lm(lm_params, tele):
    """One deterministic LM workload under a quanta-derived virtual
    clock; identical scheduling with or without telemetry attached."""
    box: dict = {}

    def vclock() -> float:   # 1 scheduling quantum == 10 virtual ms
        cb = box.get("cb")
        return 0.0 if cb is None else \
            (cb.prefill_quanta + cb.decode_quanta) * 0.01

    cm = CostModel()
    if tele is not None:
        cm.metrics = tele    # estimate-vs-actual error histograms
    cb = ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=32,
                           fused_prefill=False, clock=vclock,
                           cost_model=cm, metrics=tele)
    box["cb"] = cb
    if tele is not None:
        tele.attach(cb.bus)  # single engine: no bus rebinding after
    calibrate(cb, [Request(rid=100 + i, prompt=[1, 2, 3], max_new=4)
                   for i in range(2)])
    rng = np.random.RandomState(7)
    reqs = [Request(rid=i, prompt=rng.randint(1, 90, size=4).tolist(),
                    max_new=4 + i % 3) for i in range(4)]
    for r in reqs:
        cb.submit(r)
    log = list(cb.stream())
    return log, {r.rid: list(r.out) for r in reqs}, cb, reqs


def smoke_lm_consistency(trace_out: str | None) -> list[str]:
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)

    t0 = time.perf_counter()
    plain_log, plain_out, _, _ = _run_lm(lm_params, None)
    t_plain = time.perf_counter() - t0
    tele = Telemetry(tracer=TraceRecorder())
    t0 = time.perf_counter()
    log, out, cb, reqs = _run_lm(lm_params, tele)
    t_tele = time.perf_counter() - t0

    # Gate 1: zero-perturbation — identical events and tokens.
    assert _event_sig(log) == _event_sig(plain_log), \
        "telemetry perturbed the event sequence / virtual timestamps"
    assert out == plain_out, "telemetry perturbed generated tokens"
    check_event_invariants([e for e in log if e.rid < 100],
                           expect_finished=tuple(out))

    # Gate 2: histogram counts reconcile with engine step counters.
    reg = tele.registry
    ph = reg.get("phase_seconds")
    assert ph.count(engine="lm", phase="prefill") == cb.prefill_quanta, \
        (ph.count(engine="lm", phase="prefill"), cb.prefill_quanta)
    assert ph.count(engine="lm", phase="decode") == cb.decode_quanta, \
        (ph.count(engine="lm", phase="decode"), cb.decode_quanta)
    ev_total = reg.get("events_total")
    for t in ("Admitted", "TokenDelta", "Finished", "Progress"):
        want = sum(type(e).__name__ == t for e in log)
        assert ev_total.value(type=t) == want, (t, want)
    n_tok = sum(isinstance(e, TokenDelta) for e in log)
    assert reg.get("tokens_emitted_total").value() == n_tok
    n_fin = sum(isinstance(e, Finished) for e in log)
    assert reg.get("requests_terminal_total").value(
        engine="lm", outcome="finished") == n_fin
    assert reg.get("requests_submitted_total").value(engine="lm") \
        == 6                  # 2 calibration + 4 workload requests
    # Calibrated model observed real quanta -> error histogram live.
    err = reg.get("cost_model_rel_error")
    n_err = sum(err.samples().values()) if err is not None else 0
    assert n_err > 0, "cost_model_rel_error never observed"

    # Gate 3: span trees match per-request ground truth.
    tr = tele.tracer
    for r in reqs:
        root, children = tr.request_tree(r.rid)
        assert root is not None and root.args["outcome"] == "finished"
        names = [s.name for s in children]
        assert names.count("queue_wait") == 1, (r.rid, names)
        assert names.count("prefill") == r.prefill_steps, (r.rid, names)
        assert names.count("decode") == r.decode_steps, (r.rid, names)
        for s in children:
            assert root.start <= s.start and s.end <= root.end, \
                f"rid {r.rid}: span {s.name} outside root interval"
        assert tr.outcome(r.rid) == "finished"

    # Gate 4: exporters round-trip.
    with tempfile.TemporaryDirectory() as td:
        snap_path = os.path.join(td, "snap.json")
        reg.write_snapshot(snap_path)
        with open(snap_path) as f:
            snap = json.load(f)
        validate_record(snap)       # CI perf-trajectory schema
        assert snap["suite"] == "obs" and snap["entries"]
        tpath = trace_out or os.path.join(td, "trace.json")
        tr.export(tpath)
        with open(tpath) as f:
            chrome = json.load(f)
    evs = chrome["traceEvents"]
    assert evs and all("ph" in e and "pid" in e for e in evs)
    n_x = sum(e["ph"] == "X" for e in evs)
    assert n_x == len(tr.spans) and \
        all("ts" in e and "dur" in e for e in evs if e["ph"] == "X")
    prom = reg.to_prometheus()
    assert "# TYPE phase_seconds histogram" in prom
    assert 'phase_seconds_bucket{engine="lm",phase="decode",le="+Inf"}' \
        in prom

    overhead = (t_tele - t_plain) / max(t_plain, 1e-9)
    rows = [
        f"obs_smoke/lm_consistency,{len(log)} events bit-identical "
        f"with telemetry on,phase counts == "
        f"{cb.prefill_quanta}+{cb.decode_quanta} quanta; "
        f"{len(tr.spans)} spans; {n_err:.0f} cost-error samples",
        f"obs_smoke/exporters,{len(snap['entries'])} snapshot entries "
        f"+ {n_x} trace spans,schema + prometheus + chrome round-trip",
        f"obs_smoke/overhead,{max(overhead, 0.0):.2f}x wall overhead "
        f"with telemetry,non-gating; virtual-clock overhead gated at 0",
    ]
    for r in rows:
        print(r)
    return rows


def smoke_diffusion_spans() -> list[str]:
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (TINY_SD.text_len,), 0,
                              TINY_SD.clip_cfg().vocab_size)
    steps = 4

    def run(tele):
        box: dict = {}

        def vclock() -> float:   # 1 engine quantum == 10 virtual ms
            eng = box.get("eng")
            return 0.0 if eng is None else eng.quanta * 0.01

        eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                              clock=vclock, metrics=tele)
        box["eng"] = eng
        if tele is not None:
            tele.attach(eng.bus)
        # rid 0 streams previews (segmented clip/unet_step/vae path);
        # rid 1 runs the fused scan.
        eng.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                   steps=steps, seed=0, preview_every=2))
        eng.submit(GenerateRequest(rid=1, tokens=toks, sampler="ddim",
                                   steps=steps, seed=1))
        log = list(eng.stream())
        imgs = {e.rid: np.asarray(e.result.image) for e in log
                if isinstance(e, Finished)}
        return log, imgs, eng

    plain_log, plain_imgs, _ = run(None)
    tele = Telemetry(tracer=TraceRecorder())
    log, imgs, eng = run(tele)

    assert _event_sig(log) == _event_sig(plain_log), \
        "telemetry perturbed the diffusion event sequence"
    assert set(imgs) == set(plain_imgs) == {0, 1}
    for rid in imgs:
        assert np.array_equal(imgs[rid], plain_imgs[rid]), \
            f"rid {rid}: image not bit-identical with telemetry on"
    check_event_invariants(log, expect_finished=(0, 1))

    tr = tele.tracer
    root0, ch0 = tr.request_tree(0)
    names0 = [s.name for s in ch0]
    assert names0.count("clip") == 1, names0
    assert names0.count("unet_step") == steps, names0
    assert names0.count("vae") == 1, names0
    root1, ch1 = tr.request_tree(1)
    names1 = [s.name for s in ch1]
    assert names1.count("fused") == 1, names1
    for root, ch in ((root0, ch0), (root1, ch1)):
        assert root is not None and root.args["outcome"] == "finished"
        for s in ch:
            assert root.start <= s.start and s.end <= root.end

    reg = tele.registry
    ph = reg.get("phase_seconds")
    assert ph.count(engine="diffusion", phase="unet_step") == steps
    assert ph.count(engine="diffusion", phase="clip") == 1
    assert ph.count(engine="diffusion", phase="vae") == 1
    assert ph.count(engine="diffusion", phase="fused") == 1
    n_prev = sum(isinstance(e, PreviewLatent) for e in log)
    assert reg.get("previews_total").value() == n_prev > 0
    assert reg.get("requests_terminal_total").value(
        engine="diffusion", outcome="finished") == 2
    rows = [f"obs_smoke/diffusion_spans,clip+{steps}x unet_step+vae "
            f"spans match Fig.11 phases,fused span 1; {n_prev} preview "
            f"markers; images bit-identical"]
    print(rows[0])
    return rows


def smoke_fleet_health_metrics() -> list[str]:
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    tele = Telemetry()
    n_req = 8

    def build():
        return ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=32,
                                 fused_prefill=False, metrics=tele)

    fleet = FleetManager([ReplicaSpec(f"r{i}", build) for i in range(3)],
                         injector=FaultInjector().kill("r1", 3),
                         watchdog_threshold=NO_WATCHDOG, metrics=tele)
    tele.attach(fleet.bus)   # AFTER construction: replica buses rebound
    rng = np.random.RandomState(3)
    for i in range(n_req):
        fleet.submit(Request(rid=i,
                             prompt=rng.randint(1, 90, size=4).tolist(),
                             max_new=5))
    log = list(fleet.stream())
    stats = fleet.stats()
    assert not stats["lost"]
    check_event_invariants(log, expect_finished=tuple(range(n_req)))

    reg = tele.registry
    disp = reg.get("fleet_dispatch_total")
    assert sum(disp.samples().values()) == n_req, disp.samples()
    assert reg.get("fleet_evictions_total").value(replica="r1") == 1
    assert reg.get("fleet_migrations_total").value() \
        == stats["migrations"] > 0
    lost = reg.get("fleet_lost_total")
    assert lost is None or sum(lost.samples().values()) == 0
    trans = reg.get("replica_health_transitions_total")
    evicted = {k[0]: v for k, v in trans.samples().items()
               if k[2] == "EVICTED"}
    assert evicted == {"r1": 1.0}, trans.samples()
    assert reg.get("requests_terminal_total").value(
        engine="lm", outcome="finished") == n_req
    rows = [f"obs_smoke/fleet_health,r1 kill -> 1 eviction transition,"
            f"{stats['migrations']} migrations counted, "
            f"{n_req} dispatches, 0 lost"]
    print(rows[0])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also keep the LM section's Chrome trace JSON "
                         "at PATH (CI uploads it as an artifact)")
    a = ap.parse_args()
    all_rows = (smoke_lm_consistency(a.trace_out)
                + smoke_diffusion_spans()
                + smoke_fleet_health_metrics())
    if a.json:
        try:
            from benchmarks.common import write_bench_json
        except ImportError:
            from common import write_bench_json
        write_bench_json(a.json, "obs", all_rows, bench="obs_smoke")
