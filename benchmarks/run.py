"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and asserts each module's
reproduction bands (see module docstrings for tolerances and known
divergences).

  PYTHONPATH=src python -m benchmarks.run [--only fig8]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (fig6_7_e2e_latency, fig8_pdp,
                            fig9_10_lane_scaling, fig11_phase_breakdown,
                            kernel_microbench, table1_dtype_breakdown)
    modules = {
        "table1": table1_dtype_breakdown,
        "fig6_7": fig6_7_e2e_latency,
        "fig8": fig8_pdp,
        "fig9_10": fig9_10_lane_scaling,
        "fig11": fig11_phase_breakdown,
        "kernels": kernel_microbench,
    }
    failed = []
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and args.only != name:
            continue
        try:
            mod.run(verbose=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmark reproductions within bands")


if __name__ == "__main__":
    main()
