"""Serving cache micro-benchmark: paged KV runtime vs naive preallocation.

Serves a multi-wave workload through the ``ContinuousBatcher`` and
reports

* decode tokens/s (steady host+device loop, greedy decode),
* prefill vs decode quanta against the old replay-through-decode
  admission (which burned ``prompt_len - 1 + max_new`` decode steps per
  request),
* paged cache bytes (the physical pools actually allocated) vs the
  naive preallocation the seed used: one shared high-water cache of
  ``waves * (prompt + max_new) + 1`` positions per slot,
* prefix-cache savings when every request shares a system-prompt
  prefix,
* fused vs scan admission: per-token kernel launches and wall time of
  the same workload with ``fused_prefill`` on (one fused paged
  flash-prefill program per chunk) vs off (the decode-step-scan
  oracle).  **Gating invariant** (CI runs this without
  continue-on-error): fused admission must use strictly fewer
  per-token launches than the scan, and the two paths must agree on
  >= 90% of emitted tokens (bf16-ulp numeric divergence may flip a
  rare near-tie argmax; wholesale divergence means a kernel bug),
* the same admission comparison on Q8_0 KV pools
  (``quantized_kv=True``): the fused-q8 prefill sibling must beat the
  dequant-reference scan on launches and pass the same >= 90% token
  agreement gate — the fused kernel requantizes with the exact
  arithmetic of the scan's ``_quantize_kv`` and reads the pool at the
  scan's bf16 dequant precision, so the pools are bit-identical and
  only accumulation-order near-tie argmax flips remain
  (pool/token identity is gated bit-exactly in
  ``tests/test_flash_prefill.py``),
* roofline memory terms for the quantized hot path (packed Q8_0
  weight + KV bytes through ``fused_dequant_memory_s``) against the
  bf16 baseline, so ``BENCH_serving.json`` records the before/after
  HBM story alongside the launch counts.

Each admission arm asserts ``cb.fused_prefill`` matches what it asked
for, so the launch-count gate cannot pass vacuously by both arms
silently running the scan.

Run:  PYTHONPATH=src python benchmarks/serving_cache.py \
          [--slots 4] [--requests 16] [--prompt-len 24] [--gen 16] \
          [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request
from repro.serving.kvcache import cdiv


def cache_bytes(cb: ContinuousBatcher) -> int:
    """Bytes of the self-attention KV pools in the live cache pytree."""
    total = 0
    for layer in cb.cache:
        total += sum(x.nbytes for x in jax.tree.leaves(layer.kv))
    return total


def naive_bytes(cfg: ModelConfig, slots: int, waves: int, prompt_len: int,
                gen: int) -> int:
    """The seed's shared high-water sizing: every slot holds every wave."""
    cap = waves * (prompt_len + gen) + 1
    per_pos = 2 * cfg.num_kv_heads * cfg.hd * 2          # k+v, bf16
    return cfg.num_layers * slots * cap * per_pos


def run(slots: int = 4, requests: int = 16, prompt_len: int = 24,
        gen: int = 16, prefix_len: int = 0, block_size: int = 8,
        verbose: bool = True) -> list[str]:
    cfg = ModelConfig(name="bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=96, head_dim=32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prefix = [int(t) for t in rng.integers(1, 90, prefix_len)]
    prompts = [prefix + [int(t) for t in
                         rng.integers(1, 90, prompt_len - prefix_len)]
               for _ in range(requests)]

    max_len = ContinuousBatcher.required_len(requests, slots, prompt_len,
                                             gen)
    cb = ContinuousBatcher(params, cfg, slots=slots, max_len=max_len,
                           block_size=block_size,
                           prefix_share=prefix_len > 0)
    for rid, p in enumerate(prompts):       # warm-up wave compiles
        cb.submit(Request(rid=rid, prompt=p, max_new=gen))
    cb.run()

    q0_p, q0_d = cb.prefill_quanta, cb.decode_quanta
    for rid, p in enumerate(prompts):
        cb.submit(Request(rid=rid + requests, prompt=p, max_new=gen))
    t0 = time.time()
    done = cb.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done[-requests:])

    waves = cdiv(requests, slots)
    paged = cache_bytes(cb)
    naive = naive_bytes(cfg, slots, waves, prompt_len, gen)
    replay_decode = requests * (prompt_len - 1 + gen)
    rows = [
        f"serving_cache/throughput,{n_tok / dt:.1f} tok/s,"
        f"{requests} reqs x {gen} new on {slots} slots in {dt:.2f}s",
        f"serving_cache/quanta,prefill {cb.prefill_quanta - q0_p} + "
        f"decode {cb.decode_quanta - q0_d},"
        f"replay-admission would need {replay_decode} decode steps",
        f"serving_cache/bytes,paged {paged / 1e3:.1f} KB,"
        f"naive high-water {naive / 1e3:.1f} KB "
        f"({naive / paged:.1f}x, {waves} waves)",
    ]
    if prefix_len:
        rows.append(
            f"serving_cache/prefix,{cb.runtime.prefix.hits} blocks "
            f"adopted,{cb.runtime.cow_copies} CoW copies")
    assert all(len(r.out) == gen for r in done[-requests:]), \
        "truncated outputs: paged sizing is wrong"

    # ---- fused vs scan admission on an identical workload ----
    def admission_arm(fused: bool, quantized_kv: bool):
        cb2 = ContinuousBatcher(params, cfg, slots=slots, max_len=max_len,
                                block_size=block_size, fused_prefill=fused,
                                quantized_kv=quantized_kv)
        # Non-vacuity: the arm must actually take the path it names —
        # if init silently downgraded fused admission, the launch-count
        # gate below would compare scan against scan and prove nothing.
        assert cb2.fused_prefill is fused, (
            f"admission arm asked for fused={fused} "
            f"(quantized_kv={quantized_kv}) but got "
            f"fused_prefill={cb2.fused_prefill}")
        for rid, p in enumerate(prompts):   # warm-up wave compiles
            cb2.submit(Request(rid=rid, prompt=list(p), max_new=gen))
        cb2.run()
        l0 = cb2.prefill_launches
        for rid, p in enumerate(prompts):
            cb2.submit(Request(rid=rid + requests, prompt=list(p),
                               max_new=gen))
        t0 = time.time()
        out = cb2.run()
        return (cb2.prefill_launches - l0, time.time() - t0,
                {r.rid: r.out for r in out[-requests:]}, cb2)

    (fl, ft, fo, _), (sl, st, so, _) = (admission_arm(True, False),
                                        admission_arm(False, False))
    rows.append(
        f"serving_cache/admission,fused {fl} launches in {ft:.2f}s,"
        f"scan {sl} launches in {st:.2f}s")
    # Gating admission-quanta invariant: one fused program per chunk
    # must beat one decode-step program per prompt token.
    assert fl < sl, (
        f"fused admission used {fl} per-token kernel launches, scan "
        f"used {sl}: the fused path must be strictly cheaper")
    # Token agreement between the two paths: they are numerically
    # divergent at bf16 ulp scale (chunk-at-once vs per-token matmuls),
    # so a rare near-tie greedy argmax may legitimately flip under a
    # compiler/runtime change.  Gate on overwhelming agreement, not
    # bit equality — a kernel bug shows up as wholesale divergence.
    toks = [(a, b) for rid in fo for a, b in zip(fo[rid], so[rid])]
    agree = sum(a == b for a, b in toks) / max(1, len(toks))
    assert agree >= 0.9, (
        f"fused and scan admission agree on only {agree:.0%} of tokens "
        f"— fused prefill has diverged from the decode-step oracle")

    # ---- quantized-KV admission: fused-q8 vs dequant-reference scan ----
    (qfl, qft, qfo, qcb), (qsl, qst, qso, _) = (
        admission_arm(True, True), admission_arm(False, True))
    rows.append(
        f"serving_cache/admission_q8,fused {qfl} launches in {qft:.2f}s,"
        f"scan {qsl} launches in {qst:.2f}s")
    assert qfl < qsl, (
        f"fused-q8 admission used {qfl} per-token kernel launches, "
        f"dequant-reference scan used {qsl}: the fused Q8_0 path must "
        f"be strictly cheaper")
    # The fused kernel requantizes each chunk with quantize_q8_0 — the
    # same function the scan path's _quantize_kv applies — and reads
    # the pool at the scan's bf16 dequant precision, so the pools are
    # bit-identical between the paths (gated bit-exactly in
    # tests/test_flash_prefill.py).  Token streams are gated like the
    # fp arm: chunk-at-once vs per-token programs accumulate in a
    # different order, so a rare near-tie greedy argmax may flip;
    # wholesale divergence means a requantization bug.
    qtoks = [(a, b) for rid in qfo for a, b in zip(qfo[rid], qso[rid])]
    qagree = sum(a == b for a, b in qtoks) / max(1, len(qtoks))
    assert qagree >= 0.9, (
        f"fused-q8 and dequant-reference scan admission agree on only "
        f"{qagree:.0%} of tokens — in-kernel requantization has "
        f"diverged from the scan oracle")

    # ---- roofline memory terms: quantized hot path vs bf16 baseline --
    from repro.core.policy import get_policy
    from repro.core.qlinear import param_bytes, quantize_params
    from repro.profiling.roofline import fused_dequant_memory_s
    dense_wb = param_bytes(params)
    packed_wb = param_bytes(quantize_params(params, get_policy("q8_0")))
    q8_kvb = cache_bytes(qcb)       # int8 pools + f16 scale pools
    base_kvb = cache_bytes(cb)      # the bf16 pools measured above
    t_bf16 = fused_dequant_memory_s(
        packed_weight_bytes_per_chip=dense_wb, kv_bytes_per_chip=base_kvb)
    t_q8 = fused_dequant_memory_s(
        packed_weight_bytes_per_chip=packed_wb, kv_bytes_per_chip=q8_kvb)
    rows.append(
        f"serving_cache/roofline_q8,memory term {t_q8 * 1e6:.2f} us vs "
        f"bf16 {t_bf16 * 1e6:.2f} us,weights {packed_wb / 1e3:.1f} KB "
        f"packed vs {dense_wb / 1e3:.1f} KB bf16; KV {q8_kvb / 1e3:.1f} "
        f"KB q8 vs {base_kvb / 1e3:.1f} KB bf16")
    assert t_q8 < t_bf16, (
        "quantized hot path must strictly lower the streaming memory "
        "term (packed weights + Q8_0 KV pools)")
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--prefix-len", type=int, default=None,
                    help="shared system-prompt tokens (enables prefix "
                         "sharing)")
    ap.add_argument("--block-size", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI defaults (explicit flags still win)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    base = (dict(slots=2, requests=8, prompt_len=12, gen=4, prefix_len=8,
                 block_size=4) if a.smoke else
            dict(slots=4, requests=16, prompt_len=24, gen=16,
                 prefix_len=0, block_size=8))
    for k in base:
        if getattr(a, k) is not None:
            base[k] = getattr(a, k)
    out_rows = run(**base)
    if a.json:
        try:                      # package import (python -m ...)
            from benchmarks.common import write_bench_json
        except ImportError:       # script run: sys.path[0] is benchmarks/
            from common import write_bench_json
        write_bench_json(a.json, "serving", out_rows,
                         bench="serving_cache")
