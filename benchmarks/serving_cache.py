"""Serving cache micro-benchmark: paged KV runtime vs naive preallocation.

Serves a multi-wave workload through the ``ContinuousBatcher`` and
reports

* decode tokens/s (steady host+device loop, greedy decode),
* prefill vs decode quanta against the old replay-through-decode
  admission (which burned ``prompt_len - 1 + max_new`` decode steps per
  request),
* paged cache bytes (the physical pools actually allocated) vs the
  naive preallocation the seed used: one shared high-water cache of
  ``waves * (prompt + max_new) + 1`` positions per slot,
* prefix-cache savings when every request shares a system-prompt
  prefix,
* fused vs scan admission: per-token kernel launches and wall time of
  the same workload with ``fused_prefill`` on (one fused paged
  flash-prefill program per chunk) vs off (the decode-step-scan
  oracle).  **Gating invariant** (CI runs this without
  continue-on-error): fused admission must use strictly fewer
  per-token launches than the scan, and the two paths must agree on
  >= 90% of emitted tokens (bf16-ulp numeric divergence may flip a
  rare near-tie argmax; wholesale divergence means a kernel bug).

Run:  PYTHONPATH=src python benchmarks/serving_cache.py \
          [--slots 4] [--requests 16] [--prompt-len 24] [--gen 16] \
          [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request
from repro.serving.kvcache import cdiv


def cache_bytes(cb: ContinuousBatcher) -> int:
    """Bytes of the self-attention KV pools in the live cache pytree."""
    total = 0
    for layer in cb.cache:
        total += sum(x.nbytes for x in jax.tree.leaves(layer.kv))
    return total


def naive_bytes(cfg: ModelConfig, slots: int, waves: int, prompt_len: int,
                gen: int) -> int:
    """The seed's shared high-water sizing: every slot holds every wave."""
    cap = waves * (prompt_len + gen) + 1
    per_pos = 2 * cfg.num_kv_heads * cfg.hd * 2          # k+v, bf16
    return cfg.num_layers * slots * cap * per_pos


def run(slots: int = 4, requests: int = 16, prompt_len: int = 24,
        gen: int = 16, prefix_len: int = 0, block_size: int = 8,
        verbose: bool = True) -> list[str]:
    cfg = ModelConfig(name="bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=96, head_dim=32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prefix = [int(t) for t in rng.integers(1, 90, prefix_len)]
    prompts = [prefix + [int(t) for t in
                         rng.integers(1, 90, prompt_len - prefix_len)]
               for _ in range(requests)]

    max_len = ContinuousBatcher.required_len(requests, slots, prompt_len,
                                             gen)
    cb = ContinuousBatcher(params, cfg, slots=slots, max_len=max_len,
                           block_size=block_size,
                           prefix_share=prefix_len > 0)
    for rid, p in enumerate(prompts):       # warm-up wave compiles
        cb.submit(Request(rid=rid, prompt=p, max_new=gen))
    cb.run()

    q0_p, q0_d = cb.prefill_quanta, cb.decode_quanta
    for rid, p in enumerate(prompts):
        cb.submit(Request(rid=rid + requests, prompt=p, max_new=gen))
    t0 = time.time()
    done = cb.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done[-requests:])

    waves = cdiv(requests, slots)
    paged = cache_bytes(cb)
    naive = naive_bytes(cfg, slots, waves, prompt_len, gen)
    replay_decode = requests * (prompt_len - 1 + gen)
    rows = [
        f"serving_cache/throughput,{n_tok / dt:.1f} tok/s,"
        f"{requests} reqs x {gen} new on {slots} slots in {dt:.2f}s",
        f"serving_cache/quanta,prefill {cb.prefill_quanta - q0_p} + "
        f"decode {cb.decode_quanta - q0_d},"
        f"replay-admission would need {replay_decode} decode steps",
        f"serving_cache/bytes,paged {paged / 1e3:.1f} KB,"
        f"naive high-water {naive / 1e3:.1f} KB "
        f"({naive / paged:.1f}x, {waves} waves)",
    ]
    if prefix_len:
        rows.append(
            f"serving_cache/prefix,{cb.runtime.prefix.hits} blocks "
            f"adopted,{cb.runtime.cow_copies} CoW copies")
    assert all(len(r.out) == gen for r in done[-requests:]), \
        "truncated outputs: paged sizing is wrong"

    # ---- fused vs scan admission on an identical workload ----
    adm = {}
    for fused in (True, False):
        cb2 = ContinuousBatcher(params, cfg, slots=slots, max_len=max_len,
                                block_size=block_size, fused_prefill=fused)
        for rid, p in enumerate(prompts):   # warm-up wave compiles
            cb2.submit(Request(rid=rid, prompt=list(p), max_new=gen))
        cb2.run()
        l0 = cb2.prefill_launches
        for rid, p in enumerate(prompts):
            cb2.submit(Request(rid=rid + requests, prompt=list(p),
                               max_new=gen))
        t0 = time.time()
        out = cb2.run()
        adm[fused] = (cb2.prefill_launches - l0, time.time() - t0,
                      {r.rid: r.out for r in out[-requests:]})
    (fl, ft, fo), (sl, st, so) = adm[True], adm[False]
    rows.append(
        f"serving_cache/admission,fused {fl} launches in {ft:.2f}s,"
        f"scan {sl} launches in {st:.2f}s")
    # Gating admission-quanta invariant: one fused program per chunk
    # must beat one decode-step program per prompt token.
    assert fl < sl, (
        f"fused admission used {fl} per-token kernel launches, scan "
        f"used {sl}: the fused path must be strictly cheaper")
    # Token agreement between the two paths: they are numerically
    # divergent at bf16 ulp scale (chunk-at-once vs per-token matmuls),
    # so a rare near-tie greedy argmax may legitimately flip under a
    # compiler/runtime change.  Gate on overwhelming agreement, not
    # bit equality — a kernel bug shows up as wholesale divergence.
    toks = [(a, b) for rid in fo for a, b in zip(fo[rid], so[rid])]
    agree = sum(a == b for a, b in toks) / max(1, len(toks))
    assert agree >= 0.9, (
        f"fused and scan admission agree on only {agree:.0%} of tokens "
        f"— fused prefill has diverged from the decode-step oracle")
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--prefix-len", type=int, default=None,
                    help="shared system-prompt tokens (enables prefix "
                         "sharing)")
    ap.add_argument("--block-size", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI defaults (explicit flags still win)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    base = (dict(slots=2, requests=8, prompt_len=12, gen=4, prefix_len=8,
                 block_size=4) if a.smoke else
            dict(slots=4, requests=16, prompt_len=24, gen=16,
                 prefix_len=0, block_size=8))
    for k in base:
        if getattr(a, k) is not None:
            base[k] = getattr(a, k)
    out_rows = run(**base)
    if a.json:
        try:                      # package import (python -m ...)
            from benchmarks.common import write_bench_json
        except ImportError:       # script run: sys.path[0] is benchmarks/
            from common import write_bench_json
        write_bench_json(a.json, "serving", out_rows,
                         bench="serving_cache")
