"""Speculative-decoding smoke: correctness + launch-economics gates.

Serves identical workloads through the ``ContinuousBatcher`` with and
without draft-model speculation and gates (CI runs this without
continue-on-error):

* **bit-exactness** — greedy speculation is a latency transform, not a
  sampler: the emitted token streams must equal baseline decode
  bit-exactly, on both arms (self-draft at 100% acceptance and a tiny
  independently-initialised draft at whatever acceptance it earns);
* **launch economics** — with a usable acceptance rate, total target
  ``decode_launches`` must be *strictly below* the baseline's
  one-launch-per-token (the whole point of the verification launch);
* **accounting reconciliation** — per-request ``proposed``/``accepted``
  must sum to the scheduler counters, which must agree with the
  telemetry counters (``lm_spec_proposed_total`` /
  ``lm_spec_accepted_total``).

Both arms run the fused verify path (one launch per verification) on a
tie-stable workload; the scan path's mathematical bit-exactness is
gated in ``tests/test_spec_decode.py``.

Run:  PYTHONPATH=src python benchmarks/spec_decode_smoke.py \
          [--slots 2] [--requests 4] [--prompt-len 8] [--gen 10] [--k 3] \
          [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, LMEngineConfig, SpecDecodeConfig
from repro.models.transformer import init_lm
from repro.obs import Telemetry
from repro.serving import ContinuousBatcher, Request

CFG = ModelConfig(name="bench", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)
DRAFT = ModelConfig(name="draft", family="dense", num_layers=1,
                    d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                    vocab_size=96, head_dim=16)


def _arm(params, prompts, gen, slots, max_len, spec=None, metrics=None):
    conf = EngineConfig(metrics=metrics, lm=LMEngineConfig(
        slots=slots, max_len=max_len, fused_prefill=True,
        spec_decode=spec))
    cb = ContinuousBatcher(params, CFG, config=conf)
    reqs = [Request(rid=i, prompt=list(p), max_new=gen)
            for i, p in enumerate(prompts)]
    for r in reqs:
        cb.submit(r)
    t0 = time.time()
    cb.run()
    return cb, reqs, time.time() - t0


def run(slots: int = 2, requests: int = 4, prompt_len: int = 8,
        gen: int = 10, k: int = 3, verbose: bool = True) -> list[str]:
    params = init_lm(jax.random.PRNGKey(0), CFG)
    dparams = init_lm(jax.random.PRNGKey(2), DRAFT)
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(1, 90, prompt_len)]
               for _ in range(requests)]
    max_len = ContinuousBatcher.required_len(requests, slots,
                                             prompt_len, gen)

    base, breqs, bt = _arm(params, prompts, gen, slots, max_len)
    n_tok = sum(len(r.out) for r in breqs)

    # Arm 1: self-draft — acceptance 1.0 by construction, so the
    # launch-economics gate is exercised at its design point.
    tele = Telemetry()
    sd = SpecDecodeConfig(draft_params=params, draft_cfg=CFG, k=k)
    spec, sreqs, st = _arm(params, prompts, gen, slots, max_len,
                           spec=sd, metrics=tele)

    # Gate (a): greedy speculation is token-bit-exact vs baseline.
    assert [r.out for r in sreqs] == [r.out for r in breqs], (
        "speculative decode diverged from baseline greedy decode — "
        "verification/rollback is broken")

    # Gate (b): strictly fewer target launches than 1-per-token.
    assert spec.decode_launches < base.decode_launches, (
        f"speculation used {spec.decode_launches} target decode "
        f"launches vs baseline {base.decode_launches}: the verify "
        "launch must amortise, not add")

    # Gate (c): counters reconcile end to end — per-request accounting,
    # scheduler totals, and telemetry counters must all agree.
    assert sum(r.proposed for r in sreqs) == spec.spec_proposed
    assert sum(r.accepted for r in sreqs) == spec.spec_accepted
    assert tele.counter("lm_spec_proposed_total").value() \
        == spec.spec_proposed, "telemetry lost proposed tokens"
    assert tele.counter("lm_spec_accepted_total").value() \
        == spec.spec_accepted, "telemetry lost accepted tokens"

    acc = spec.spec_accepted / max(1, spec.spec_proposed)
    rows = [
        f"spec_decode/baseline,{n_tok} tok in "
        f"{base.decode_launches} launches,"
        f"{requests} reqs x {gen} new on {slots} slots in {bt:.2f}s",
        f"spec_decode/self_draft,{n_tok} tok in "
        f"{spec.decode_launches} launches,"
        f"acceptance {acc:.0%} k={k} "
        f"+{spec.draft_launches} draft launches in {st:.2f}s",
        f"spec_decode/tokens_per_round,"
        f"{spec.spec_tokens_per_round():.2f},"
        f"{spec.spec_rounds} rounds for {n_tok} tokens",
    ]

    # Arm 2: a real (tiny, independently initialised) draft model.
    # Its acceptance rate is whatever it earns — usually low on random
    # weights — but correctness must hold at *any* acceptance rate.
    td = SpecDecodeConfig(draft_params=dparams, draft_cfg=DRAFT, k=k)
    tiny, treqs, tt = _arm(params, prompts, gen, slots, max_len,
                           spec=td)
    assert [r.out for r in treqs] == [r.out for r in breqs], (
        "speculation with an independent draft diverged from baseline "
        "— acceptance logic depends on the draft being right")
    tacc = tiny.spec_accepted / max(1, tiny.spec_proposed)
    rows.append(
        f"spec_decode/tiny_draft,{n_tok} tok in "
        f"{tiny.decode_launches} launches,"
        f"acceptance {tacc:.0%} ({DRAFT.num_layers}L/{DRAFT.d_model}d "
        f"draft) in {tt:.2f}s")

    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--gen", type=int, default=None)
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI defaults (explicit flags still win)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    base = (dict(slots=2, requests=4, prompt_len=8, gen=10, k=3)
            if a.smoke else
            dict(slots=2, requests=6, prompt_len=12, gen=16, k=4))
    for key in base:
        if getattr(a, key) is not None:
            base[key] = getattr(a, key)
    out_rows = run(**base)
    if a.json:
        try:                      # package import (python -m ...)
            from benchmarks.common import write_bench_json
        except ImportError:       # script run: sys.path[0] is benchmarks/
            from common import write_bench_json
        write_bench_json(a.json, "serving", out_rows,
                         bench="spec_decode")
