"""Gating streaming smoke: event-ordering invariants + SLO scheduling.

Drives an ``EngineRouter`` multiplexing a ``DiffusionEngine`` (with
``PreviewLatent`` streaming) and an LM ``ContinuousBatcher`` over one
host loop — including a mid-stream cancellation — and asserts the
event-stream invariants the streaming API contracts on:

* exactly one ``Admitted`` and exactly one terminal event
  (``Finished`` | ``Cancelled``) per rid, and the ``Admitted``
  precedes everything else;
* ``TokenDelta.pos`` strictly increasing per rid;
* no events of any kind after a rid's terminal event;
* the stream interleaves diffusion and LM events (not two serial
  phases);
* cancellation returns every KV block to the pool
  (``check_consistency()`` clean, allocated blocks back to baseline).

Then replays a deadline-laden LM workload under a deterministic
virtual clock (1 quantum = 10 ms) twice — EDF vs FIFO admission — and
**gates** on the EDF deadline-hit-rate being strictly better.

Run:  PYTHONPATH=src python benchmarks/streaming_smoke.py
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, Admitted, Cancelled, DiffusionEngine,
                          EngineRouter, Finished, GenerateRequest,
                          PreviewLatent, TokenDelta, init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

LM_CFG = ModelConfig(name="smoke-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)


def check_event_invariants(log, expect_cancelled=(), expect_finished=()):
    """The per-rid lifecycle invariants, asserted from a raw log."""
    by_rid: dict[int, list] = {}
    for e in log:
        by_rid.setdefault(e.rid, []).append(e)
    for rid, evs in by_rid.items():
        admits = [e for e in evs if isinstance(e, Admitted)]
        terms = [e for e in evs if isinstance(e, (Finished, Cancelled))]
        assert len(admits) <= 1, f"rid {rid}: {len(admits)} Admitted"
        assert len(terms) == 1, f"rid {rid}: {len(terms)} terminal events"
        assert evs[-1] is terms[0], f"rid {rid}: events after terminal"
        if admits:
            assert evs[0] is admits[0], f"rid {rid}: pre-admission events"
        poss = [e.pos for e in evs if isinstance(e, TokenDelta)]
        assert poss == sorted(set(poss)), \
            f"rid {rid}: TokenDelta positions not strictly increasing"
    for rid in expect_cancelled:
        assert isinstance(by_rid[rid][-1], Cancelled), f"rid {rid}"
    for rid in expect_finished:
        assert isinstance(by_rid[rid][-1], Finished), f"rid {rid}"
    return by_rid


def smoke_mixed_stream() -> None:
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)

    diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    lm = ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=16)
    router = EngineRouter(diffusion=diff, lm=lm)
    baseline_blocks = lm.runtime.allocated_blocks

    router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                  steps=4, seed=0, preview_every=2))
    router.submit(Request(rid=1, prompt=[3, 1, 4, 1, 5], max_new=6))
    victim = router.submit(Request(rid=2, prompt=[2, 7, 1, 8], max_new=8))

    log, cancelled = [], False
    for e in router.stream():
        log.append(e)
        # Cancel rid 2 mid-decode: after its second token arrives.
        if not cancelled and isinstance(e, TokenDelta) and e.rid == 2 \
                and e.pos >= 1:
            assert victim.cancel()
            cancelled = True
    assert cancelled, "victim never produced 2 tokens"

    by_rid = check_event_invariants(log, expect_cancelled=(2,),
                                    expect_finished=(0, 1))
    assert any(isinstance(e, PreviewLatent) for e in by_rid[0]), \
        "diffusion request streamed no previews"
    # Interleave: some LM event must land between two diffusion events.
    kinds = [e.rid for e in log]
    first0, last0 = kinds.index(0), len(kinds) - 1 - kinds[::-1].index(0)
    assert any(r != 0 for r in kinds[first0:last0]), \
        "stream did not interleave diffusion and LM events"
    # Cancelled blocks are back in the pool.
    lm.runtime.check_consistency()
    assert lm.runtime.allocated_blocks == baseline_blocks, \
        f"leak: {lm.runtime.allocated_blocks} blocks still allocated"
    print(f"streaming_smoke/stream,{len(log)} events over 3 rids,"
          f"invariants hold, cancel released all blocks")


def smoke_edf_beats_fifo() -> None:
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    # Deadlines tighten in submission order, so FIFO head-of-line
    # blocks the tight ones; slots=1 makes the reorder decisive.
    deadlines = [2000.0, 1000.0, 300.0, 150.0]

    def hit_rate(edf: bool) -> float:
        box: dict = {}

        def vclock() -> float:   # 1 scheduling quantum == 10 virtual ms
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = ContinuousBatcher(lm_params, LM_CFG, slots=1, max_len=16,
                               edf=edf, clock=vclock,
                               fused_prefill=False)
        box["cb"] = cb
        for rid, dl in enumerate(deadlines):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=dl))
        fins = {e.rid: e.ts for e in cb.stream()
                if isinstance(e, Finished)}
        assert len(fins) == len(deadlines)
        return sum(fins[r] <= deadlines[r] / 1e3
                   for r in fins) / len(fins)

    edf, fifo = hit_rate(True), hit_rate(False)
    print(f"streaming_smoke/slo,edf hit-rate {edf:.0%},"
          f"fifo hit-rate {fifo:.0%}")
    assert edf > fifo, (
        f"EDF admission must strictly beat FIFO on deadline hit-rate "
        f"(edf={edf:.0%}, fifo={fifo:.0%})")


if __name__ == "__main__":
    smoke_mixed_stream()
    smoke_edf_beats_fifo()
