"""Gating streaming smoke: event-ordering invariants + SLO scheduling.

Drives an ``EngineRouter`` multiplexing a ``DiffusionEngine`` (with
``PreviewLatent`` streaming) and an LM ``ContinuousBatcher`` over one
host loop — including a mid-stream cancellation — and asserts the
event-stream invariants the streaming API contracts on:

* exactly one ``Admitted`` and exactly one terminal event
  (``Finished`` | ``Cancelled`` | ``Rejected``) per rid, and the
  ``Admitted`` precedes everything else;
* ``TokenDelta.pos`` strictly increasing per rid;
* no events of any kind after a rid's terminal event;
* the stream interleaves diffusion and LM events (not two serial
  phases);
* cancellation returns every KV block to the pool
  (``check_consistency()`` clean, allocated blocks back to baseline).

Then replays a deadline-laden LM workload under a deterministic
virtual clock (1 quantum = 10 ms) twice — EDF vs FIFO admission — and
**gates** on the EDF deadline-hit-rate being strictly better.

Finally the **admission-feasibility** check (gating): the same virtual
clock drives a mixed-deadline workload three ways — FIFO, EDF, and
EDF + a calibrated phase-aware ``CostModel`` — and asserts

* hit-rate(cost-model) >= hit-rate(EDF) > hit-rate(FIFO),
* the infeasible request is ``Rejected`` at submit, never ``Admitted``
  (zero infeasible requests ever reach a slot),
* the diffusion engine rejects by the same feasibility rule from its
  seeded Fig.-11 phase composition (clip + steps x unet + vae).

Run:  PYTHONPATH=src python benchmarks/streaming_smoke.py [--json PATH]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, Admitted, Cancelled, CostModel,
                          DiffusionEngine, EngineRouter, Finished,
                          GenerateRequest, Preempted, PreviewLatent,
                          Rejected, TokenDelta, calibrate, init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

LM_CFG = ModelConfig(name="smoke-lm", family="dense", num_layers=2,
                     d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                     vocab_size=96, head_dim=16)


def check_event_invariants(log, expect_cancelled=(), expect_finished=(),
                           expect_rejected=()):
    """The per-rid lifecycle invariants, asserted from a raw log."""
    by_rid: dict[int, list] = {}
    for e in log:
        by_rid.setdefault(e.rid, []).append(e)
    for rid, evs in by_rid.items():
        admits = [e for e in evs if isinstance(e, Admitted)]
        terms = [e for e in evs
                 if isinstance(e, (Finished, Cancelled, Rejected))]
        assert len(admits) <= 1, f"rid {rid}: {len(admits)} Admitted"
        assert len(terms) == 1, f"rid {rid}: {len(terms)} terminal events"
        assert evs[-1] is terms[0], f"rid {rid}: events after terminal"
        if admits:
            assert evs[0] is admits[0], f"rid {rid}: pre-admission events"
        if admits and isinstance(terms[0], Rejected):
            # The one admitted-then-rejected path: a preempted
            # over-budget decode past feasibility at its next pop.
            assert any(isinstance(e, Preempted) for e in evs), \
                f"rid {rid}: Rejected after admission without Preempted"
        poss = [e.pos for e in evs if isinstance(e, TokenDelta)]
        assert poss == sorted(set(poss)), \
            f"rid {rid}: TokenDelta positions not strictly increasing"
    for rid in expect_cancelled:
        assert isinstance(by_rid[rid][-1], Cancelled), f"rid {rid}"
    for rid in expect_finished:
        assert isinstance(by_rid[rid][-1], Finished), f"rid {rid}"
    for rid in expect_rejected:
        assert isinstance(by_rid[rid][-1], Rejected), f"rid {rid}"
    return by_rid


def smoke_mixed_stream() -> list[str]:
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (TINY_SD.text_len,),
                              0, TINY_SD.clip_cfg().vocab_size)
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)

    diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    lm = ContinuousBatcher(lm_params, LM_CFG, slots=2, max_len=16)
    router = EngineRouter(diffusion=diff, lm=lm)
    baseline_blocks = lm.runtime.allocated_blocks

    router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                  steps=4, seed=0, preview_every=2))
    router.submit(Request(rid=1, prompt=[3, 1, 4, 1, 5], max_new=6))
    victim = router.submit(Request(rid=2, prompt=[2, 7, 1, 8], max_new=8))

    log, cancelled = [], False
    for e in router.stream():
        log.append(e)
        # Cancel rid 2 mid-decode: after its second token arrives.
        if not cancelled and isinstance(e, TokenDelta) and e.rid == 2 \
                and e.pos >= 1:
            assert victim.cancel()
            cancelled = True
    assert cancelled, "victim never produced 2 tokens"

    by_rid = check_event_invariants(log, expect_cancelled=(2,),
                                    expect_finished=(0, 1))
    assert any(isinstance(e, PreviewLatent) for e in by_rid[0]), \
        "diffusion request streamed no previews"
    # Interleave: some LM event must land between two diffusion events.
    kinds = [e.rid for e in log]
    first0, last0 = kinds.index(0), len(kinds) - 1 - kinds[::-1].index(0)
    assert any(r != 0 for r in kinds[first0:last0]), \
        "stream did not interleave diffusion and LM events"
    # Cancelled blocks are back in the pool.
    lm.runtime.check_consistency()
    assert lm.runtime.allocated_blocks == baseline_blocks, \
        f"leak: {lm.runtime.allocated_blocks} blocks still allocated"
    rows = [f"streaming_smoke/stream,{len(log)} events over 3 rids,"
            f"invariants hold, cancel released all blocks"]
    print(rows[0])
    return rows


def smoke_edf_beats_fifo() -> list[str]:
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    # Deadlines tighten in submission order, so FIFO head-of-line
    # blocks the tight ones; slots=1 makes the reorder decisive.
    deadlines = [2000.0, 1000.0, 300.0, 150.0]

    def hit_rate(edf: bool) -> float:
        box: dict = {}

        def vclock() -> float:   # 1 scheduling quantum == 10 virtual ms
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = ContinuousBatcher(lm_params, LM_CFG, slots=1, max_len=16,
                               edf=edf, clock=vclock,
                               fused_prefill=False)
        box["cb"] = cb
        for rid, dl in enumerate(deadlines):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=dl))
        fins = {e.rid: e.ts for e in cb.stream()
                if isinstance(e, Finished)}
        assert len(fins) == len(deadlines)
        return sum(fins[r] <= deadlines[r] / 1e3
                   for r in fins) / len(fins)

    edf, fifo = hit_rate(True), hit_rate(False)
    rows = [f"streaming_smoke/slo,edf hit-rate {edf:.0%},"
            f"fifo hit-rate {fifo:.0%}"]
    print(rows[0])
    assert edf > fifo, (
        f"EDF admission must strictly beat FIFO on deadline hit-rate "
        f"(edf={edf:.0%}, fifo={fifo:.0%})")
    return rows


def smoke_admission_feasibility() -> list[str]:
    """Gating: cost-model admission beats plain EDF beats FIFO on a
    mixed-deadline virtual-clock workload, and no infeasible request
    is ever admitted to a slot."""
    lm_params = init_lm(jax.random.PRNGKey(2), LM_CFG)
    # Each request costs 4 quanta = 40 virtual ms (1 prefill chunk +
    # 3 decode quanta on slots=1).  rid 1 is infeasible from birth
    # (30 ms budget < 40 ms service); the rest are feasible but only
    # if nobody wastes quanta on rid 1.
    deadlines = [2000.0, 30.0, 110.0, 70.0, 500.0, 160.0]
    infeasible = {1}

    def hit_rate(edf: bool, with_model: bool):
        box: dict = {}

        def vclock() -> float:   # 1 scheduling quantum == 10 virtual ms
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cm = CostModel() if with_model else None
        cb = ContinuousBatcher(lm_params, LM_CFG, slots=1, max_len=16,
                               edf=edf, clock=vclock,
                               fused_prefill=False, cost_model=cm)
        box["cb"] = cb
        if with_model:
            # Calibration micro-run: two deadline-free samples seed the
            # per-phase EWMA (first-of-shape quanta skipped as compile).
            calibrate(cb, [Request(rid=100 + i, prompt=[1, 2, 3],
                                   max_new=4) for i in range(2)])
        t0 = {rid: cb.bus.clock() for rid in range(len(deadlines))}
        for rid, dl in enumerate(deadlines):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=dl))
        log = [e for e in cb.stream()]
        fins = {e.rid: e.ts for e in log if isinstance(e, Finished)}
        admitted = {e.rid for e in log if isinstance(e, Admitted)}
        rejected = {e.rid: e for e in log if isinstance(e, Rejected)}
        hits = sum(rid in fins
                   and fins[rid] - t0[rid] <= deadlines[rid] / 1e3
                   for rid in range(len(deadlines))) / len(deadlines)
        return hits, admitted, rejected, log

    fifo, _, _, _ = hit_rate(edf=False, with_model=False)
    edf, _, _, _ = hit_rate(edf=True, with_model=False)
    cost, admitted, rejected, log = hit_rate(edf=True, with_model=True)
    rows = [f"streaming_smoke/admission,cost-model hit-rate {cost:.0%},"
            f"edf {edf:.0%} fifo {fifo:.0%} "
            f"({len(rejected)} infeasible rejected)"]
    print(rows[0])
    assert cost >= edf > fifo, (
        f"admission-feasibility gate: expected cost-model >= EDF > "
        f"FIFO, got {cost:.0%} / {edf:.0%} / {fifo:.0%}")
    # Zero infeasible requests ever reach a slot.
    assert infeasible <= set(rejected), \
        f"infeasible {infeasible} not rejected (got {set(rejected)})"
    assert not (set(rejected) & admitted), \
        f"rejected rids admitted to a slot: {set(rejected) & admitted}"
    for rid in infeasible & set(rejected):
        ev = rejected[rid]
        assert ev.estimated_s > ev.budget_s > 0, \
            f"rid {rid}: bad Rejected detail {ev}"
    check_event_invariants(
        [e for e in log if e.rid < 100],
        expect_rejected=tuple(sorted(set(rejected))))

    # Diffusion engine: same feasibility rule from the seeded Fig.-11
    # phase composition (clip + steps x unet_step + vae).
    sd_params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = [1] * TINY_SD.text_len
    dcm = CostModel()
    dcm.seed(("diff", TINY_SD.name, "clip", False, 1, None), 0.010)
    dcm.seed(("diff", TINY_SD.name, "unet_step", "ddim", 8, False, 1,
              None),
             0.020)
    dcm.seed(("diff", TINY_SD.name, "vae", 8, 1, None), 0.010)
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                          cost_model=dcm)
    # 4 ddim steps pad to a pow2 scan of 4: 10 + 4x20 + 10 = 100 ms.
    tight = eng.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                       steps=4, seed=0, deadline_ms=50.0))
    loose = eng.submit(GenerateRequest(rid=1, tokens=toks, sampler="ddim",
                                       steps=4, seed=1,
                                       deadline_ms=5000.0))
    eng.run()
    assert tight.state == "REJECTED" and tight.result().outcome == "rejected"
    assert loose.state == "FINISHED" and loose.result().outcome == "finished"
    assert not eng.bus.admitted(0), "rejected diffusion request admitted"
    rows.append("streaming_smoke/admission_diffusion,"
                "est 100ms vs 50ms budget rejected,"
                "5000ms budget admitted+finished")
    print(rows[1])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append machine-readable rows to the suite's "
                         "perf-trajectory record (benchmarks/common.py "
                         "schema)")
    a = ap.parse_args()
    all_rows = (smoke_mixed_stream() + smoke_edf_beats_fifo()
                + smoke_admission_feasibility())
    if a.json:
        try:                      # package import (python -m ...)
            from benchmarks.common import write_bench_json
        except ImportError:       # script run: sys.path[0] is benchmarks/
            from common import write_bench_json
        write_bench_json(a.json, "serving", all_rows,
                         bench="streaming_smoke")