"""Table I reproduction: dot-product execution-time share by dtype.

Enumerates the U-Net denoising graph's dot products, assigns GGML
dtypes per offload policy, costs them on the calibrated ARM host model
(pure computation, no memcpy — matching the paper's methodology), and
compares the fractions against the paper's Table I.
"""
from __future__ import annotations

from repro.core.accounting import assign_formats, fractions, time_by_format
from repro.core.policy import get_policy

from benchmarks import common
from benchmarks.device_model import ARM_A72

TOL = 0.10  # absolute tolerance on each fraction


def run(verbose: bool = True) -> list[str]:
    rows = []
    sites = common.unet_sites()
    for model in ("q3_k", "q8_0"):
        assigned = assign_formats(sites, get_policy(model))
        fr = fractions(time_by_format(assigned, ARM_A72))
        total_t = sum(time_by_format(assigned, ARM_A72).values())
        for fmt, want in common.TABLE1[model].items():
            got = fr.get(fmt, 0.0)
            ok = abs(got - want) <= TOL
            rows.append(common.csv_row(
                f"table1/{model}/{fmt}", total_t * got * 1e6,
                f"frac={got:.3f} paper={want:.3f} "
                f"{'OK' if ok else 'DIVERGES'}"))
            if verbose:
                print(rows[-1])
            assert ok, (model, fmt, got, want)
    return rows


if __name__ == "__main__":
    run()
