"""End-to-end image generation driver (the paper's Fig. 5 workload).

Generates images with the SD-Turbo single-step sampler under a chosen
quantization policy, and reports per-stage latency and model bytes.
Offline weights are synthetic, so image *content* is noise-like; the
compute graph, quantized kernels, and byte traffic are the real ones.

Run:  PYTHONPATH=src python examples/generate_image.py \
          [--policy q3_k] [--steps 4] [--size tiny|sd15] [--batch 1]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes
from repro.diffusion.pipeline import (SD_TURBO, TINY_SD, generate,
                                      init_pipeline, quantize_pipeline)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="q8_0",
                    choices=["none", "q8_0", "q3_k", "q3_k_imax"])
    ap.add_argument("--steps", type=int, default=1)
    ap.add_argument("--size", default="tiny", choices=["tiny", "sd15"])
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt", default="a lovely cat")  # paper's prompt
    args = ap.parse_args()

    cfg = TINY_SD if args.size == "tiny" else SD_TURBO
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    params = init_pipeline(key, cfg)
    t1 = time.time()
    policy = get_policy(args.policy)
    qp = quantize_pipeline(params, policy)
    t2 = time.time()
    print(f"init {t1-t0:.1f}s | quantize({args.policy}) {t2-t1:.1f}s | "
          f"bytes {param_bytes(params)/1e6:.0f} -> {param_bytes(qp)/1e6:.0f} MB")

    # "Tokenize" the prompt deterministically (no tokenizer offline).
    vocab = cfg.clip_cfg().vocab_size
    toks = jnp.array([[hash((args.prompt, i)) % vocab
                       for i in range(cfg.text_len)]], jnp.int32)
    toks = jnp.tile(toks, (args.batch, 1))

    gen = jax.jit(lambda p, t, k: generate(p, cfg, t, k,
                                           steps=args.steps))
    t3 = time.time()
    img = jax.block_until_ready(gen(qp, toks, jax.random.PRNGKey(7)))
    t4 = time.time()
    img = jax.block_until_ready(gen(qp, toks, jax.random.PRNGKey(8)))
    t5 = time.time()
    print(f"E2E latency: compile+run {t4-t3:.2f}s, steady-state "
          f"{t5-t4:.2f}s for batch {args.batch} "
          f"({args.steps} step(s), {img.shape[1]}x{img.shape[2]})")
    assert bool(jnp.isfinite(img.astype(jnp.float32)).all()), "NaN image"
    print("image stats: mean %.4f std %.4f" % (
        float(img.mean()), float(img.std())))


if __name__ == "__main__":
    main()
