"""End-to-end image generation through the request-based engine API
(the paper's Fig. 5 workload, served instead of single-shot).

Submits a batch of ``GenerateRequest``s to a ``DiffusionEngine`` —
sampler picked by name from the registry, per-request seeds and
classifier-free-guidance scales — under a chosen quantization policy,
and reports latency, compile (trace) counts, and model bytes.
With ``--preview-every N`` the engine streams an x0-space
``PreviewLatent`` event every N denoise steps (the segmented program
path) and this host loop reports each preview as it lands.
Offline weights are synthetic, so image *content* is noise-like; the
compute graph, quantized kernels, and byte traffic are the real ones.

Run:  PYTHONPATH=src python examples/generate_image.py \
          [--policy q3_k] [--sampler ddim] [--steps 4] \
          [--size tiny|sd15] [--batch 2] [--guidance 7.5] \
          [--preview-every 1]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes
from repro.engine import (SD_TURBO, TINY_SD, DiffusionEngine,
                          GenerateRequest, PreviewLatent, default_sampler,
                          init_pipeline, list_samplers, quantize_pipeline)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="q8_0",
                    choices=["none", "q8_0", "q3_k", "q3_k_imax"])
    ap.add_argument("--sampler", default=None, choices=list_samplers(),
                    help="default: turbo for 1 step, ddim otherwise")
    ap.add_argument("--steps", type=int, default=1)
    ap.add_argument("--size", default="tiny", choices=["tiny", "sd15"])
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--guidance", type=float, default=1.0)
    ap.add_argument("--negative-prompt", default=None)
    ap.add_argument("--prompt", default="a lovely cat")  # paper's prompt
    ap.add_argument("--preview-every", type=int, default=0,
                    help="stream an x0 preview every N denoise steps")
    args = ap.parse_args()
    if args.steps < 1:
        ap.error("--steps must be >= 1")

    cfg = TINY_SD if args.size == "tiny" else SD_TURBO
    sampler = args.sampler or default_sampler(args.steps)
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    params = init_pipeline(key, cfg)
    t1 = time.time()
    policy = get_policy(args.policy)
    qp = quantize_pipeline(params, policy)
    t2 = time.time()
    print(f"init {t1-t0:.1f}s | quantize({args.policy}) {t2-t1:.1f}s | "
          f"bytes {param_bytes(params)/1e6:.0f} -> {param_bytes(qp)/1e6:.0f} MB")

    # "Tokenize" the prompt deterministically (no tokenizer offline).
    vocab = cfg.clip_cfg().vocab_size

    def tokenize(text):
        return jnp.array([hash((text, i)) % vocab
                          for i in range(cfg.text_len)], jnp.int32)

    toks = tokenize(args.prompt)
    neg = (tokenize(args.negative_prompt)
           if args.negative_prompt is not None else None)

    engine = DiffusionEngine(qp, cfg, max_batch=args.batch)
    for i in range(args.batch):
        engine.submit(GenerateRequest(
            rid=i, tokens=toks, neg_tokens=neg, sampler=sampler,
            steps=args.steps, seed=7 + i, guidance_scale=args.guidance,
            preview_every=args.preview_every))
    t3 = time.time()
    if args.preview_every:
        for e in engine.stream():       # previews land mid-denoise
            if isinstance(e, PreviewLatent):
                lat = e.latent.astype(jnp.float32)
                print(f"  rid={e.rid} preview {e.step}/{e.total}: "
                      f"x0 latent std {float(lat.std()):.4f}")
        results = list(engine.finished)
    else:
        results = engine.run()
    jax.block_until_ready(results[-1].image)
    t4 = time.time()
    # Steady state: same (sampler, steps, shape) key -> no retrace.
    for i in range(args.batch):
        engine.submit(GenerateRequest(
            rid=args.batch + i, tokens=toks, neg_tokens=neg,
            sampler=sampler, steps=args.steps, seed=100 + i,
            guidance_scale=args.guidance,
            preview_every=args.preview_every))
    engine.run()
    jax.block_until_ready(engine.finished[-1].image)
    t5 = time.time()

    img = results[0].image
    print(f"E2E latency [{sampler}]: compile+run {t4-t3:.2f}s, "
          f"steady-state {t5-t4:.2f}s for batch {args.batch} "
          f"({results[0].steps} step(s), {img.shape[0]}x{img.shape[1]}) | "
          f"jit traces: {engine.traces}")
    for r in results:
        im = r.image.astype(jnp.float32)
        assert bool(jnp.isfinite(im).all()), f"NaN image (rid={r.rid})"
        print(f"  rid={r.rid} seed={r.seed}: mean {float(im.mean()):.4f} "
              f"std {float(im.std()):.4f}")


if __name__ == "__main__":
    main()
