"""Quickstart: the paper's pipeline in five minutes (CPU-friendly).

1. Build a tiny Stable-Diffusion pipeline (CLIP + UNet + VAE).
2. Quantize it GGML-style with the paper's two policies (Q8_0 / Q3_K).
3. Generate an image with the SD-Turbo single-step sampler.
4. Show the dot-product dtype breakdown (the paper's Table I lens).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.core.accounting import MatmulOp, assign_formats, flops_by_format
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes
from repro.diffusion.pipeline import TINY_SD, generate, init_pipeline, \
    quantize_pipeline


def main():
    key = jax.random.PRNGKey(0)
    cfg = TINY_SD
    params = init_pipeline(key, cfg)
    print(f"[1] pipeline init: {param_bytes(params)/1e6:.1f} MB bf16")

    for policy_name in ("q8_0", "q4_0", "q3_k", "q3_k_imax"):
        policy = get_policy(policy_name)
        qp = quantize_pipeline(params, policy)
        print(f"[2] {policy_name:10s}: {param_bytes(qp)/1e6:.1f} MB "
              f"(scale_bits={policy.scale_bits})")

    qp = quantize_pipeline(params, get_policy("q8_0"))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 77), 0, 512)
    img = generate(qp, cfg, tokens, jax.random.PRNGKey(2))
    assert bool(jnp.isfinite(img.astype(jnp.float32)).all())
    print(f"[3] generated image {img.shape}, range "
          f"[{float(img.min()):.2f}, {float(img.max()):.2f}]")

    sites: list[MatmulOp] = []
    qlinear.set_recorder(lambda **kw: sites.append(MatmulOp(**kw)))
    jax.eval_shape(lambda p, t, k: generate(p, cfg, t, k),
                   jax.eval_shape(lambda k: init_pipeline(k, cfg), key),
                   jax.ShapeDtypeStruct((1, 77), jnp.int32), key)
    qlinear.set_recorder(None)
    fl = flops_by_format(assign_formats(sites, get_policy("q8_0")))
    tot = sum(fl.values())
    print("[4] dot-product FLOP share by dtype (Table I lens):")
    for fmt, v in sorted(fl.items(), key=lambda kv: -kv[1]):
        print(f"    {fmt:6s} {100*v/tot:5.1f}%")


if __name__ == "__main__":
    main()
