"""Serve a (reduced) assigned architecture through the engine API.

Demonstrates the quantized-offload serving path the paper targets:
weights quantized per policy, then requests submitted to the
``ContinuousBatcher`` — the LM engine behind the same
``submit()``/``stream()``/``run()`` protocol as ``DiffusionEngine``.
Finished requests free their slot mid-flight (their cache blocks
return to the paged pool) and queued ones are admitted with chunked
prefill, so the jitted decode step always runs at the fixed batch
shape (KV/SSM cache machinery: paged block tables, per-slot positions,
recurrent states, cross-KV).

The host loop here consumes the *event stream* instead of draining
``run()``: every ``submit()`` returns a ``RequestHandle``, the engine
emits ``Admitted``/``TokenDelta``/``Finished`` events, and tokens
print as they are generated (the old ``done = engine.run()`` one-liner
still works — see ``src/repro/engine/README.md`` for the migration
map).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-1.3b \
          [--policy q3_k] [--slots 4] [--requests 8] [--gen 32]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced, smoke_inputs
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes, quantize_params
from repro.engine import Finished, TokenDelta
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request
from repro.train.serve_step import make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--policy", default="q8_0",
                    choices=["none", "q8_0", "q3_k", "q3_k_imax"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quantized-kv", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    qp = quantize_params(params, get_policy(args.policy))
    print(f"{cfg.name}: {param_bytes(params)/1e6:.1f} MB -> "
          f"{param_bytes(qp)/1e6:.1f} MB ({args.policy})")

    inp = smoke_inputs(key, cfg, batch=args.slots, seq=args.prompt_len)
    max_len = ContinuousBatcher.required_len(args.requests, args.slots,
                                             args.prompt_len, args.gen)
    engine = ContinuousBatcher(qp, cfg, slots=args.slots, max_len=max_len,
                               enc_embeds=inp.get("enc_embeds"),
                               quantized_kv=args.quantized_kv)
    prompts = np.asarray(inp["tokens"])
    for r in range(args.requests):
        engine.submit(Request(rid=r,
                              prompt=prompts[r % args.slots].tolist(),
                              max_new=args.gen))

    t0 = time.time()
    done, shown = [], set()
    for e in engine.stream():
        if isinstance(e, TokenDelta) and e.rid not in shown:
            shown.add(e.rid)            # stream: first token per request
            print(f"  rid={e.rid} first token {e.token} "
                  f"(pos {e.pos}, t+{time.time() - t0:.2f}s)")
        elif isinstance(e, Finished):
            done.append(e.result)
    dt = time.time() - t0
    n_tok = sum(len(d.prompt) + len(d.out) for d in done)
    print(f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile) on {args.slots} slots")
    print(f"quanta: {engine.prefill_quanta} prefill + "
          f"{engine.decode_quanta} decode "
          f"({engine.runtime.allocated_blocks} cache blocks live)")
    print("first request out:", done[0].out[:12])
    # Last-position prefill logits must agree with the decode path.
    pl = jax.jit(make_prefill(cfg))(qp, inp)
    print("prefill/decode consistency check: logits shape", pl.shape)


if __name__ == "__main__":
    main()
