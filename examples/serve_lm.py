"""Serve a (reduced) assigned architecture with batched requests.

Demonstrates the quantized-offload serving path the paper targets:
weights quantized per policy, prefill + batched greedy decode with the
KV/SSM cache machinery (ring-buffer SWA, recurrent states, cross-KV).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch xlstm-1.3b \
          [--policy q3_k] [--batch 4] [--gen 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced, smoke_inputs
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes, quantize_params
from repro.models.transformer import init_lm
from repro.train.serve_step import make_cache, make_decode, make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--policy", default="q8_0",
                    choices=["none", "q8_0", "q3_k", "q3_k_imax"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quantized-kv", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    qp = quantize_params(params, get_policy(args.policy))
    print(f"{cfg.name}: {param_bytes(params)/1e6:.1f} MB -> "
          f"{param_bytes(qp)/1e6:.1f} MB ({args.policy})")

    inp = smoke_inputs(key, cfg, batch=args.batch, seq=args.prompt_len)
    enc = inp.get("enc_embeds")
    max_len = args.prompt_len + args.gen
    cache = make_cache(qp, cfg, args.batch, max_len,
                       quantized_kv=args.quantized_kv, enc_embeds=enc)
    decode = jax.jit(make_decode(cfg), donate_argnums=(3,))
    prefill = jax.jit(make_prefill(cfg))

    # Prefill (teacher-forced through decode to fill the cache) + decode.
    t0 = time.time()
    tok = inp["tokens"][:, :1]
    out = [tok]
    for t in range(max_len - 1):
        nxt, logits, cache = decode(qp, tok, jnp.int32(t), cache)
        tok = (inp["tokens"][:, t + 1:t + 2]
               if t + 1 < args.prompt_len else nxt)
        out.append(tok)
    seq = jax.block_until_ready(jnp.concatenate(out, axis=1))
    dt = time.time() - t0
    print(f"generated {seq.shape} in {dt:.2f}s "
          f"({args.batch * max_len / dt:.1f} tok/s incl. compile)")
    print("sample token ids:", seq[0, args.prompt_len:
                                   args.prompt_len + 12].tolist())
    # Last-position prefill logits must agree with the decode path.
    pl = prefill(qp, inp)
    print("prefill/decode consistency check: logits shape", pl.shape)


if __name__ == "__main__":
    main()
