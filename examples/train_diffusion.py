"""Train the (tiny) SD U-Net with eps-prediction on synthetic latents.

The paper's framework is inference-only; this driver completes the
substrate (deliverable: build the training side too): DDPM
eps-prediction loss over the full noise schedule, AdamW, checkpointing.

  PYTHONPATH=src python examples/train_diffusion.py --steps 30
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.data.pipeline import latent_batch
from repro.diffusion.schedule import NoiseSchedule
from repro.models.clip import TINY_CLIP, clip_encode, init_clip
from repro.models.unet import TINY_UNET, apply_unet, init_unet
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-4)
    args = ap.parse_args()

    ucfg, ccfg = TINY_UNET, TINY_CLIP
    key = jax.random.PRNGKey(0)
    params = init_unet(key, ucfg)
    clip_params = init_clip(jax.random.fold_in(key, 1), ccfg)
    sched = NoiseSchedule()
    ac = sched.alphas_cumprod()
    tcfg = TrainConfig(lr=args.lr, weight_decay=0.01)
    opt = adamw.init_adam(params, tcfg)

    def loss_fn(p, x0, t, noise, ctx):
        a = ac[t][:, None, None, None]
        xt = jnp.sqrt(a) * x0 + jnp.sqrt(1 - a) * noise
        eps = apply_unet(p, ucfg, xt.astype(jnp.bfloat16), t, ctx)
        return jnp.mean((eps.astype(jnp.float32) - noise) ** 2)

    @jax.jit
    def train_step(p, opt, x0, t, noise, ctx):
        loss, g = jax.value_and_grad(loss_fn)(p, x0, t, noise, ctx)
        p, opt = adamw.adam_update(g, opt, p, tcfg)
        return p, opt, loss

    toks = jax.random.randint(jax.random.PRNGKey(2),
                              (args.batch, 77), 0, ccfg.vocab_size)
    ctx = clip_encode(clip_params, ccfg, toks)
    losses = []
    for i in range(args.steps):
        x0 = jnp.asarray(latent_batch(i, batch=args.batch, h=8, w=8))
        k = jax.random.fold_in(key, 100 + i)
        t = jax.random.randint(k, (args.batch,), 0, 1000)
        noise = jax.random.normal(jax.random.fold_in(k, 1), x0.shape)
        params, opt, loss = train_step(params, opt, x0, t, noise, ctx)
        losses.append(float(loss))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d} eps-loss {losses[-1]:.4f}")
    a, b = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {a:.3f} -> {b:.3f} ({'improved' if b < a else 'flat'})")


if __name__ == "__main__":
    main()
