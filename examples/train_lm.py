"""End-to-end LM training driver with checkpoint-restart.

Trains a reduced config of any assigned architecture on the synthetic
pipeline, with the full production train step (remat, optional
microbatching, quantized Adam moments, gradient compression) and
atomic checkpointing + auto-resume.

A ~100M-parameter run for a few hundred steps:
  PYTHONPATH=src python examples/train_lm.py --arch granite-8b \
      --d-model 768 --layers 12 --steps 300
CI-speed smoke:
  PYTHONPATH=src python examples/train_lm.py --arch granite-8b --steps 5
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.core.qlinear import param_count
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault_tolerance import StepTimer, Watchdog
from repro.models.transformer import init_lm
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--quantized-moments", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    over = {}
    if args.d_model:
        hd = args.d_model // cfg.num_heads
        over.update(d_model=args.d_model, head_dim=hd,
                    d_ff=4 * args.d_model if cfg.d_ff else 0)
    if args.layers:
        plen = len(tuple(cfg.block_pattern))
        over.update(num_layers=max(plen, args.layers // plen * plen))
    if over:
        cfg = dataclasses.replace(cfg, **over)
    tcfg = TrainConfig(lr=args.lr, microbatch=args.microbatch,
                       quantized_moments=args.quantized_moments,
                       grad_compression=args.grad_compression,
                       steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir)

    key = jax.random.PRNGKey(tcfg.seed)
    params, opt, comp = init_train_state(key, cfg, tcfg, init_lm)
    print(f"arch={cfg.name} params={param_count(params)/1e6:.1f}M "
          f"layers={cfg.num_layers} d={cfg.d_model}")

    start = 0
    if args.resume == "auto":
        last = ckpt.latest_step(tcfg.ckpt_dir)
        if last is not None:
            restored, man = ckpt.restore(tcfg.ckpt_dir, last,
                                         {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            start = man["step"]
            print(f"resumed from step {start}")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch=args.batch, seed=tcfg.seed,
                         start_step=start)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    watchdog = Watchdog(on_straggler=lambda s, t, e: print(
        f"  [watchdog] step {s} took {t:.2f}s (ewma {e:.2f}s)"))
    timer = StepTimer(watchdog)

    losses = []
    for i in range(start, args.steps):
        batch = next(pipe)
        mb = {k: jnp.asarray(v) for k, v in batch.items()}
        with timer:
            params, opt, comp, metrics = step_fn(params, opt, comp, mb)
        losses.append(float(metrics["loss"]))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if (i + 1) % tcfg.ckpt_every == 0 or i == args.steps - 1:
            d = ckpt.save(tcfg.ckpt_dir, i + 1,
                          {"params": params, "opt": opt},
                          meta={"seed": tcfg.seed, **pipe.state()})
            ckpt.gc_old(tcfg.ckpt_dir)
            print(f"  checkpoint -> {d}")
    pipe.close()
    if len(losses) > 10:
        a, b = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss {a:.3f} -> {b:.3f} ({'improved' if b < a else 'NO'})")


if __name__ == "__main__":
    main()
