"""Generate EXPERIMENTS.md tables from the dry-run JSON artifacts."""
import glob
import json
import sys


def load(dirname, mesh="16x16"):
    rows = []
    for f in sorted(glob.glob(f"{dirname}/*_{mesh}.json")):
        if mesh == "16x16" and "2x16x16" in f:
            continue
        rows.append(json.load(open(f)))
    return rows


def roofline_table(rows):
    out = ["| arch | shape | bound | compute s | memory s | collective s "
           "| frac | useful | arg+temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    rows = sorted(rows, key=lambda d: (d["shape"], -d["roofline_fraction"]))
    for d in rows:
        mem = (d["memory_analysis"]["argument_bytes"]
               + d["memory_analysis"]["temp_bytes"]) / 1e9
        out.append(
            f"| {d['arch']} | {d['shape']} | **{d['bound']}** "
            f"| {d['compute_s']:.3e} | {d['memory_s']:.3e} "
            f"| {d['collective_s']:.3e} | {d['roofline_fraction']:.3f} "
            f"| {d['useful_ratio']:.2f} | {mem:.1f} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | mesh | chips | compile | GFLOP/chip "
           "| GB/chip | wire GB/chip | coll ops | arg GB | temp GB |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        ma = d["memory_analysis"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['chips']} "
            f"| ok ({d['compile_s']}s) | {d['flops_per_chip']/1e9:.0f} "
            f"| {d['bytes_per_chip']/1e9:.1f} "
            f"| {d['wire_bytes_per_chip']/1e9:.2f} | {d['collective_ops']} "
            f"| {ma['argument_bytes']/1e9:.2f} | {ma['temp_bytes']/1e9:.2f} |")
    return "\n".join(out)


def compare_table(base_rows, opt_rows):
    base = {(d["arch"], d["shape"]): d for d in base_rows}
    opt = {(d["arch"], d["shape"]): d for d in opt_rows}
    out = ["| arch | shape | bound (b->o) | dominant term s (b->o) | gain "
           "| frac (b->o) |",
           "|---|---|---|---|---|---|"]
    for key in sorted(opt, key=lambda k: (k[1], k[0])):
        b, o = base.get(key), opt[key]
        if b is None:
            continue
        bb = max(b["compute_s"], b["memory_s"], b["collective_s"])
        oo = max(o["compute_s"], o["memory_s"], o["collective_s"])
        out.append(
            f"| {key[0]} | {key[1]} | {b['bound']}->{o['bound']} "
            f"| {bb:.3e} -> {oo:.3e} | **{bb/oo:.1f}x** "
            f"| {b['roofline_fraction']:.3f} -> "
            f"{o['roofline_fraction']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        print(roofline_table(load("experiments/dryrun_opt")))
    elif which == "roofline_base":
        print(roofline_table(load("experiments/dryrun")))
    elif which == "dryrun":
        rows = (load("experiments/dryrun_opt", "16x16")
                + load("experiments/dryrun_opt", "2x16x16"))
        print(dryrun_table(rows))
    elif which == "compare":
        print(compare_table(load("experiments/dryrun"),
                            load("experiments/dryrun_opt")))
