"""Atomic, resumable checkpointing.

Fault-tolerance contract (1000+-node posture):

* **Atomicity** — a checkpoint is written to ``step_XXXX.tmp/`` and
  renamed only after every array and the metadata manifest are fsynced;
  a crash mid-write can never corrupt the latest valid checkpoint.
* **Provenance** — the manifest records step, RNG seed, data-pipeline
  cursor, and config digest; restore rebuilds the exact training state
  (the data pipeline is deterministic in (seed, step), so restart
  replays no sample twice and skips none).
* **Auto-resume** — ``latest_step()`` + ``restore()`` let the launcher
  resume after preemption without operator input (``train.py --resume
  auto``).
* **Multi-host** — in a real multi-controller deployment each host
  writes only the shards it owns (orbax/ocdbt layout); this
  single-process implementation keeps the same directory layout with
  one writer and documents the extension point.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np

from repro.core.quant import Q3KTensor, Q4_0Tensor, Q8_0Tensor


def _enc(a) -> tuple[np.ndarray, str]:
    """npz-safe encoding: (array, suffix). bfloat16 -> uint16 view."""
    a = np.asarray(a)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16), "~bf16"
    return a, ""


def _dec(key: str, a: np.ndarray) -> np.ndarray:
    if key.endswith("~bf16"):
        import ml_dtypes
        return a.view(ml_dtypes.bfloat16)
    return a


def _leaf_arrays(i: int, leaf) -> dict[str, np.ndarray]:
    if isinstance(leaf, Q8_0Tensor):
        parts = {"q8.qs": leaf.qs, "q8.d": leaf.d}
    elif isinstance(leaf, Q4_0Tensor):
        parts = {"q4.qs": leaf.qs, "q4.d": leaf.d}
    elif isinstance(leaf, Q3KTensor):
        parts = {"q3k.ql": leaf.ql, "q3k.qh": leaf.qh,
                 "q3k.scales": leaf.scales, "q3k.d": leaf.d,
                 "q3k.sb": np.asarray(leaf.scale_bits)}
    else:
        parts = {"a": leaf}
    out = {}
    for name, arr in parts.items():
        enc, suffix = _enc(arr)
        out[f"{i}.{name}{suffix}"] = enc
    return out


def _find(data, i: int, name: str) -> np.ndarray:
    for suffix in ("", "~bf16"):
        key = f"{i}.{name}{suffix}"
        if key in data:
            return _dec(key, data[key])
    raise KeyError(f"{i}.{name}")


_IS_QLEAF = lambda x: isinstance(x, (Q8_0Tensor, Q4_0Tensor, Q3KTensor))


def save(path: str, step: int, trees: dict[str, Any],
         meta: dict | None = None) -> str:
    """Save named pytrees atomically. Returns the final directory."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    for name, tree in trees.items():
        leaves = jax.tree.flatten(tree, is_leaf=_IS_QLEAF)[0]
        arrs: dict[str, np.ndarray] = {}
        for i, leaf in enumerate(leaves):
            arrs.update(_leaf_arrays(i, leaf))
        with open(os.path.join(tmp, f"{name}.npz"), "wb") as f:
            np.savez(f, **arrs)
            f.flush()
            os.fsync(f.fileno())
    manifest = {"step": step, **(meta or {})}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, step: int, templates: dict[str, Any]
            ) -> tuple[dict[str, Any], dict]:
    """Restore named pytrees using same-structure templates."""
    final = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for name, template in templates.items():
        data = np.load(os.path.join(final, f"{name}.npz"))
        leaves, treedef = jax.tree.flatten(template, is_leaf=_IS_QLEAF)
        new_leaves = []
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, Q8_0Tensor):
                new_leaves.append(Q8_0Tensor(
                    qs=_find(data, i, "q8.qs"), d=_find(data, i, "q8.d")))
            elif isinstance(leaf, Q4_0Tensor):
                new_leaves.append(Q4_0Tensor(
                    qs=_find(data, i, "q4.qs"), d=_find(data, i, "q4.d")))
            elif isinstance(leaf, Q3KTensor):
                new_leaves.append(Q3KTensor(
                    ql=_find(data, i, "q3k.ql"),
                    qh=_find(data, i, "q3k.qh"),
                    scales=_find(data, i, "q3k.scales"),
                    d=_find(data, i, "q3k.d"),
                    scale_bits=int(_find(data, i, "q3k.sb"))))
            else:
                new_leaves.append(_find(data, i, "a"))
        out[name] = jax.tree.unflatten(treedef, new_leaves)
    return out, manifest


def gc_old(path: str, keep: int = 3) -> None:
    """Keep the newest `keep` checkpoints (bounded disk on long runs)."""
    if not os.path.isdir(path):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"),
                      ignore_errors=True)
