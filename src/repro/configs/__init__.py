"""Architecture registry + per-(arch, shape) input specifications."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, ShapeConfig, TrainConfig, SHAPES, reduced)
from repro.models import frontend

ARCH_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "whisper-large-v3": "whisper_large_v3",
    "llama3-405b": "llama3_405b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "granite-8b": "granite_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCHS = tuple(ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.config


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch, shape) cell runnable?  long_500k needs a
    sub-quadratic path (DESIGN.md section Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention; no sub-quadratic path "
                       "at 524288 tokens")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    * train  -> {tokens, labels [, enc_embeds, prefix_embeds]}
    * prefill-> {tokens [, enc_embeds, prefix_embeds]}
    * decode -> {token} (the cache is built separately via
      jax.eval_shape(init_cache, ...) — see launch/dryrun.py).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"token": sds((b, 1), i32)}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            specs["enc_embeds"] = sds(
                frontend.audio_frontend_shape(cfg, b), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["prefix_embeds"] = sds(
                frontend.vision_frontend_shape(cfg, b), jnp.bfloat16)
    return specs


def smoke_inputs(key: jax.Array, cfg: ModelConfig, *, batch: int = 2,
                 seq: int = 16) -> dict:
    """Concrete small inputs matching input_specs' structure."""
    ks = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                        cfg.vocab_size),
           "labels": jax.random.randint(ks[1], (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.family == "audio":
        out["enc_embeds"] = frontend.synthetic_frontend(
            ks[2], (batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        out["prefix_embeds"] = frontend.synthetic_frontend(
            ks[2], (batch, 8, cfg.d_model))
    return out
