"""Config dataclasses for models, shapes, and runs."""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    expert_ff: int = 0           # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # Block pattern, repeated over the layer stack.  Kinds:
    # "attn", "mamba", "mlstm", "slstm".
    block_pattern: Sequence[str] = ("attn",)
    moe: MoEConfig | None = None
    moe_every: int = 1           # MoE FFN on layers where (idx % moe_every==0)
    sliding_window: int | None = None
    qkv_bias: bool = False
    # Encoder-decoder (whisper): encoder layer count + fixed encoder length.
    encoder_layers: int = 0
    encoder_seq: int = 1500
    rope_theta: float = 10_000.0
    mrope: bool = False          # qwen2-vl M-RoPE (t/h/w sections)
    mrope_sections: Sequence[int] = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    pos_embed: str = "rope"      # "rope" | "sinusoidal" | "none"
    activation: str = "silu"     # "silu" (swiglu) | "gelu"
    # Mamba / xLSTM internals.
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # Default offload policy name for serving.
    default_policy: str = "q8_0"
    # Cost-probe plumbing (see launch/dryrun.py): XLA's cost_analysis
    # counts while-loop bodies ONCE, so roofline probes lower small
    # fully-unrolled variants and extrapolate linearly.
    scan_unroll: bool = False
    mamba_chunk: int = 0         # 0 -> models.ssm.MAMBA_CHUNK
    # Source annotation ([arXiv/hf ref; verification tier]).
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return "attn" not in tuple(self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: recurrent, hybrid, or windowed attention."""
        kinds = set(self.block_pattern)
        if kinds <= {"mamba", "mlstm", "slstm"}:
            return True
        if self.sliding_window is not None:
            return True
        return "attn" in kinds and kinds != {"attn"}  # hybrid

    def pattern_for_layers(self) -> list[str]:
        pat = list(self.block_pattern)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


# The four assigned LM shapes (identical across all 10 architectures).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0          # 0 -> no accumulation
    remat: str = "block"         # "none" | "block" | "full"
    quantized_moments: bool = False  # Q8_0 Adam moments (beyond-paper)
    grad_compression: bool = False   # int8 error-feedback cross-pod reduce
    scan_unroll: bool = False        # unroll microbatch loop (cost probes)
    seed: int = 0
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = tuple(cfg.block_pattern)
    small = dict(
        num_layers=max(2, len(pat)),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=64 if cfg.encoder_layers else cfg.encoder_seq,
        sliding_window=32 if cfg.sliding_window else None,
        ssm_state=8,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(num_experts=4, top_k=2,
                                 num_shared=min(1, cfg.moe.num_shared),
                                 expert_ff=128,
                                 capacity_factor=cfg.moe.capacity_factor)
    if cfg.mrope:
        # Scale the M-RoPE sections to the reduced head_dim (sum = hd/2).
        half = small["head_dim"] // 2
        t = half // 4
        small["mrope_sections"] = (half - 2 * (half - t) // 2,
                                   (half - t) // 2, (half - t) // 2)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
