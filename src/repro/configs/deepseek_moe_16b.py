"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed, top-6.

[arXiv:2401.06066; hf]  28L d_model=2048 16H (kv=16) expert_ff=1408
vocab=102400.
"""
from repro.configs.base import ModelConfig, MoEConfig

config = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, expert_ff=1408),
    default_policy="q8_0",
    source="[arXiv:2401.06066; hf]",
)
