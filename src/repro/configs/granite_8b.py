"""Granite-8B (code) — llama-arch dense GQA.

[arXiv:2405.04324; hf]  36L d_model=4096 32H (kv=8) d_ff=14336
vocab=49152.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=49152, head_dim=128,
    default_policy="q8_0",
    source="[arXiv:2405.04324; hf]",
)
