"""H2O-Danube3-4B — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (kv=8)
d_ff=10240 vocab=32000.  SWA window 4096 (mistral-style) — the bounded
KV ring buffer is what makes long_500k feasible for this arch.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    sliding_window=4096,
    default_policy="q8_0",
    source="[arXiv:2401.16818; unverified]",
)
