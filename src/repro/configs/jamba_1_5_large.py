"""Jamba-1.5-Large (398B) — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (kv=8) d_ff=24576
vocab=65536.  Attention in 1 of every 8 layers; MoE FFN every 2nd
layer.  Hybrid recurrence keeps long_500k sub-quadratic (KV cache only
for the 9 attention layers, sequence-sharded).
"""
from repro.configs.base import ModelConfig, MoEConfig

config = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    block_pattern=("attn",) + ("mamba",) * 7,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, expert_ff=24576),
    moe_every=2,
    default_policy="q3_k",
    source="[arXiv:2403.19887; hf]",
)
