"""Moonlight-16B-A3B (kimi/moonshot) — MoE 64e top-6, 2 shared.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (kv=16)
expert_ff=1408 vocab=163840.
"""
from repro.configs.base import ModelConfig, MoEConfig

config = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, expert_ff=1408),
    default_policy="q8_0",
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)
