"""Qwen1.5-110B — dense GQA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  80L d_model=8192 64H (kv=8) d_ff=49152
vocab=152064.  QKV biases stay fp32-adjacent (GGML keeps bias adds on
the host path too).
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064, head_dim=128,
    qkv_bias=True,
    default_policy="q3_k",
    source="[hf:Qwen/Qwen1.5-0.5B; hf]",
)
