"""Qwen2-VL-72B backbone — M-RoPE, dynamic resolution (frontend STUB).

[arXiv:2409.12191; hf]  80L d_model=8192 64H (kv=8) d_ff=29568
vocab=152064.  M-RoPE sections (16, 24, 24) over head_dim/2 = 64;
vision patch embeddings arrive precomputed via input_specs.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    mrope=True, mrope_sections=(16, 24, 24),
    default_policy="q8_0",
    source="[arXiv:2409.12191; hf]",
)
