"""Whisper large-v3 backbone — encoder-decoder, conv frontend STUB.

[arXiv:2212.04356; unverified]  32L d_model=1280 20H d_ff=5120
vocab=51866.  input_specs supplies precomputed 1500-frame embeddings
(the conv1d+GELU frontend is a stub per the assignment).
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    encoder_layers=32, encoder_seq=1500,
    norm="layernorm", activation="gelu", pos_embed="sinusoidal",
    default_policy="q8_0",
    source="[arXiv:2212.04356; unverified]",
)
