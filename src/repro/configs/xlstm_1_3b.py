"""xLSTM-1.3B — sLSTM + mLSTM blocks at 7:1 (mLSTM:sLSTM).

[arXiv:2405.04517; unverified]  48L d_model=2048 4H d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own projections; no separate FFN.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    default_policy="q8_0",
    source="[arXiv:2405.04517; unverified]",
)
