"""Core of the paper's contribution: GGML-format quantized execution.

- :mod:`repro.core.quant` — Q8_0 / Q3_K / Q8_K block formats.
- :mod:`repro.core.qlinear` — role-tagged linear layers + PTQ.
- :mod:`repro.core.policy` — offload policies (which tensors quantize).
- :mod:`repro.core.accounting` — per-dtype dot-product accounting
  (Table I reproduction).
"""
from repro.core.quant import (  # noqa: F401
    Q8_0Tensor, Q3KTensor, Q8KTensor,
    quantize_q8_0, dequantize_q8_0, quantize_q3_k, dequantize_q3_k,
    quantize_q8_k, dequantize_q8_k, quantize, dequantize, BPW,
)
from repro.core.policy import (  # noqa: F401
    OffloadPolicy, get_policy, NONE_POLICY, Q8_0_POLICY, Q3_K_POLICY,
    Q3_K_IMAX_POLICY,
)
from repro.core.qlinear import (  # noqa: F401
    Linear, init_linear, apply_linear, quantize_params, param_bytes,
    param_count,
)
