"""Analytic per-dtype dot-product accounting (reproduces Table I).

The paper profiles stable-diffusion.cpp and splits dot-product execution
time by data type (F32 / F16 / Q3_K / Q8_0).  We reproduce this by
enumerating every matmul in a model graph with its role, applying an
:class:`~repro.core.policy.OffloadPolicy` to assign formats (exactly as
GGML model files do), and costing each op on a device model.

Models expose ``enumerate_matmuls(cfg, batch, seq) -> [MatmulOp]``; the
benchmark harness sums time per format.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable

from repro.core.policy import OffloadPolicy
from repro.core.quant import BPW


@dataclasses.dataclass(frozen=True)
class MatmulOp:
    """One dot-product site: y[m,n] += x[m,k] * w[n,k], executed `count` times."""
    name: str
    role: str          # policy role, or "activation" for act-act matmuls
    m: int
    n: int
    k: int
    count: int = 1
    # activation-activation matmuls (attention score/PV) have no weight
    # tensor; GGML runs them in F16 — they are never offloaded.
    act_act: bool = False

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k * self.count

    def weight_bytes(self, fmt: str) -> float:
        if self.act_act:
            return 0.0
        return self.n * self.k * BPW[fmt] / 8.0 * self.count

    def act_bytes(self, act_bits: int = 16) -> float:
        return (self.m * self.k + self.m * self.n) * act_bits / 8.0 * self.count


def assign_formats(ops: Iterable[MatmulOp], policy: OffloadPolicy,
                   act_fmt: str = "f32") -> list[tuple[MatmulOp, str]]:
    """GGML-style dtype assignment.

    Activation-activation matmuls -> F32 (GGML act-act mul_mat).
    Weight matmuls take the
    policy format; K-dims not divisible by the block size fall back to
    F16, and f32-pinned roles go to F32 — this is what produces the
    paper's F32/F16 residue share.
    """
    out = []
    for op in ops:
        if op.act_act:
            out.append((op, act_fmt))
            continue
        fmt = policy.format_for(op.role)
        block = {"q3_k": 256, "q8_0": 32, "q4_0": 32}.get(fmt, 1)
        if op.k % block:
            fmt = "f16" if fmt.startswith("q") else fmt
        out.append((op, fmt))
    return out


def time_by_format(assigned: list[tuple[MatmulOp, str]],
                   device) -> dict[str, float]:
    """Sum modeled execution seconds per format on a device model."""
    acc: dict[str, float] = defaultdict(float)
    for op, fmt in assigned:
        acc[fmt] += device.matmul_time(op, fmt)
    return dict(acc)


def fractions(times: dict[str, float]) -> dict[str, float]:
    tot = sum(times.values()) or 1.0
    return {k: v / tot for k, v in times.items()}


def flops_by_format(assigned: list[tuple[MatmulOp, str]]) -> dict[str, float]:
    acc: dict[str, float] = defaultdict(float)
    for op, fmt in assigned:
        acc[fmt] += op.flops
    return dict(acc)
