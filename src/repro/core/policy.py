"""Offload policy: which tensors run through quantized kernels.

Mirrors GGML model-file conventions (the thing the paper profiles in
Table I): a model is stored with per-tensor quantization types, the
accelerator executes the quantized dot products, and everything else
(F32/F16 ops — norms, softmax, attention score/PV, small tensors) stays
on the "host" path — on TPU, plain bf16/f32 XLA ops.

A policy maps tensor *roles* to formats.  Presets reproduce the paper's
two evaluated models (Q8_0 and Q3_K quantizations of SD-Turbo / generic
transformer weights).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

# Roles a weight tensor can play.  Any matmul weight in the framework is
# tagged with one of these when created.
ROLES = (
    "attn_qkv", "attn_out", "mlp_up", "mlp_gate", "mlp_down",
    "expert_up", "expert_gate", "expert_down", "router",
    "ssm_in", "ssm_out", "ssm_x",
    "embed", "lm_head", "conv", "time_embed", "proj_misc",
)

# Formats understood by repro.core.quant.quantize().
FORMATS = ("f32", "bf16", "f16", "q8_0", "q4_0", "q3_k")


@dataclasses.dataclass(frozen=True)
class OffloadPolicy:
    """Per-role weight-format assignment."""
    name: str
    default: str = "bf16"
    overrides: Mapping[str, str] = dataclasses.field(default_factory=dict)
    # Paper's OP_CVT53 approximation (Q3_K only).
    scale_bits: int = 6
    # Quantize the KV cache to Q8_0 blocks (beyond-paper extension).
    quantize_kv: bool = False

    def format_for(self, role: str) -> str:
        if role not in ROLES:
            raise KeyError(f"unknown tensor role {role!r}")
        return self.overrides.get(role, self.default)

    def is_quantized(self, role: str) -> bool:
        return self.format_for(role).startswith("q")


# GGML-like conventions: routers, norms and small glue stay high
# precision; big projection matrices take the model's quantization type.
_COMMON_HP = {
    "router": "f32",
    "time_embed": "f32",
    "proj_misc": "bf16",
}

NONE_POLICY = OffloadPolicy(name="none", default="bf16")

# stable-diffusion.cpp executes convs as im2col + F16 mul_mat and does
# NOT quantize conv weights; attention act-act mul_mats run in F32.
# This is what produces Table I's large F16/F32 residue.
Q8_0_POLICY = OffloadPolicy(
    name="q8_0",
    default="q8_0",
    overrides={**_COMMON_HP, "embed": "q8_0", "conv": "f16"},
)

Q3_K_POLICY = OffloadPolicy(
    name="q3_k",
    default="q3_k",
    # GGML's Q3_K_M keeps embeddings / output at higher precision.
    overrides={**_COMMON_HP, "embed": "q8_0", "lm_head": "q8_0",
               "conv": "f16"},
)

Q3_K_IMAX_POLICY = dataclasses.replace(
    Q3_K_POLICY, name="q3_k_imax", scale_bits=5)  # paper's 5-bit scales

# Beyond the paper's two formats: llama.cpp's default deployment point.
Q4_0_POLICY = OffloadPolicy(
    name="q4_0",
    default="q4_0",
    overrides={**_COMMON_HP, "embed": "q8_0", "lm_head": "q8_0",
               "conv": "f16"},
)

PRESETS = {p.name: p for p in
           (NONE_POLICY, Q8_0_POLICY, Q4_0_POLICY, Q3_K_POLICY,
            Q3_K_IMAX_POLICY)}


def get_policy(name: str) -> OffloadPolicy:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; have {list(PRESETS)}")
