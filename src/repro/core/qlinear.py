"""Quantized linear layers: init, post-training quantization, apply.

A :class:`Linear` is a registered pytree whose children are the weight
(dense array *or* ``Q8_0Tensor``/``Q3KTensor`` after quantization) and
optional bias; the tensor *role* rides along as static aux data so
policies can be applied under ``jit``/``pjit`` without string leaves.
Weights are stored output-major ``(N, K)``, matching the kernel layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.policy import OffloadPolicy
from repro.core.quant import Q3KTensor, Q4_0Tensor, Q8_0Tensor
from repro.kernels import ops

# ---------------------------------------------------------------------
# Matmul recorder: benchmarks install a callback here to enumerate every
# dot-product site (role, m, n, k) — the basis of the Table I
# reproduction.  ``None`` in production = zero overhead.
_RECORDER = None


def set_recorder(fn) -> None:
    global _RECORDER
    _RECORDER = fn


def record_matmul(name: str, role: str, m: int, n: int, k: int,
                  count: int = 1, act_act: bool = False) -> None:
    if _RECORDER is not None:
        _RECORDER(name=name, role=role, m=m, n=n, k=k, count=count,
                  act_act=act_act)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Linear:
    w: Any                      # (N, K) array | Q8_0Tensor | Q3KTensor
    b: Any = None               # (N,) array | None
    role: str = "proj_misc"     # static

    def tree_flatten(self):
        return (self.w, self.b), self.role

    @classmethod
    def tree_unflatten(cls, role, children):
        return cls(children[0], children[1], role)


def init_linear(key: jax.Array, in_dim: int, out_dim: int, *,
                role: str, bias: bool = False,
                dtype=jnp.bfloat16, scale: float | None = None) -> Linear:
    std = scale if scale is not None else in_dim ** -0.5
    w = (jax.random.normal(key, (out_dim, in_dim), jnp.float32)
         * std).astype(dtype)
    b = jnp.zeros((out_dim,), dtype) if bias else None
    return Linear(w=w, b=b, role=role)


_QTYPES = (Q8_0Tensor, Q4_0Tensor, Q3KTensor)


def apply_linear(p: Linear, x: jax.Array, *,
                 force: ops.Force = "auto") -> jax.Array:
    w = p.w
    if _RECORDER is not None:
        n_, k_ = (w.shape[-2], w.shape[-1])
        m_ = 1
        for d in x.shape[:-1]:
            m_ *= int(d)
        record_matmul("linear", p.role, m_, int(n_), int(k_))
    if isinstance(w, _QTYPES):
        y = ops.quantized_matmul(x, w, force=force)
    else:
        y = jax.lax.dot_general(
            x.astype(w.dtype), w,
            dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)
    if p.b is not None:
        y = y + p.b.astype(y.dtype)
    return y


def quantize_linear(p: Linear, policy: OffloadPolicy) -> Linear:
    """Post-training quantization of one linear layer."""
    fmt = policy.format_for(p.role)
    w = p.w
    if isinstance(w, _QTYPES):
        return p
    if not fmt.startswith("q"):
        return Linear(quant.quantize(w, fmt), p.b, p.role)
    kw = {"scale_bits": policy.scale_bits} if fmt == "q3_k" else {}
    # Quantized axis is K (last); roles whose K doesn't divide the block
    # stay unquantized (GGML keeps such tensors in F16 as well).
    block = 256 if fmt == "q3_k" else 32
    if w.shape[-1] % block:
        return p
    return Linear(quant.quantize(w, fmt, **kw), p.b, p.role)


def quantize_params(params: Any, policy: OffloadPolicy) -> Any:
    """Walk a param pytree, quantizing every Linear per the policy.

    Generic over containers (dicts, lists, Conv, NamedTuples): Linears
    are treated as leaves of the traversal."""
    return jax.tree.map(
        lambda node: (quantize_linear(node, policy)
                      if isinstance(node, Linear) else node),
        params, is_leaf=lambda x: isinstance(x, Linear))


def _qleaf(x):
    return isinstance(x, _QTYPES)


def param_bytes(params: Any) -> int:
    """Total parameter storage bytes (quantized tensors count packed)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=_qleaf):
        if _qleaf(leaf):
            total += leaf.nbytes()
        elif hasattr(leaf, "dtype"):
            total += leaf.size * leaf.dtype.itemsize
    return total


def param_count(params: Any) -> int:
    """Logical parameter count (quantized tensors count logical size)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=_qleaf):
        if _qleaf(leaf):
            total += int(jnp.prod(jnp.array(leaf.shape)))
        elif hasattr(leaf, "size"):
            total += leaf.size
    return total
