"""GGML-semantic blocked quantization formats: Q8_0, Q3_K, Q8_K.

These reproduce the value semantics of the formats the paper offloads to
IMAX3 (stable-diffusion.cpp / GGML):

* **Q8_0** — blocks of 32 weights; one fp16 scale ``d`` per block; int8
  quants ``q``; value ``w = d * q``.  8.5 bits/weight.
* **Q3_K** — super-blocks of 256 weights = 16 sub-blocks of 16; 3-bit
  quants in ``[-4, 3]`` stored as 2-bit low parts (``ql``) plus a 1-bit
  high mask (``qh``); 6-bit unsigned sub-block scales with an offset of
  32 (effective multiplier ``sc - 32``); one fp16 super-scale ``d``;
  value ``w = d * (sc - 32) * q``.  ~3.44 bits/weight packed.
* **Q8_K** — activation-side format for quantized dot products: blocks
  of 256, fp32 scale, int8 quants.

The paper's OP_CVT53 restructuring (6-bit scales approximated to 5 bits,
2+1-bit quants unified to 3 bits) is reproduced by ``scale_bits=5`` and
by the in-kernel ``ql|qh<<2`` unpack in ``repro.kernels.q3k_matmul``.

All functions are pure-jnp and jittable; leading (row) dimensions are
arbitrary, the quantized axis is always the last one.

Edge cases (regression-tested in ``tests/test_quant.py``):

* fp16 block scales are saturated into ``[F16_TINY, F16_MAX]`` for
  non-zero blocks, so huge blocks cannot dequantize to NaN (0 * inf)
  and tiny-but-representable blocks are not silently flushed to zero.
* int8 codes are clipped to the symmetric ``[-127, 127]`` (Q8_0) /
  ``[0, 15]`` (Q4_0) before the narrowing cast — fp16 rounding of the
  scale can otherwise overshoot to -128 / 16 and wrap.
* Q8_0/Q4_0 accept ragged last dimensions: the tail block is zero
  padded for storage and the logical length is carried on the tensor
  (``.shape`` stays logical, ``dequantize_*`` slices the pad off).
  K-quants (Q3_K/Q8_K) keep GGML's hard divisibility requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

QK8_0 = 32     # Q8_0 block size
QK_K = 256     # k-quant super-block size
Q3K_SUB = 16   # Q3_K sub-block size
N_SUB = QK_K // Q3K_SUB  # 16 sub-blocks per super-block

# Storage cost in bits per weight (packed, GGML-faithful).
BPW = {
    "f32": 32.0,
    "f16": 16.0,
    "bf16": 16.0,
    "q8_0": (32 * 8 + 16) / 32,                  # 8.5
    "q4_0": (16 * 8 + 16) / 32,                  # 4.5
    "q3_k": (64 * 8 + 32 * 8 + 12 * 8 + 16) / 256,  # 3.4375
    "q8_k": (256 * 8 + 32) / 256,                # 8.125
}


def _check_last_divisible(x: jax.Array, block: int) -> None:
    if x.shape[-1] % block:
        raise ValueError(
            f"quantized axis {x.shape[-1]} not divisible by block {block}")


# fp16 range guards for block scales.  GGML stores ``d`` as fp16; a naive
# ``(amax / q_max).astype(float16)`` overflows to inf for amax beyond
# ~127 * 65504 (dequant then yields 0 * inf = NaN) and flushes to zero
# below the smallest subnormal (silently zeroing a representable block).
F16_MAX = 65504.0    # largest finite float16
F16_TINY = 2.0 ** -24  # smallest positive (subnormal) float16


def _f16_scale(amax: jax.Array, q_max: float) -> jax.Array:
    """Block scale ``amax / q_max`` saturated into fp16's positive range.

    Zero blocks keep a scale of exactly 0 (and quantize to all-zero via
    the ``inv`` guard in the callers); non-zero blocks are clamped into
    ``[F16_TINY, F16_MAX]`` so the fp16 cast can neither overflow to inf
    nor flush a representable scale to zero.
    """
    d = amax / q_max
    d = jnp.where(amax > 0, jnp.clip(d, F16_TINY, F16_MAX), 0.0)
    return d.astype(jnp.float16)


def _pad_tail(x: jax.Array, block: int) -> tuple[jax.Array, int | None]:
    """Zero-pad the last axis up to a block multiple.

    Returns ``(padded, logical)`` where ``logical`` is the original last
    dimension when padding was needed, else ``None``.  Padding zeros are
    inert: they never raise a block's amax and dequantize back to 0.
    """
    pad = -x.shape[-1] % block
    if not pad:
        return x, None
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths), x.shape[-1]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q8_0Tensor:
    """Q8_0: int8 quants + fp16 per-32 scales.

    ``logical`` (static aux) records the pre-padding last dimension for
    tensors whose quantized axis was not a block multiple; ``None`` means
    the stored and logical lengths agree.  ``shape`` always reports the
    logical shape; ``nbytes`` counts the stored (padded) payload.
    """
    qs: jax.Array  # int8   (..., Kp)  Kp = logical rounded up to 32
    d: jax.Array   # f16    (..., Kp // 32)
    logical: int | None = None

    @property
    def shape(self):
        k = self.logical if self.logical is not None else self.qs.shape[-1]
        return self.qs.shape[:-1] + (k,)

    def tree_flatten(self):
        return (self.qs, self.d), self.logical

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, logical=aux)

    def nbytes(self) -> int:
        return self.qs.size + 2 * self.d.size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q3KTensor:
    """Q3_K: packed 3-bit quants, 6-bit sub-scales, fp16 super-scale.

    ``ql`` packs 4 low-2-bit values per byte (value j of each group of 4
    at bit ``2*j``); ``qh`` packs 8 high bits per byte (value j of each
    group of 8 at bit ``j``).  ``scales`` holds the 6-bit codes packed 4
    per 3 bytes (little-endian bitstream within each 3-byte group).
    """
    ql: jax.Array      # uint8 (..., K // 4)
    qh: jax.Array      # uint8 (..., K // 8)
    scales: jax.Array  # uint8 (..., K // 256, 12)  packed 6-bit codes
    d: jax.Array       # f16   (..., K // 256)
    scale_bits: int = 6  # 6 (exact) or 5 (paper's OP_CVT53 approximation)

    @property
    def shape(self):
        return self.ql.shape[:-1] + (self.ql.shape[-1] * 4,)

    def tree_flatten(self):
        return (self.ql, self.qh, self.scales, self.d), self.scale_bits

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, scale_bits=aux)

    def nbytes(self) -> int:
        return self.ql.size + self.qh.size + self.scales.size + 2 * self.d.size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q8KTensor:
    """Q8_K activation blocks: int8 quants + fp32 per-256 scales."""
    qs: jax.Array  # int8 (..., K)
    d: jax.Array   # f32  (..., K // 256)

    @property
    def shape(self):
        return self.qs.shape

    def tree_flatten(self):
        return (self.qs, self.d), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    def nbytes(self) -> int:
        return self.qs.size + 4 * self.d.size


# ---------------------------------------------------------------- Q8_0

def quantize_q8_0(x: jax.Array) -> Q8_0Tensor:
    xp, logical = _pad_tail(x, QK8_0)
    xb = xp.astype(jnp.float32).reshape(*xp.shape[:-1], -1, QK8_0)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    d = _f16_scale(amax, 127.0)
    inv = jnp.where(d > 0, 1.0 / d.astype(jnp.float32), 0.0)
    # clip to the symmetric [-127, 127]: fp16 rounding of ``d`` can push
    # ``round(x * inv)`` to -128, which must not wrap on the int8 cast.
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127).astype(jnp.int8)
    return Q8_0Tensor(qs=q.reshape(xp.shape), d=d, logical=logical)


def dequantize_q8_0(t: Q8_0Tensor, dtype=jnp.float32) -> jax.Array:
    qb = t.qs.reshape(*t.qs.shape[:-1], -1, QK8_0).astype(jnp.float32)
    w = qb * t.d.astype(jnp.float32)[..., None]
    w = w.reshape(t.qs.shape)
    if t.logical is not None:
        w = w[..., :t.logical]
    return w.astype(dtype)


# ---------------------------------------------------------------- Q4_0

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q4_0Tensor:
    """Q4_0: 4-bit quants (offset 8), two per byte, fp16 per-32 scales.

    GGML semantics: w = d * (q - 8), q in [0, 15].  The extra GGML
    format beyond the paper's two — 4.5 bits/weight, the most common
    llama.cpp deployment point.
    """
    qs: jax.Array  # uint8 (..., Kp // 2) packed low-nibble-first
    d: jax.Array   # f16   (..., Kp // 32)
    logical: int | None = None  # pre-padding K when ragged, else None

    @property
    def shape(self):
        k = self.logical if self.logical is not None else self.qs.shape[-1] * 2
        return self.qs.shape[:-1] + (k,)

    def tree_flatten(self):
        return (self.qs, self.d), self.logical

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, logical=aux)

    def nbytes(self) -> int:
        return self.qs.size + 2 * self.d.size


def pack_q4(q_unsigned: jax.Array) -> jax.Array:
    """Pack 4-bit values (0..15), last axis K -> K/2 bytes (lo, hi)."""
    k = q_unsigned.shape[-1]
    q = q_unsigned.astype(jnp.uint8).reshape(*q_unsigned.shape[:-1],
                                             k // 2, 2)
    return (q[..., 0] | (q[..., 1] << 4)).astype(jnp.uint8)


def unpack_q4(qs: jax.Array) -> jax.Array:
    """(..., K/2) bytes -> (..., K) int8 values in [-8, 7] (offset 8)."""
    lo = (qs & 0x0F).astype(jnp.int32) - 8
    hi = ((qs >> 4) & 0x0F).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*qs.shape[:-1], qs.shape[-1] * 2).astype(jnp.int8)


def quantize_q4_0(x: jax.Array) -> Q4_0Tensor:
    xp, logical = _pad_tail(x, QK8_0)
    xb = xp.astype(jnp.float32).reshape(*xp.shape[:-1], -1, QK8_0)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    d = _f16_scale(amax, 7.0)  # q-8 in [-8,7]; use +/-7 sym
    inv = jnp.where(d > 0, 1.0 / d.astype(jnp.float32), 0.0)
    # clip keeps the code in [0, 15]: without it, fp16 rounding of ``d``
    # can drive ``round(x * inv)`` to -8 (code -8+8 = 0 is fine) or +8
    # (code 16 would wrap into the neighbouring nibble when packed).
    q = jnp.clip(jnp.round(xb * inv[..., None]) + 8, 0, 15)
    qs = pack_q4(q.reshape(*xp.shape[:-1], -1).astype(jnp.uint8))
    return Q4_0Tensor(qs=qs, d=d, logical=logical)


def dequantize_q4_0(t: Q4_0Tensor, dtype=jnp.float32) -> jax.Array:
    q = unpack_q4(t.qs).astype(jnp.float32)
    qb = q.reshape(*q.shape[:-1], -1, QK8_0)
    w = qb * t.d.astype(jnp.float32)[..., None]
    w = w.reshape(q.shape)
    if t.logical is not None:
        w = w[..., :t.logical]
    return w.astype(dtype)


# ---------------------------------------------------------------- Q8_K

def quantize_q8_k(x: jax.Array) -> Q8KTensor:
    _check_last_divisible(x, QK_K)
    xb = x.astype(jnp.float32).reshape(*x.shape[:-1], -1, QK_K)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    d = amax / 127.0
    inv = jnp.where(d > 0, 1.0 / d, 0.0)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127).astype(jnp.int8)
    return Q8KTensor(qs=q.reshape(x.shape), d=d.astype(jnp.float32))


def dequantize_q8_k(t: Q8KTensor, dtype=jnp.float32) -> jax.Array:
    qb = t.qs.reshape(*t.qs.shape[:-1], -1, QK_K).astype(jnp.float32)
    w = qb * t.d[..., None]
    return w.reshape(t.qs.shape).astype(dtype)


# ---------------------------------------------------------------- Q3_K

def pack_q3(q_unsigned: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pack unsigned 3-bit values (0..7), last axis K -> (ql K/4, qh K/8)."""
    k = q_unsigned.shape[-1]
    q = q_unsigned.astype(jnp.uint8)
    low = (q & 3).reshape(*q.shape[:-1], k // 4, 4)
    shifts = jnp.arange(4, dtype=jnp.uint8) * 2
    ql = jnp.sum(low.astype(jnp.uint32) << shifts.astype(jnp.uint32), axis=-1)
    hi = ((q >> 2) & 1).reshape(*q.shape[:-1], k // 8, 8)
    hshifts = jnp.arange(8, dtype=jnp.uint32)
    qh = jnp.sum(hi.astype(jnp.uint32) << hshifts, axis=-1)
    return ql.astype(jnp.uint8), qh.astype(jnp.uint8)


def unpack_q3(ql: jax.Array, qh: jax.Array) -> jax.Array:
    """Inverse of pack_q3: returns signed int8 values in [-4, 3], shape (..., K)."""
    shifts = jnp.arange(4, dtype=jnp.uint8) * 2
    low = (ql[..., None] >> shifts) & 3                      # (..., K/4, 4)
    low = low.reshape(*ql.shape[:-1], ql.shape[-1] * 4)
    hshifts = jnp.arange(8, dtype=jnp.uint8)
    hi = (qh[..., None] >> hshifts) & 1                       # (..., K/8, 8)
    hi = hi.reshape(*qh.shape[:-1], qh.shape[-1] * 8)
    q = (low.astype(jnp.int8) | (hi.astype(jnp.int8) << 2)).astype(jnp.int32) - 4
    return q.astype(jnp.int8)


def pack_scales6(sc: jax.Array) -> jax.Array:
    """Pack unsigned 6-bit codes (..., nsb, 16) -> (..., nsb, 12) bytes.

    Four codes -> three bytes, little-endian within each group.
    """
    s = sc.astype(jnp.uint32).reshape(*sc.shape[:-1], 4, 4)
    word = (s[..., 0] | (s[..., 1] << 6) | (s[..., 2] << 12) | (s[..., 3] << 18))
    b0 = word & 0xFF
    b1 = (word >> 8) & 0xFF
    b2 = (word >> 16) & 0xFF
    packed = jnp.stack([b0, b1, b2], axis=-1)                 # (..., 4, 3)
    return packed.reshape(*sc.shape[:-1], 12).astype(jnp.uint8)


def unpack_scales6(packed: jax.Array) -> jax.Array:
    """Inverse of pack_scales6: (..., nsb, 12) -> (..., nsb, 16) uint8 codes."""
    p = packed.astype(jnp.uint32).reshape(*packed.shape[:-1], 4, 3)
    word = p[..., 0] | (p[..., 1] << 8) | (p[..., 2] << 16)
    s = jnp.stack([(word >> (6 * j)) & 0x3F for j in range(4)], axis=-1)
    return s.reshape(*packed.shape[:-1], 16).astype(jnp.uint8)


def approx_scale_codes(sc: jax.Array, scale_bits: int) -> jax.Array:
    """Paper's OP_CVT53 scale approximation: 6-bit code -> ``scale_bits``.

    ``sc`` are unsigned 6-bit codes (effective multiplier ``sc - 32``).
    For 5 bits we drop the LSB of the effective value and re-center,
    which the paper reports as having almost no effect on outputs.
    """
    if scale_bits == 6:
        return sc
    if scale_bits == 5:
        eff = sc.astype(jnp.int32) - 32          # [-32, 31]
        eff5 = (eff >> 1) << 1                   # drop LSB -> 5-bit grid
        return (eff5 + 32).astype(jnp.uint8)
    raise ValueError(f"unsupported scale_bits={scale_bits}")


def quantize_q3_k(x: jax.Array, scale_bits: int = 6) -> Q3KTensor:
    _check_last_divisible(x, QK_K)
    lead = x.shape[:-1]
    xs = x.astype(jnp.float32).reshape(*lead, -1, N_SUB, Q3K_SUB)
    # Per-sub-block ideal scale: q in [-4, 3] -> divide by 4.
    amax = jnp.max(jnp.abs(xs), axis=-1)                       # (..., nsb, 16)
    d_sub = amax / 4.0
    # Super-block scale so that |code| <= 31 (code = sc - 32 in [-32, 31]).
    d = jnp.max(d_sub, axis=-1) / 31.0                         # (..., nsb)
    inv_d = jnp.where(d > 0, 1.0 / d, 0.0)
    code = jnp.clip(jnp.round(d_sub * inv_d[..., None]), 0, 31) + 32
    code = approx_scale_codes(code.astype(jnp.uint8), scale_bits)
    eff = d[..., None] * (code.astype(jnp.float32) - 32.0)     # (..., nsb, 16)
    inv_eff = jnp.where(eff != 0, 1.0 / eff, 0.0)
    q = jnp.clip(jnp.round(xs * inv_eff[..., None]), -4, 3)
    qu = (q + 4).astype(jnp.uint8).reshape(*lead, -1)          # (..., K) 0..7
    ql, qh = pack_q3(qu)
    return Q3KTensor(ql=ql, qh=qh, scales=pack_scales6(code),
                     d=d.astype(jnp.float16), scale_bits=scale_bits)


def q3k_effective_scales(t: Q3KTensor) -> jax.Array:
    """Effective per-sub-block multiplier d*(sc-32): shape (..., K // 16)."""
    code = unpack_scales6(t.scales).astype(jnp.float32)        # (..., nsb, 16)
    eff = t.d.astype(jnp.float32)[..., None] * (code - 32.0)
    return eff.reshape(*t.d.shape[:-1], -1)


def dequantize_q3_k(t: Q3KTensor, dtype=jnp.float32) -> jax.Array:
    q = unpack_q3(t.ql, t.qh).astype(jnp.float32)              # (..., K)
    eff = q3k_effective_scales(t)                              # (..., K/16)
    qb = q.reshape(*q.shape[:-1], -1, Q3K_SUB)
    w = qb * eff[..., None]
    return w.reshape(q.shape).astype(dtype)


# ------------------------------------------------------------- helpers

def quantize(x: jax.Array, fmt: str, **kw: Any):
    if fmt == "q8_0":
        return quantize_q8_0(x)
    if fmt == "q4_0":
        return quantize_q4_0(x)
    if fmt == "q3_k":
        return quantize_q3_k(x, **kw)
    if fmt == "q8_k":
        return quantize_q8_k(x)
    if fmt in ("f32", "f16", "bf16"):
        return x.astype({"f32": jnp.float32, "f16": jnp.float16,
                         "bf16": jnp.bfloat16}[fmt])
    raise ValueError(f"unknown format {fmt!r}")


def dequantize(t, dtype=jnp.float32) -> jax.Array:
    if isinstance(t, Q8_0Tensor):
        return dequantize_q8_0(t, dtype)
    if isinstance(t, Q4_0Tensor):
        return dequantize_q4_0(t, dtype)
    if isinstance(t, Q3KTensor):
        return dequantize_q3_k(t, dtype)
    if isinstance(t, Q8KTensor):
        return dequantize_q8_k(t, dtype)
    return t.astype(dtype)
