"""Deterministic, restart-stable synthetic data pipeline with prefetch.

At 1000+-node scale the input pipeline must be (a) deterministic under
restart — a resumed job consumes exactly the batches the crashed job
would have — and (b) never the straggler.  Both are structural here:

* Batch ``i`` is a pure function of ``(seed, i)`` (counter-based RNG),
  so the pipeline "state" in a checkpoint is a single integer cursor.
* A background thread prefetches ``prefetch`` batches ahead, modelling
  the host->device feeding that the paper identifies as the bottleneck
  of its lane scaling (§V.A: host cores saturate the IMAX lanes).

The synthetic stream produces token sequences with a fixed-point
structure (Zipf-ish marginals, local repetition) so that language-model
training losses show real learning signal in the examples.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class TokenPipeline:
    def __init__(self, *, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, start_step: int = 0, prefetch: int = 2):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- deterministic batch synthesis ---------------------------------
    def make_batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        v = self.vocab_size
        # Zipf-like marginal with local bigram structure.
        base = rng.zipf(1.5, size=(self.batch, self.seq_len + 1)) % v
        shift = rng.integers(0, 7, size=(self.batch, 1))
        seq = (base + shift) % v
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}

    # -- prefetch loop --------------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.make_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    # -- checkpoint integration -----------------------------------------
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)


def latent_batch(step: int, *, batch: int, h: int, w: int, c: int = 4,
                 seed: int = 0) -> np.ndarray:
    """Deterministic synthetic latents for diffusion training/serving."""
    rng = np.random.default_rng((seed << 20) ^ (step + 0x5D))
    return rng.standard_normal((batch, h, w, c)).astype(np.float32)
