"""Compatibility wrapper over the request-based engine API.

The text-to-image pipeline (CLIP -> UNet denoise -> VAE decode, the
stable-diffusion.cpp path the paper profiles) now lives in
:mod:`repro.engine.diffusion_engine`:

* serving callers build a :class:`repro.engine.DiffusionEngine` and
  ``submit()`` :class:`repro.engine.GenerateRequest` objects — that
  path gets micro-batching, the sampler registry, per-request CFG
  scales, and the jitted ``lax.scan`` denoise loop with an explicit
  compile cache;
* this module re-exports the configs/init/quantize helpers and keeps
  ``generate`` as a thin, fully-traceable single-shot wrapper (it is
  called under ``jax.jit`` and ``jax.eval_shape`` by the benchmarks).

Every linear/conv weight remains role-tagged, so applying an
``OffloadPolicy`` still quantizes exactly the tensors GGML would
(Q8_0 or Q3_K model files).  The engine redesign kept the sampler
math and the noise draw (same bf16 values per key) but restructured
the program around ``lax.scan``, so outputs for a fixed key agree
with the pre-engine pipeline to bf16 reassociation tolerance
(corr > 0.9999), not bit-exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Re-exported for compatibility: configs and weight helpers moved to
# the engine subsystem (benchmarks/ and examples/ import them here).
from repro.engine.api import default_sampler, uses_cfg
from repro.engine.diffusion_engine import (SD_TURBO, TINY_SD,  # noqa: F401
                                           SDConfig, build_denoise,
                                           init_pipeline, quantize_pipeline)
from repro.engine.samplers import get_sampler
from repro.diffusion import schedule as sched_mod


def generate(params: dict, cfg: SDConfig, tokens: jax.Array,
             key: jax.Array, *, steps: int | None = None,
             sampler: str | None = None, guidance_scale: float = 1.0,
             neg_tokens: jax.Array | None = None) -> jax.Array:
    """tokens: (B, 77) -> images (B, 8*latent_hw, 8*latent_hw, 3).

    Single-shot traceable path: picks the sampler by name from the
    registry (default: turbo for 1 step, ddim otherwise) and runs the
    shared scan-based denoise program once at batch shape ``B``.
    Serving workloads should prefer ``DiffusionEngine``.
    """
    steps = steps or cfg.steps
    name = sampler or default_sampler(steps)
    use_cfg = uses_cfg(neg_tokens, guidance_scale)
    b = tokens.shape[0]
    # bf16 draw upcast to f32: bit-compatible with the pre-engine
    # pipeline for a fixed key (random.normal differs per dtype).
    noise = jax.random.normal(key, (b, cfg.latent_hw, cfg.latent_hw, 4),
                              jnp.bfloat16).astype(jnp.float32)
    plan = get_sampler(name).plan(sched_mod.NoiseSchedule(), steps, steps)
    neg = neg_tokens if neg_tokens is not None else jnp.zeros_like(tokens)
    g = jnp.full((b,), guidance_scale, jnp.float32)
    fn = build_denoise(cfg, name, use_cfg)
    return fn(params, tokens, neg, g, noise, plan)
