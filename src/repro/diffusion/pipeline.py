"""Text-to-image pipeline: CLIP -> UNet (denoise loop) -> VAE decode.

This is the stable-diffusion.cpp execution path the paper profiles:
every linear/conv weight is role-tagged, so applying an
``OffloadPolicy`` quantizes exactly the tensors GGML would (Q8_0 or
Q3_K model files), and the un-quantized remainder (norms, softmax,
attention score/PV) is the paper's F32/F16 "host" share.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.policy import OffloadPolicy
from repro.core.qlinear import quantize_params
from repro.diffusion import schedule as sched_mod
from repro.models import clip as clip_mod
from repro.models import unet as unet_mod
from repro.models import vae as vae_mod


@dataclasses.dataclass(frozen=True)
class SDConfig:
    name: str = "sd-turbo"
    unet: unet_mod.UNetConfig = unet_mod.SD15_UNET
    vae: vae_mod.VAEConfig = vae_mod.SD15_VAE
    clip: Any = None   # ModelConfig; None -> clip_mod.clip_config()
    latent_hw: int = 64          # 512x512 image -> 64x64 latent
    text_len: int = 77
    steps: int = 1               # SD-Turbo single step

    def clip_cfg(self):
        return self.clip or clip_mod.clip_config()


SD_TURBO = SDConfig()
TINY_SD = SDConfig(name="tiny-sd", unet=unet_mod.TINY_UNET,
                   vae=vae_mod.TINY_VAE, clip=clip_mod.TINY_CLIP,
                   latent_hw=8, steps=1)


def init_pipeline(key: jax.Array, cfg: SDConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "clip": clip_mod.init_clip(ks[0], cfg.clip_cfg()),
        "unet": unet_mod.init_unet(ks[1], cfg.unet),
        "vae": vae_mod.init_vae_decoder(ks[2], cfg.vae),
    }


def quantize_pipeline(params: dict, policy: OffloadPolicy) -> dict:
    """GGML-style model-file quantization (the paper's two models)."""
    return quantize_params(params, policy)


def generate(params: dict, cfg: SDConfig, tokens: jax.Array,
             key: jax.Array, *, steps: int | None = None) -> jax.Array:
    """tokens: (B, 77) -> images (B, 8*latent_hw, 8*latent_hw, 3)."""
    steps = steps or cfg.steps
    b = tokens.shape[0]
    ctx = clip_mod.clip_encode(params["clip"], cfg.clip_cfg(), tokens)
    noise_sched = sched_mod.NoiseSchedule()
    x = jax.random.normal(key, (b, cfg.latent_hw, cfg.latent_hw, 4),
                          jnp.bfloat16)
    if steps == 1:  # SD-Turbo
        t = jnp.full((b,), 999)
        eps = unet_mod.apply_unet(params["unet"], cfg.unet, x, t, ctx)
        x0 = sched_mod.turbo_step(noise_sched, x.astype(jnp.float32),
                                  eps.astype(jnp.float32))
    else:
        ts = sched_mod.ddim_timesteps(steps)
        x0 = x.astype(jnp.float32)
        for i in range(steps):
            t = jnp.full((b,), ts[i])
            eps = unet_mod.apply_unet(params["unet"], cfg.unet,
                                      x0.astype(jnp.bfloat16), t, ctx)
            t_prev = ts[i + 1] if i + 1 < steps else jnp.array(-1)
            x0 = sched_mod.ddim_step(noise_sched, x0,
                                     eps.astype(jnp.float32),
                                     ts[i], t_prev)
    img = vae_mod.apply_vae_decoder(params["vae"], cfg.vae,
                                    x0.astype(jnp.bfloat16))
    return img
