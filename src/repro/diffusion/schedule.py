"""Diffusion noise schedules and samplers (DDIM / Euler / SD-Turbo).

SD v1.5's scaled-linear beta schedule; the paper's experiment is the
SD-Turbo single-step sampler (adversarial diffusion distillation
checkpoint — our weights are synthetic, but the sampler math and the
compute graph are the real ones, which is what the kernel offload
study needs).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NoiseSchedule:
    num_train_timesteps: int = 1000
    beta_start: float = 0.00085
    beta_end: float = 0.012

    def alphas_cumprod(self) -> jax.Array:
        betas = jnp.linspace(self.beta_start ** 0.5, self.beta_end ** 0.5,
                             self.num_train_timesteps) ** 2
        return jnp.cumprod(1.0 - betas)


def ddim_timesteps(num_steps: int, num_train: int = 1000) -> jax.Array:
    """Evenly spaced descending timesteps, starting at ``num_train - 1``.

    ``num_steps`` is clamped to ``[1, num_train]`` (more steps than
    training timesteps would make the stride 0 and crash ``arange``);
    the result always holds exactly ``min(num_steps, num_train)``
    unique timesteps.
    """
    num_steps = max(1, min(int(num_steps), int(num_train)))
    step = num_train // num_steps
    return jnp.arange(num_train - 1, -1, -step)[:num_steps]


def ddim_step(sched: NoiseSchedule, x: jax.Array, eps: jax.Array,
              t: jax.Array, t_prev: jax.Array) -> jax.Array:
    ac = sched.alphas_cumprod()
    a_t = ac[t]
    a_prev = jnp.where(t_prev >= 0, ac[jnp.maximum(t_prev, 0)], 1.0)
    x0 = (x - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    return jnp.sqrt(a_prev) * x0 + jnp.sqrt(1 - a_prev) * eps


def euler_timestep_indices(sched: NoiseSchedule,
                           num_steps: int) -> jax.Array:
    """Descending timestep indices for the Euler sigma spacing.

    Shared by ``euler_sigmas`` and the engine's Euler sampler plan so
    the UNet's conditioning timestep always matches the sigma fed to
    ``euler_step``.
    """
    return jnp.linspace(sched.num_train_timesteps - 1, 0,
                        num_steps).round().astype(jnp.int32)


def euler_sigmas(sched: NoiseSchedule, num_steps: int) -> jax.Array:
    ac = sched.alphas_cumprod()
    sigmas = jnp.sqrt((1 - ac) / ac)
    idx = euler_timestep_indices(sched, num_steps)
    return jnp.concatenate([sigmas[idx], jnp.zeros((1,))])


def euler_step(x: jax.Array, eps: jax.Array, sigma: jax.Array,
               sigma_next: jax.Array) -> jax.Array:
    d = eps  # eps-prediction == derivative in the VE view used here
    return x + (sigma_next - sigma) * d


def turbo_step(sched: NoiseSchedule, x: jax.Array,
               eps: jax.Array, t: int = 999) -> jax.Array:
    """SD-Turbo: single step from pure noise directly to x0 estimate."""
    ac = sched.alphas_cumprod()
    a_t = ac[t]
    return (x - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
