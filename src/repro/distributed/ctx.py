"""Logical-axis sharding context for activation constraints.

GSPMD's sharding propagation is a global heuristic: left alone it picks
different strategies per program (we measured 2.5-4x redundant compute
on the 16x16 mesh, and unstable choices between otherwise-identical
lowerings).  Production JAX frameworks pin intermediate shardings with
``with_sharding_constraint``; this module provides that as an ambient
context so model code stays mesh-agnostic:

* the launcher/dry-run enters :func:`axis_env` around lowering;
* model code calls :func:`constrain` (or the shape-specific helpers) at
  the canonical cut points (residual stream, head-split tensors, FFN
  hidden, expert buffers, logits);
* without an active env (CPU smoke tests) everything is a no-op.

Dims that don't divide the assigned mesh axes are silently left
unsharded (e.g. batch=1 decode, kv-heads < model parallelism).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ENV = contextvars.ContextVar("repro_axis_env", default=None)


class AxisEnv:
    def __init__(self, mesh: Mesh, dp: Sequence[str] = ("data",),
                 tp: str = "model", moe_mode: str = "ep"):
        self.mesh = mesh
        self.dp = tuple(a for a in dp if a in mesh.shape)
        self.tp = tp if tp in mesh.shape else None
        self.moe_mode = moe_mode  # "ep": experts on tp | "dp": FSDP

    def axis_size(self, axes) -> int:
        n = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            n *= self.mesh.shape[a]
        return n


@contextlib.contextmanager
def axis_env(mesh: Mesh, dp: Sequence[str] = ("pod", "data"),
             tp: str = "model", moe_mode: str = "ep"):
    env = AxisEnv(mesh, dp, tp, moe_mode)
    token = _ENV.set(env)
    try:
        yield env
    finally:
        _ENV.reset(token)


def current() -> AxisEnv | None:
    return _ENV.get()


def constrain(x: jax.Array, spec_map: dict[int, str]) -> jax.Array:
    """spec_map: dim index -> 'dp' | 'tp'. No-op without an env."""
    env = current()
    if env is None or x is None:
        return x
    axes: list = [None] * x.ndim
    for dim, kind in spec_map.items():
        if dim >= x.ndim:
            continue
        name = env.dp if kind == "dp" else env.tp
        if not name:
            continue
        if kind == "dp":
            # use the largest prefix of dp axes that divides
            use = []
            prod = 1
            for a in name:
                if x.shape[dim] % (prod * env.mesh.shape[a]) == 0:
                    use.append(a)
                    prod *= env.mesh.shape[a]
            if use:
                axes[dim] = tuple(use) if len(use) > 1 else use[0]
        else:
            if x.shape[dim] % env.mesh.shape[name] == 0:
                axes[dim] = name
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, P(*axes)))


# Canonical cut points --------------------------------------------------

def act(x):      # (B, S, d) residual stream
    return constrain(x, {0: "dp"})


def heads(x):    # (B, H, S, hd) attention-head tensors (keys/values)
    return constrain(x, {0: "dp", 1: "tp"})


def heads_q(x):  # (B, H, Sq, hd) query-side tensors
    """When the head count doesn't divide the TP axis (whisper: 20
    heads on 16-way model; xlstm: 4 heads), shard the query-position
    dim instead — attention is embarrassingly parallel over queries, so
    this recovers the 16x replicated S^2 logits memory/compute."""
    env = current()
    if (env is not None and env.tp
            and x.ndim == 4
            and x.shape[1] % env.mesh.shape[env.tp]
            and x.shape[2] % env.mesh.shape[env.tp] == 0):
        return constrain(x, {0: "dp", 2: "tp"})
    return constrain(x, {0: "dp", 1: "tp"})


def ffn(x):      # (B, S, ff) / (B, S, 2*d_in) hidden
    return constrain(x, {0: "dp", 2: "tp"})


def vocab(x):    # (B, S, V) logits
    return constrain(x, {0: "dp", 2: "tp"})


def experts(x):  # (E, C, d) expert buffers
    return constrain(x, {0: "tp"})


def expert_buf(x):  # (G, E, C, d): EP shards E on tp; DP-MoE keeps G-local
    env = current()
    if env is not None and env.moe_mode == "dp":
        return constrain(x, {0: "dp"})
    return constrain(x, {0: "dp", 1: "tp"})


def kv_cache(x):  # (B, Hkv, C, hd) per-layer cache inside the scan
    return constrain(x, {0: "dp", 2: "tp"})


def paged_kv(x):  # (NB, Hkv, bs, hd) paged block pool: shard kv heads
    return constrain(x, {1: "tp"})


def decode_logits(x):  # (B, Hkv, G, C) decode attention logits
    return constrain(x, {0: "dp", 3: "tp"})
