"""Fault tolerance & elasticity for 1000+-node runs.

Mechanisms provided (and exercised by the launcher / tests):

1. **Checkpoint-restart** — `repro.checkpoint.ckpt` atomic checkpoints
   + the launcher's `--resume auto` path.  MTBF math: with per-step
   time t_s, checkpoint interval k, node MTBF m and N nodes, expected
   lost work per failure is k·t_s/2 and failures arrive at N/m; the
   launcher picks k so overhead (write time + expected replay) is <1%.
2. **Straggler mitigation** — a per-step watchdog measures step
   latency EWMA; a step exceeding `threshold ×` the EWMA marks the
   step "suspect" and triggers the `on_straggler` hook (in a real
   multi-controller deployment: preempt + re-slice the failed host's
   pod; here: recorded + surfaced in metrics so tests can assert the
   policy).  The synchronous-SPMD alternative of backup workers is
   intentionally not used — at pod granularity, restart-from-ckpt with
   elastic re-meshing is cheaper than 2× hot spares.
3. **Elastic re-meshing** — `elastic_mesh()` rebuilds the largest
   valid (pod, data, model) mesh from the *live* device set; because
   model code depends only on mesh axis names, a job restarted on
   fewer pods re-lowers the same program with a smaller `pod` axis and
   continues from checkpoint (tested in tests/test_distributed.py).
4. **Replica health state machine** — `ReplicaHealth` turns the
   per-step `Watchdog` signal into a serving-side lifecycle
   (HEALTHY -> SUSPECT -> EVICTED, plus DRAINING for planned removal)
   consumed by `repro.engine.fleet.FleetManager`: one straggler step
   marks a replica SUSPECT, `suspect_limit` *consecutive* stragglers
   (or a hard fault) evict it, and a clean step clears suspicion.
   Eviction is terminal: a flapping replica must be replaced, not
   re-trusted.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class Watchdog:
    """Per-step latency EWMA + straggler detection hook."""
    threshold: float = 3.0
    alpha: float = 0.2
    on_straggler: Callable[[int, float, float], None] | None = None
    ewma: float | None = None
    suspects: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        suspect = (self.ewma is not None
                   and seconds > self.threshold * self.ewma)
        if suspect:
            self.suspects.append((step, seconds, self.ewma))
            if self.on_straggler:
                self.on_straggler(step, seconds, self.ewma)
        # Don't poison the EWMA with the straggler sample itself.
        if not suspect:
            self.ewma = (seconds if self.ewma is None
                         else (1 - self.alpha) * self.ewma
                         + self.alpha * seconds)
        return suspect


# Replica lifecycle states (see ReplicaHealth).
HEALTHY = "HEALTHY"
SUSPECT = "SUSPECT"
DRAINING = "DRAINING"
EVICTED = "EVICTED"


@dataclasses.dataclass
class ReplicaHealth:
    """Watchdog-driven replica lifecycle state machine.

    ``observe_step(step, seconds)`` feeds one step duration through
    the :class:`Watchdog` and advances the state:

    * HEALTHY -> SUSPECT on one straggler step (duration above
      ``threshold x`` the EWMA);
    * SUSPECT -> HEALTHY on a clean step (suspicion is consecutive);
    * SUSPECT -> EVICTED after ``suspect_limit`` consecutive
      straggler steps (a hung replica never produces a clean step, so
      it converges here);
    * any live state -> EVICTED via :meth:`evict` (hard fault:
      the replica's step raised);
    * HEALTHY/SUSPECT -> DRAINING via :meth:`drain` (planned removal:
      finish in-flight work, accept nothing new).  A DRAINING replica
      is still watched and can still be EVICTED.

    EVICTED is terminal — a fleet migrates the replica's in-flight
    requests and never dispatches to it again.
    """
    watchdog: Watchdog = dataclasses.field(default_factory=Watchdog)
    suspect_limit: int = 2
    state: str = HEALTHY
    consecutive_suspects: int = 0
    reason: str = ""
    # Optional telemetry: ``name`` identifies the replica and
    # ``metrics`` is a duck-typed sink (repro.obs.Telemetry or a bare
    # MetricsRegistry) — every state change increments a
    # ``replica_health_transitions_total{replica, src, dst}`` counter.
    name: str = ""
    metrics: object = None

    @property
    def live(self) -> bool:
        return self.state != EVICTED

    @property
    def dispatchable(self) -> bool:
        """Whether new requests may be placed on this replica."""
        return self.state in (HEALTHY, SUSPECT)

    def _set_state(self, new: str) -> None:
        if new == self.state:
            return
        old, self.state = self.state, new
        if self.metrics is not None:
            self.metrics.counter(
                "replica_health_transitions_total",
                "replica health state transitions",
                labels=("replica", "src", "dst"),
            ).inc(replica=self.name, src=old, dst=new)

    def observe_step(self, step: int, seconds: float) -> str:
        if not self.live:
            return self.state
        if self.watchdog.observe(step, seconds):
            self.consecutive_suspects += 1
            if self.consecutive_suspects >= self.suspect_limit:
                self.evict(f"watchdog: {self.consecutive_suspects} "
                           f"consecutive straggler steps "
                           f"(last {seconds:.3f}s vs EWMA "
                           f"{self.watchdog.ewma or 0:.3f}s)")
            elif self.state == HEALTHY:
                self._set_state(SUSPECT)
        else:
            self.consecutive_suspects = 0
            if self.state == SUSPECT:
                self._set_state(HEALTHY)
        return self.state

    def evict(self, reason: str) -> None:
        if self.live:
            self._set_state(EVICTED)
            self.reason = reason

    def drain(self) -> None:
        if self.state in (HEALTHY, SUSPECT):
            self._set_state(DRAINING)


class StepTimer:
    """Context manager timing one step into a :class:`Watchdog`.

    ``clock`` is injectable (default ``time.monotonic``) so fleet
    health telemetry is deterministic under virtual-clock tests — the
    same discipline as :class:`repro.engine.events.EventBus`.
    """

    def __init__(self, watchdog: Watchdog,
                 clock: Callable[[], float] = time.monotonic):
        self.watchdog = watchdog
        self.clock = clock
        self._t0 = None
        self._step = 0

    def __enter__(self):
        self._t0 = self.clock()
        return self

    def __exit__(self, *exc):
        self.watchdog.observe(self._step, self.clock() - self._t0)
        self._step += 1
        return False


def elastic_mesh(devices=None, *, model_parallel: int = 16,
                 pod_size: int = 256) -> Mesh:
    """Largest valid (pod, data, model) mesh from the live device set.

    Keeps `model` fixed (TP degree is a model property), fills `data`
    with what remains inside a pod, and `pod` with whole live pods —
    a job that lost a pod restarts on (pods-1) without re-tuning.
    """
    devices = np.asarray(devices if devices is not None else jax.devices())
    n = devices.size
    pods = max(n // pod_size, 1)
    per_pod = n // pods
    data = max(per_pod // model_parallel, 1)
    usable = pods * data * model_parallel
    devices = devices[:usable].reshape(pods, data, model_parallel)
    return Mesh(devices, ("pod", "data", "model"))
