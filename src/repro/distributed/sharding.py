"""Sharding rules: logical roles -> PartitionSpec on the production mesh.

Mesh axes:
  * ``pod``   — pure data parallelism across pods (cross-ICI/DCN axis).
  * ``data``  — data parallelism + FSDP (ZeRO-3-style parameter
    sharding: every weight also shards its K dim over ``data``).
  * ``model`` — tensor parallelism (heads / ffn / vocab / experts).

Role-based rules cover every ``Linear`` (dense or quantized — the
quantized side tensors ``qs/ql/qh/scales/d`` inherit the weight's spec
with the K-shard dropped when the scale dim doesn't divide).  Remaining
leaves (norms, conv glue, biases) are replicated; big cache/state
buffers get a documented heuristic (batch->data, seq->model, fallback
largest-divisible-dim).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.qlinear import Linear
from repro.core.quant import Q3KTensor, Q4_0Tensor, Q8_0Tensor

# role -> (N_axis, K_axis) for the logical (N, K) weight.
# N is the output dim, K the input/contraction dim.
ROLE_RULES: dict[str, tuple] = {
    "attn_qkv":   ("model", "data"),
    "attn_out":   ("data", "model"),
    "mlp_up":     ("model", "data"),
    "mlp_gate":   ("model", "data"),
    "mlp_down":   ("data", "model"),
    "expert_up":  ("model", "data"),   # expert dim handled separately
    "expert_gate": ("model", "data"),
    "expert_down": ("model", "data"),
    "router":     (None, None),
    "ssm_in":     ("model", "data"),
    "ssm_x":      (None, None),
    "ssm_out":    ("data", "model"),
    "embed":      ("model", "data"),
    "lm_head":    ("model", "data"),
    "conv":       (None, None),
    "time_embed": (None, None),
    "proj_misc":  (None, None),
}


def _divides(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return dim % size == 0


def _weight_spec(shape: tuple, role: str, mesh: Mesh) -> P:
    n_ax, k_ax = ROLE_RULES.get(role, (None, None))
    if len(shape) == 3:  # stacked experts (E, N, K)
        from repro.distributed import ctx as _ctx
        env = _ctx.current()
        if env is not None and getattr(env, "moe_mode", "ep") == "dp":
            # DP-MoE: experts replicated in compute, weights FSDP'd on
            # data (gathered per layer) — cheaper than buffer all-to-all
            # when token bytes exceed expert-weight bytes (training).
            k_ax2 = "data" if _divides(shape[2], mesh, "data") else None
            return P(None, None, k_ax2)
        e_ax = "model" if _divides(shape[0], mesh, "model") else None
        k_ax2 = "data" if (e_ax != "data"
                           and _divides(shape[2], mesh, "data")) else None
        return P(e_ax, None, k_ax2)
    n_ax = n_ax if _divides(shape[0], mesh, n_ax) else None
    k_ax = k_ax if len(shape) > 1 and _divides(shape[1], mesh, k_ax) else None
    if len(shape) == 1:
        return P(n_ax)
    return P(n_ax, k_ax)


def _qside_spec(wspec: P, shape: tuple, mesh: Mesh) -> P:
    """Spec for a quantized side tensor (same leading layout as the
    weight, trailing quantization axes keep the K shard only if they
    divide)."""
    axes = list(wspec) + [None] * (len(shape) - len(wspec))
    axes = axes[: len(shape)]
    for i, ax in enumerate(axes):
        if not _divides(shape[i], mesh, ax):
            axes[i] = None
    return P(*axes)


def linear_specs(lin: Linear, mesh: Mesh) -> Linear:
    """Return a Linear-shaped pytree of PartitionSpecs."""
    w = lin.w
    if isinstance(w, (Q8_0Tensor, Q4_0Tensor)):
        ws = _weight_spec(w.qs.shape, lin.role, mesh)
        spec_w = type(w)(qs=ws, d=_qside_spec(ws, w.d.shape, mesh))
    elif isinstance(w, Q3KTensor):
        ws = _weight_spec(w.ql.shape, lin.role, mesh)
        spec_w = Q3KTensor(
            ql=ws, qh=_qside_spec(ws, w.qh.shape, mesh),
            scales=_qside_spec(ws, w.scales.shape, mesh),
            d=_qside_spec(ws, w.d.shape, mesh), scale_bits=w.scale_bits)
    else:
        spec_w = _weight_spec(w.shape, lin.role, mesh)
    spec_b = None
    if lin.b is not None:
        n_ax = _weight_spec((lin.b.shape[0], 1), lin.role, mesh)[0]
        spec_b = P(n_ax)
    return Linear(w=spec_w, b=spec_b, role=lin.role)


def heuristic_spec(shape: tuple, mesh: Mesh, *,
                   skip_dims: tuple = ()) -> P:
    """Greedy fallback for stacked caches / states: assign each mesh
    axis (largest first) to the largest unassigned divisible dim."""
    axes: list = [None] * len(shape)
    order = sorted(mesh.shape.items(), key=lambda kv: -kv[1])
    taken = set(skip_dims)
    for name, size in order:
        cands = [(d, shape[d]) for d in range(len(shape))
                 if d not in taken and axes[d] is None
                 and shape[d] % size == 0 and shape[d] >= size]
        if not cands:
            continue
        d = max(cands, key=lambda c: c[1])[0]
        axes[d] = name
        taken.add(d)
    return P(*axes)


def _stacked(spec: P, leaf_ndim: int, base_ndim: int) -> P:
    """Prepend None axes for the period-stacking dims vmap added."""
    extra = leaf_ndim - base_ndim
    return P(*([None] * extra), *spec)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """Sharding spec pytree matching a model parameter tree.

    Linear leaves (possibly stacked over scan periods: leading axes are
    replicated) follow ROLE_RULES; everything else is replicated.

    ``fsdp=False`` (serving): drop the K-dim `data` shard so weights
    are TP-sharded only — no per-layer weight all-gathers, and (for
    quantized models) only quantized bytes ever leave HBM.  Quantized
    params fit TP-only for every assigned arch (405B Q3_K: ~11 GB/chip).
    """
    def one(node):
        if isinstance(node, Linear):
            base = linear_specs(_unstack_linear(node), mesh)
            if not fsdp:
                base = jax.tree.map(
                    lambda sp: P(*[None if ax == "data" else ax
                                   for ax in sp]) if isinstance(sp, P)
                    else sp,
                    base, is_leaf=lambda x: isinstance(x, P))

            # Re-add leading stacked dims.
            def fix(spec_leaf, arr_leaf):
                if arr_leaf is None:
                    return None
                base_nd = len(tuple(spec_leaf))
                return _stacked(spec_leaf, arr_leaf.ndim, base_nd)
            return jax.tree.map(
                fix, base, node,
                is_leaf=lambda x: isinstance(x, P) or x is None)
        if isinstance(node, (Q8_0Tensor, Q4_0Tensor, Q3KTensor)):
            # Bare quantized tensors outside a Linear = flattened
            # quantized optimizer moments: shard dim0 over all axes
            # that divide (ZeRO for the quantized state).
            def flat_spec(a):
                ax = []
                prod = 1
                for name in ("data", "model", "pod"):
                    if name in mesh.shape and a.shape[0] % (
                            prod * mesh.shape[name]) == 0:
                        ax.append(name)
                        prod *= mesh.shape[name]
                lead = tuple(ax) if len(ax) > 1 else (ax[0] if ax else None)
                return P(lead, *([None] * (a.ndim - 1)))
            return jax.tree.map(flat_spec, node)
        return P()  # replicate norms & misc

    return jax.tree.map(
        one, params,
        is_leaf=lambda x: isinstance(
            x, (Linear, Q8_0Tensor, Q4_0Tensor, Q3KTensor)))


def _unstack_linear(lin: Linear) -> Linear:
    """View of a (possibly period-stacked) Linear with the logical
    trailing dims only — rules are written against logical (N, K)."""
    def last(a, nd):
        nd = min(nd, a.ndim)
        return jax.ShapeDtypeStruct(a.shape[-nd:], a.dtype)
    expert = lin.role.startswith("expert")
    nd = 3 if expert else 2
    w = lin.w
    if isinstance(w, (Q8_0Tensor, Q4_0Tensor)):
        w = type(w)(last(w.qs, nd), last(w.d, nd))
    elif isinstance(w, Q3KTensor):
        w = Q3KTensor(last(w.ql, nd), last(w.qh, nd),
                      last(w.scales, nd + 1), last(w.d, nd), w.scale_bits)
    else:
        w = last(w, nd)
    b = None
    if lin.b is not None:
        b = jax.ShapeDtypeStruct(lin.b.shape[-1:], lin.b.dtype)
    return Linear(w=w, b=b, role=lin.role)


def _data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def cache_specs(cache: Any, mesh: Mesh) -> Any:
    """Decode-cache specs.

    Leaves are period-stacked states: dim0 = period (replicated), dim1 =
    batch.  Batch shards over (pod, data) when divisible; the sequence /
    capacity dim of KV caches shards over `model` — and additionally
    over the data axes when batch can't use them (long_500k b=1:
    sequence-parallel decode).  SSM/xLSTM state feature dims shard over
    `model`.
    """
    data_axes = _data_axes(mesh)
    data_sz = int(np.prod([mesh.shape[a] for a in data_axes])) \
        if data_axes else 1
    msz = mesh.shape.get("model", 1)

    def leaf_spec(a):
        if a is None or not hasattr(a, "shape") or a.ndim < 2:
            return P()
        shape = a.shape
        axes: list = [None] * len(shape)
        b = shape[1]
        batch_on_data = data_axes and b % data_sz == 0
        if batch_on_data:
            axes[1] = data_axes if len(data_axes) > 1 else data_axes[0]
        # Choose the "big" dim: KV caches are (np,B,H,C,hd) -> dim 3;
        # states are (np,B,...) -> largest trailing dim.
        cands = [d for d in range(2, len(shape))]
        if not cands:
            return P(*axes)
        big = max(cands, key=lambda d: shape[d])
        want = ["model"] if batch_on_data else ["model", *data_axes]
        sz = 1
        got = []
        for ax in want:
            if shape[big] % (sz * mesh.shape[ax]) == 0:
                got.append(ax)
                sz *= mesh.shape[ax]
        if got:
            axes[big] = tuple(got) if len(got) > 1 else got[0]
        return P(*axes)
    return jax.tree.map(leaf_spec, cache)


def batch_specs(tree: Any, mesh: Mesh) -> Any:
    """Input batch: dim0 (global batch) over all data-ish axes that
    divide it; everything else replicated."""
    data_axes = [a for a in ("pod", "data") if a in mesh.shape]

    def leaf_spec(a):
        if a is None or not hasattr(a, "shape") or a.ndim == 0:
            return P()
        b = a.shape[0]
        use = []
        prod = 1
        for ax in data_axes:
            if b % (prod * mesh.shape[ax]) == 0:
                use.append(ax)
                prod *= mesh.shape[ax]
        spec = [tuple(use) if use else None] + [None] * (a.ndim - 1)
        return P(*spec)
    return jax.tree.map(leaf_spec, tree)


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
