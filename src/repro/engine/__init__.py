"""Unified request-based serving engine (diffusion + LM decode + ASR):
typed requests, streaming event lifecycle, SLO-aware multiplexing."""
from repro.engine.api import (Engine, GenerateRequest, GenerateResult,
                              TranscribeRequest, default_sampler, uses_cfg)
from repro.engine.asr_engine import AsrEngine, audio_fingerprint
from repro.engine.config import (AsrEngineConfig, DiffusionEngineConfig,
                                 EngineConfig, LMEngineConfig,
                                 SpecDecodeConfig, build_engine)
from repro.engine.diffusion_engine import (SD_TURBO, TINY_SD, DiffusionEngine,
                                           SDConfig, build_denoise,
                                           build_denoise_step, build_encode,
                                           build_finalize_decode,
                                           init_pipeline, quantize_pipeline,
                                           steps_bucket)
from repro.engine.costmodel import CostModel, calibrate
from repro.engine.events import (Admitted, Cancelled, Event, EventBus,
                                 Finished, Preempted, PreviewLatent, Progress,
                                 Rejected, RequestHandle, TokenDelta)
from repro.engine.fleet import (FaultInjector, FleetManager, ReplicaFault,
                                ReplicaSpec)
from repro.engine.results import (ImageResult, LMResult, RequestStats,
                                  TerminalResult, TranscriptResult)
from repro.engine.router import EngineRouter
from repro.engine.samplers import (get_sampler, list_samplers,
                                   register_sampler)

__all__ = [
    "Engine", "GenerateRequest", "GenerateResult", "TranscribeRequest",
    "default_sampler", "uses_cfg",
    "AsrEngine", "audio_fingerprint",
    "EngineConfig", "LMEngineConfig", "AsrEngineConfig",
    "DiffusionEngineConfig", "SpecDecodeConfig", "build_engine",
    "TerminalResult", "RequestStats", "LMResult", "TranscriptResult",
    "ImageResult",
    "DiffusionEngine", "SDConfig", "SD_TURBO", "TINY_SD",
    "build_denoise", "build_denoise_step", "build_encode",
    "build_finalize_decode", "init_pipeline", "quantize_pipeline",
    "steps_bucket",
    "CostModel", "calibrate",
    "Event", "EventBus", "RequestHandle", "Admitted", "TokenDelta",
    "PreviewLatent", "Progress", "Preempted", "Cancelled", "Rejected",
    "Finished",
    "EngineRouter",
    "FleetManager", "ReplicaSpec", "ReplicaFault", "FaultInjector",
    "get_sampler", "list_samplers", "register_sampler",
]
