"""Unified request-based serving engine (diffusion + LM decode)."""
from repro.engine.api import (Engine, GenerateRequest, GenerateResult,
                              default_sampler, uses_cfg)
from repro.engine.diffusion_engine import (SD_TURBO, TINY_SD, DiffusionEngine,
                                           SDConfig, build_denoise,
                                           init_pipeline, quantize_pipeline,
                                           steps_bucket)
from repro.engine.samplers import (get_sampler, list_samplers,
                                   register_sampler)

__all__ = [
    "Engine", "GenerateRequest", "GenerateResult", "default_sampler",
    "uses_cfg",
    "DiffusionEngine", "SDConfig", "SD_TURBO", "TINY_SD",
    "build_denoise", "init_pipeline", "quantize_pipeline", "steps_bucket",
    "get_sampler", "list_samplers", "register_sampler",
]
