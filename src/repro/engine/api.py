"""Request-based generation API shared by diffusion and LM serving.

The paper treats Stable Diffusion as a *serving* workload (the
stable-diffusion.cpp path profiled on IMAX3), and its companion LLM
study serves decode on the same platform.  Both workloads therefore
share one engine surface:

* a typed request (``GenerateRequest`` for text-to-image; the LM path
  keeps its own ``serving.scheduler.Request``) is ``submit()``-ed and
  a :class:`repro.engine.events.RequestHandle` comes back — iterate
  ``handle.events()`` to stream the request's typed lifecycle events
  (``Admitted``/``TokenDelta``/``PreviewLatent``/…), or call
  ``handle.result()`` to just wait for the payload;
* ``step()`` advances the engine by one scheduling quantum — one
  micro-batched denoise program (or one denoise *segment* for
  preview-streaming requests) for diffusion, one prefill chunk or
  batched decode step for the LM ``ContinuousBatcher`` — and returns
  how many requests it touched;
* ``stream()`` is the push-style host loop: a generator that steps the
  engine and yields every event in emission order;
* ``cancel(rid)`` aborts a request at any lifecycle point and frees
  its state (queue entry, slot, KV blocks);
* ``run()`` is retained as a thin drain-the-stream compatibility
  wrapper: it drives ``step()`` until idle and returns the finished
  results, so pre-streaming callers keep working unchanged (and, with
  no deadlines submitted, in bit-identical order).

Requests carry optional SLO fields — ``deadline_ms`` (relative
latency budget from submission) and ``priority`` — consumed by the
engines' earliest-deadline-first admission and by
:class:`repro.engine.router.EngineRouter`'s SLO-aware multiplexing.
With a :class:`repro.engine.costmodel.CostModel` attached, the budget
also feeds feasibility admission control: ``submit()`` emits a
terminal :class:`~repro.engine.events.Rejected` (estimated service
time vs budget) instead of enqueueing a request that provably cannot
meet its deadline, and the router multiplexes on estimated *slack*
rather than the raw deadline.

``Engine`` is a structural :class:`typing.Protocol`:
``DiffusionEngine`` and ``ContinuousBatcher`` both satisfy it without
inheriting from a common base, so host-side schedulers (the paper's
"host" role) can drive either workload through the same loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, runtime_checkable

import jax


def default_sampler(steps: int) -> str:
    """Paper default: SD-Turbo for single-step, DDIM otherwise."""
    return "turbo" if steps == 1 else "ddim"


def uses_cfg(neg_tokens, guidance_scale: float) -> bool:
    """Whether classifier-free guidance changes the output (and thus
    which of the two compiled program variants a request needs)."""
    return neg_tokens is not None or guidance_scale != 1.0


@dataclasses.dataclass
class GenerateRequest:
    """One text-to-image generation request.

    ``tokens``/``neg_tokens`` are prompt token ids of length
    ``cfg.text_len`` (list or array).  ``guidance_scale`` is the
    classifier-free-guidance weight: ``eps = eps_uncond +
    scale * (eps_cond - eps_uncond)``; ``1.0`` with no negative prompt
    disables the unconditional branch entirely.  ``seed`` alone
    determines the initial latent noise, so the same request is
    bit-identical whether it runs alone or co-batched.

    ``latent_hw`` selects a per-request latent size (a shape bucket in
    the engine's compile cache; mixed sizes never co-batch).
    ``preview_every`` > 0 streams a
    :class:`~repro.engine.events.PreviewLatent` event every N denoise
    steps (the request then runs on the segmented per-step program
    instead of the fused scan).  ``deadline_ms``/``priority`` feed EDF
    admission: earlier deadline first, higher priority breaks ties,
    arrival order last.
    """
    rid: int
    tokens: Sequence[int] | jax.Array
    neg_tokens: Sequence[int] | jax.Array | None = None
    guidance_scale: float = 1.0
    sampler: str = "turbo"
    steps: int = 1
    seed: int = 0
    latent_hw: int | None = None    # None -> engine config default
    preview_every: int = 0          # 0 -> no previews (fused scan path)
    preview_decode: bool = False    # previews carry VAE-decoded pixels
    deadline_ms: float | None = None  # SLO budget from submission
    priority: int = 0               # higher wins EDF ties
    # Absolute deadline on the engine's clock, set at submission.  A
    # declared field (mirroring serving.Request._deadline) so a request
    # migrated across replicas keeps its original budget instead of
    # restarting it at adoption.
    _deadline: float = dataclasses.field(default=float("inf"), repr=False)


@dataclasses.dataclass
class TranscribeRequest:
    """One streaming speech-transcription request (ASR modality).

    ``audio`` is the pre-extracted frame-embedding tensor
    ``(cfg.encoder_seq, cfg.d_model)`` the stub conv frontend would
    produce (``models.frontend``); the engine ingests it in
    ``audio_chunk``-frame quanta (streaming audio admission, mirroring
    chunked prompt prefill) and encodes incrementally.  ``prompt`` is
    the decoder's token prefix (language/task tags for Whisper); the
    transcript accumulates in ``out`` and the request object doubles as
    its own ``Finished`` result, like the LM path's
    ``serving.scheduler.Request``.

    ``group`` co-schedules requests round-robin;
    ``deadline_ms``/``priority`` feed the same EDF + cost-model
    admission as the other modalities.  ``encode_steps`` /
    ``prefill_steps`` / ``decode_steps`` bill the scheduling quanta the
    request consumed, per phase.
    """
    rid: int
    audio: Any                       # (encoder_seq, d_model) array
    prompt: Sequence[int] = ()
    max_new: int = 16
    eos: int | None = None
    group: int = 0
    deadline_ms: float | None = None
    priority: int = 0
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    encode_steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    _seq: int = dataclasses.field(default=0, repr=False)
    _deadline: float = dataclasses.field(default=float("inf"), repr=False)
    # Tokens still to ingest (prompt at first admission; prompt + out
    # after a preemption resume) — mirrors serving.Request._feed.
    _feed: list = dataclasses.field(default_factory=list, repr=False)
    # Per-frame content fingerprints of ``audio`` (computed once at
    # submit) — the cross-pool prefix-cache key chain.
    _audio_key: list = dataclasses.field(default_factory=list, repr=False)


@dataclasses.dataclass
class GenerateResult:
    """Finished request: decoded image plus the settings that made it.

    ``prefill_steps``/``decode_steps`` report the scheduling quanta the
    request consumed — the same accounting the LM path keeps on
    ``serving.scheduler.Request``, so mixed-workload hosts can bill and
    load-balance both engines uniformly.  For diffusion, ingestion is
    free (prompts ride into the denoise program) and every denoise step
    is a decode quantum.
    """
    rid: int
    image: jax.Array                # (H, W, 3) in [-1, 1]
    sampler: str
    steps: int
    seed: int
    prefill_steps: int = 0          # quanta spent ingesting the prompt
    decode_steps: int = 0           # quanta spent generating


@runtime_checkable
class Engine(Protocol):
    """Structural protocol every serving engine implements."""

    def submit(self, request: Any) -> Any:
        """Enqueue a request (admission happens inside ``step``);
        returns a :class:`repro.engine.events.RequestHandle`."""
        ...

    def step(self) -> int:
        """Advance one scheduling quantum; return #requests progressed."""
        ...

    def stream(self, max_steps: int = 100_000) -> Any:
        """Generator: step the engine, yielding typed lifecycle events
        in emission order, until it idles."""
        ...

    def cancel(self, rid: int) -> bool:
        """Abort a request (queued or running) and free its state;
        True if the rid was live."""
        ...

    def has_work(self) -> bool:
        """Whether any request is queued or in flight."""
        ...

    def run(self, max_steps: int = 10_000) -> list:
        """Drive ``step`` until the queue drains; return finished items
        (drain-the-stream compatibility wrapper)."""
        ...
