"""Request-based generation API shared by diffusion and LM serving.

The paper treats Stable Diffusion as a *serving* workload (the
stable-diffusion.cpp path profiled on IMAX3), and its companion LLM
study serves decode on the same platform.  Both workloads therefore
share one engine surface:

* a typed request (``GenerateRequest`` for text-to-image; the LM path
  keeps its own ``serving.scheduler.Request``) is ``submit()``-ed;
* ``step()`` advances the engine by one scheduling quantum — one
  micro-batched denoise program for diffusion, one batched decode step
  for the LM ``ContinuousBatcher`` — and returns how many requests it
  touched;
* ``run()`` drains the queue and returns the finished results.

``Engine`` is a structural :class:`typing.Protocol`:
``DiffusionEngine`` and ``ContinuousBatcher`` both satisfy it without
inheriting from a common base, so host-side schedulers (the paper's
"host" role) can drive either workload through the same loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, Sequence, runtime_checkable

import jax


def default_sampler(steps: int) -> str:
    """Paper default: SD-Turbo for single-step, DDIM otherwise."""
    return "turbo" if steps == 1 else "ddim"


def uses_cfg(neg_tokens, guidance_scale: float) -> bool:
    """Whether classifier-free guidance changes the output (and thus
    which of the two compiled program variants a request needs)."""
    return neg_tokens is not None or guidance_scale != 1.0


@dataclasses.dataclass
class GenerateRequest:
    """One text-to-image generation request.

    ``tokens``/``neg_tokens`` are prompt token ids of length
    ``cfg.text_len`` (list or array).  ``guidance_scale`` is the
    classifier-free-guidance weight: ``eps = eps_uncond +
    scale * (eps_cond - eps_uncond)``; ``1.0`` with no negative prompt
    disables the unconditional branch entirely.  ``seed`` alone
    determines the initial latent noise, so the same request is
    bit-identical whether it runs alone or co-batched.
    """
    rid: int
    tokens: Sequence[int] | jax.Array
    neg_tokens: Sequence[int] | jax.Array | None = None
    guidance_scale: float = 1.0
    sampler: str = "turbo"
    steps: int = 1
    seed: int = 0
    latent_hw: int | None = None    # None -> engine config default


@dataclasses.dataclass
class GenerateResult:
    """Finished request: decoded image plus the settings that made it.

    ``prefill_steps``/``decode_steps`` report the scheduling quanta the
    request consumed — the same accounting the LM path keeps on
    ``serving.scheduler.Request``, so mixed-workload hosts can bill and
    load-balance both engines uniformly.  For diffusion, ingestion is
    free (prompts ride into the denoise program) and every denoise step
    is a decode quantum.
    """
    rid: int
    image: jax.Array                # (H, W, 3) in [-1, 1]
    sampler: str
    steps: int
    seed: int
    prefill_steps: int = 0          # quanta spent ingesting the prompt
    decode_steps: int = 0           # quanta spent generating


@runtime_checkable
class Engine(Protocol):
    """Structural protocol every serving engine implements."""

    def submit(self, request: Any) -> None:
        """Enqueue a request (admission happens inside ``step``)."""
        ...

    def step(self) -> int:
        """Advance one scheduling quantum; return #requests progressed."""
        ...

    def run(self, max_steps: int = 10_000) -> list:
        """Drive ``step`` until the queue drains; return finished items."""
        ...
