"""Streaming ASR engine: encoder-decoder serving over two paged pools.

The paper positions the CGLA as a *general-purpose* on-device AI
platform; its companion Whisper study (PAPERS.md) serves streaming
encoder-decoder speech recognition on the same hardware.  This module
is the third modality behind :class:`repro.engine.router.EngineRouter`
— a :class:`repro.engine.api.Engine`-protocol scheduler for
Whisper-style transcription, structurally mirroring the LM
``serving.ContinuousBatcher`` with one extra phase and one extra pool:

* **Streaming audio ingestion** — a
  :class:`~repro.engine.api.TranscribeRequest` carries pre-extracted
  frame embeddings ``(encoder_seq, d_model)``; admission feeds them in
  ``audio_chunk``-frame *encode quanta* (mirroring chunked prompt
  prefill).  Each quantum is ONE jitted program: the chunk lands in
  the slot's row of a persistent frame buffer, the full (non-causal)
  encoder re-runs over that row, and every layer's K/V projections are
  scattered into the slot's **cross-attention blocks** — so the final
  chunk's program leaves exactly the one-shot encoder KV
  (chunked == one-shot, oracle-gated in tests).
* **Paged cross-attention pool** — encoder KV lives in a second
  refcounted block pool on :class:`repro.serving.kvcache.PagedKVRuntime`
  (``cross_len=encoder_seq``).  With ``audio_share=True`` a finished
  encode publishes its chain to the audio prefix cache (keyed on
  per-frame content fingerprints); a later request with the *same*
  audio adopts every block read-only and skips the encode entirely.
  Adoption is all-or-nothing: the encoder is non-causal, so a partial
  frame prefix has no reusable KV.
* **Fused enc-dec decoder prefill** — decoder self-attention rides the
  ordinary paged pool, and whisper's pure-attention decoder is
  fused-prefill eligible (``prefill_path``): each prompt chunk is one
  fused paged flash-prefill program per layer plus one chunk-at-once
  paged cross-attention read per layer, instead of a per-token
  decode-step scan (``prefill_launches`` counts the difference; the
  scan path remains the retained bit-exactness oracle).
* **Decoder-pool prefix sharing stays OFF** — decoder self-attention
  KV depends on the audio through the cross-attention residuals, so a
  token-keyed prefix adoption across requests with different audio
  would be wrong.  Audio sharing (above) is the sound ASR analogue.
* **Lifecycle / SLO parity** — EDF-within-fairness-groups admission,
  cost-model feasibility rejection at submit (``encode-chunk`` /
  ``prefill`` / ``decode-token`` phase keys, plus the queueing-delay
  term shared with the other engines), per-quantum EWMA observations,
  ``TokenDelta`` transcript streaming, cancellation and preemption
  releasing BOTH pools, and ``evacuate``/``adopt`` fleet hooks
  (re-admission re-adopts a published audio chain, so migration skips
  the re-encode and resumes bit-exactly via chunked re-prefill).

``step()`` runs one quantum, encode-prioritized: pending audio chunks
first, then pending prompt chunks, else one batched decode step.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.engine import events as ev
from repro.engine.api import TranscribeRequest
from repro.engine.config import EngineConfig, UNSET, resolve
from repro.models.transformer import (cache_slot_merge, cache_slot_reset,
                                      cache_slot_view, encoder_forward,
                                      init_cache, lm_decode_step,
                                      lm_prefill_chunk, prefill_path,
                                      write_cross_kv)
from repro.serving.kvcache import PagedKVRuntime, cdiv

DEFAULT_BLOCK = 16
DEFAULT_AUDIO_CHUNK = 16


def audio_fingerprint(audio: Any) -> list[int]:
    """Per-frame content fingerprints of an audio embedding tensor —
    the cross-pool prefix-cache key chain (host-side, hashed row
    bytes; stable within a process, which is the cache's lifetime)."""
    a = np.asarray(audio)
    return [hash(a[f].tobytes()) for f in range(a.shape[0])]


def make_asr_encode(cfg: ModelConfig):
    """One streaming encode quantum as a single jitted program:
    scatter the frame chunk into the slot's row of the persistent
    frame buffer, re-run the full non-causal encoder over that row,
    and write every layer's cross K/V into the slot's cross blocks.
    The final chunk therefore leaves exactly the one-shot encoder KV;
    intermediate chunks' writes are transient (overwritten by the next
    quantum).  Compiled once per distinct chunk length."""
    def encode(params, frames, f0, slot, cross_row, frame_buf, cache):
        frame_buf = jax.lax.dynamic_update_slice(
            frame_buf, frames.astype(frame_buf.dtype),
            (slot, f0, jnp.int32(0)))
        buf = jax.lax.dynamic_slice_in_dim(frame_buf, slot, 1, axis=0)
        enc_out = encoder_forward(params, cfg, buf)
        cache = write_cross_kv(params, cfg, enc_out, cross_row, cache)
        return frame_buf, cache
    return jax.jit(encode, donate_argnums=(5, 6))


def make_asr_prefill(cfg: ModelConfig, *, fused: bool = True):
    """Batch-1 chunked decoder prefill for one slot: slot view with the
    cross pools passed through (``paged_cross``), self-attention KV via
    the slot's block-table row, cross attention via its cross-table
    row.  Fused (one paged flash-prefill program + one paged cross
    read per layer per chunk) or the reference decode-step scan."""
    def prefill(params, tokens, pos0, slot, block_row, cross_row, cache):
        local = cache_slot_view(cache, slot, paged_cross=True)
        logits, local = lm_prefill_chunk(params, cfg, tokens, pos0, local,
                                         block_tables=block_row,
                                         cross_tables=cross_row,
                                         fused=fused)
        cache = cache_slot_merge(cache, local, slot)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache
    return jax.jit(prefill, donate_argnums=(6,))


def make_asr_decode(cfg: ModelConfig):
    """Greedy decode step at the fixed slot-batch shape: paged
    self-attention KV plus a paged cross-attention read per layer."""
    def step(params, tokens, positions, block_tables, cross_tables, cache):
        logits, cache = lm_decode_step(params, cfg, tokens, positions,
                                       cache, block_tables=block_tables,
                                       cross_tables=cross_tables)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache
    return jax.jit(step, donate_argnums=(5,))


class AsrEngine(ev.EventStreamMixin):
    """Whisper-style encoder-decoder transcription engine.

    ``max_len`` is the per-request *decoder* capacity (prompt +
    max_new - 1, like the LM batcher); the encoder span is fixed at
    ``cfg.encoder_seq`` frames per request.  ``audio_share=True``
    (default) enables the audio prefix cache: identical audio across
    requests shares cross blocks read-only and skips re-encoding.
    ``decode_fn`` must follow :func:`make_asr_decode`'s signature.
    ``clock`` is the SLO/event timebase (injectable for deterministic
    tests and virtual-time benchmarks)."""

    def __init__(self, params: Any, cfg: ModelConfig, *,
                 config: EngineConfig | None = None,
                 slots: int = UNSET, max_len: int = UNSET,
                 decode_fn: Callable | None = UNSET,
                 quantized_kv: bool = UNSET,
                 weight_quant: str | None = UNSET,
                 block_size: int = UNSET,
                 cross_block_size: int | None = UNSET,
                 audio_chunk: int = UNSET,
                 prefill_chunk: int = UNSET,
                 audio_share: bool = UNSET,
                 extra_blocks: int = UNSET,
                 fused_prefill: bool = UNSET,
                 bus: ev.EventBus | None = UNSET,
                 clock: Callable[[], float] = UNSET,
                 edf: bool = UNSET,
                 cost_model=UNSET, metrics=UNSET):
        # Config-first construction (PR 10): the loose kwargs are a
        # deprecation shim resolved onto config.asr — explicit kwargs
        # win, gated bit-identical in tests/test_engine_config.py.
        self.config, asrc = resolve(config, "asr", dict(
            slots=slots, max_len=max_len, decode_fn=decode_fn,
            quantized_kv=quantized_kv, weight_quant=weight_quant,
            block_size=block_size, cross_block_size=cross_block_size,
            audio_chunk=audio_chunk, prefill_chunk=prefill_chunk,
            audio_share=audio_share, extra_blocks=extra_blocks,
            fused_prefill=fused_prefill, bus=bus, clock=clock, edf=edf,
            cost_model=cost_model, metrics=metrics))
        if asrc.max_len is None:
            raise ValueError("max_len is required (pass max_len= or "
                             "config.asr.max_len)")
        (slots, max_len, decode_fn, quantized_kv, block_size,
         cross_block_size, audio_chunk, prefill_chunk, audio_share,
         extra_blocks, fused_prefill) = (
            asrc.slots, asrc.max_len, asrc.decode_fn, asrc.quantized_kv,
            asrc.block_size, asrc.cross_block_size, asrc.audio_chunk,
            asrc.prefill_chunk, asrc.audio_share, asrc.extra_blocks,
            asrc.fused_prefill)
        weight_quant = self.config.weight_quant
        bus, clock, edf = (self.config.bus, self.config.clock,
                           self.config.edf)
        cost_model, metrics = (self.config.cost_model,
                               self.config.metrics)
        if not cfg.is_enc_dec:
            raise ValueError(
                f"AsrEngine needs an encoder-decoder config, got "
                f"{cfg.name} (is_enc_dec=False)")
        if weight_quant is not None:
            params = quantize_params(params, get_policy(weight_quant))
        self.weight_quant = weight_quant
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.audio_chunk = max(1, audio_chunk)
        self.audio_share = audio_share
        self.metrics = metrics
        cbs = cross_block_size or block_size
        cross_bps = cdiv(cfg.encoder_seq, cbs)
        self.runtime = PagedKVRuntime(
            slots, max_len, block_size, extra_blocks=extra_blocks,
            cross_len=cfg.encoder_seq, cross_block_size=cbs,
            # Headroom so published audio chains survive slot turnover
            # without blocking fresh admissions.
            cross_extra_blocks=(slots * cross_bps if audio_share else 0),
            cross_prefix_share=audio_share, metrics=metrics)
        self.cache = init_cache(
            params, cfg, slots, max_len, quantized_kv=quantized_kv,
            block_size=block_size, num_blocks=self.runtime.num_blocks,
            cross_block_size=cbs,
            cross_num_blocks=self.runtime.cross_num_blocks)
        # Per-slot streaming frame buffer: chunks accumulate here so
        # every encode quantum sees all frames ingested so far.
        self._frame_buf = jnp.zeros(
            (slots, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        # Same single source of truth as the LM batcher: launch
        # accounting and cost-model keys describe the executed path.
        self.fused_prefill = prefill_path(
            cfg, quantized_kv=quantized_kv, fused=fused_prefill) == "fused"
        self.step_fn = decode_fn or make_asr_decode(cfg)
        self._prefill_raw = make_asr_prefill(cfg, fused=self.fused_prefill)
        self._encode_fn = make_asr_encode(cfg)
        self._reset_fn = jax.jit(cache_slot_reset, donate_argnums=(0,))
        self.slots: list[TranscribeRequest | None] = [None] * slots
        self._pending: list[list[int]] = [[] for _ in range(slots)]
        self._audio_left = [0] * slots    # frames still to ingest
        self._next_tok = np.zeros(slots, np.int32)
        self.finished: list[TranscribeRequest] = []
        self._groups: "OrderedDict[int, list]" = OrderedDict()
        self._rr: deque[int] = deque()
        self.bus = bus if bus is not None else ev.EventBus(clock)
        self.edf = edf
        self.quantized_kv = quantized_kv
        self.cost_model = cost_model
        self.rejections = 0
        self._cm_warm: set = set()
        self.preemptions = 0
        self._subseq = 0
        self.encode_quanta = 0
        self.prefill_quanta = 0
        self.decode_quanta = 0
        self.audio_hits = 0               # requests that skipped encode
        # Admission cost in kernel launches (same acceptance metric as
        # the LM batcher: fused admission is strictly fewer launches).
        self.prefill_launches = 0
        self.last_quantum: tuple[str, int] | None = None

    # ------------------------------------------------------------ sizing
    @staticmethod
    def required_len(prompt_len: int, max_new: int) -> int:
        """Per-request decoder capacity: positions
        ``0 .. prompt_len + max_new - 2`` (the final token is emitted,
        never cached)."""
        return prompt_len + max_new - 1

    # --------------------------------------------------------------- API
    def submit(self, req: TranscribeRequest) -> ev.RequestHandle:
        if not req.prompt:
            raise ValueError(
                "TranscribeRequest needs a non-empty decoder prompt "
                "(Whisper task/language tags)")
        need = len(req.prompt) + req.max_new - 1
        if need > self.max_len:
            raise ValueError(
                f"prompt {len(req.prompt)} + max_new {req.max_new} needs "
                f"capacity {need} > per-request max_len={self.max_len}")
        a = np.asarray(req.audio)
        want = (self.cfg.encoder_seq, self.cfg.d_model)
        if a.shape != want:
            raise ValueError(f"audio shape {a.shape} != {want} "
                             f"(encoder_seq, d_model)")
        if (self.bus.terminal(req.rid) is not None
                or self.bus.admitted(req.rid)
                or any(r.rid == req.rid
                       for q in self._groups.values() for r in q)):
            raise ValueError(f"duplicate rid {req.rid}")
        req._seq = self._subseq
        self._subseq += 1
        req._deadline = (float("inf") if req.deadline_ms is None
                         else self.bus.clock() + req.deadline_ms / 1e3)
        if not req._feed:
            req._feed = list(req.prompt)
        if not req._audio_key:
            req._audio_key = audio_fingerprint(a)
        if self.metrics is not None:
            self.metrics.request_submitted(req.rid, "asr",
                                           self.bus.clock())
        if self.cost_model is not None and req.deadline_ms is not None:
            est = self.cost_model.estimate_asr(self, req)
            if est is not None:
                # Queueing-delay-aware admission: a feasible-in-
                # isolation request behind a deep queue is rejected up
                # front instead of expiring while it waits.
                est += self.cost_model.queue_wait(self)
            budget = req.deadline_ms / 1e3
            if est is not None and est > budget:
                self.rejections += 1
                self.bus.emit(ev.Rejected, req.rid, estimated_s=est,
                              budget_s=budget, reason="infeasible")
                return self.handle(req.rid)
        self._enqueue(req)
        return self.handle(req.rid)

    def _enqueue(self, req: TranscribeRequest) -> None:
        if req.group not in self._groups:
            self._groups[req.group] = []
            self._rr.append(req.group)
        self._groups[req.group].append(req)

    @property
    def queue_len(self) -> int:
        return sum(len(q) for q in self._groups.values())

    def has_work(self) -> bool:
        return bool(self.queue_len) or any(s is not None
                                           for s in self.slots)

    def next_deadline(self) -> float:
        cands = [r._deadline for q in self._groups.values() for r in q]
        cands += [r._deadline for r in self.slots if r is not None]
        return min(cands, default=float("inf"))

    def next_slack(self) -> float:
        """Minimum estimated slack (deadline - now - estimated
        remaining service) over queued + running requests; +inf when
        none declares a deadline (router multiplex key)."""
        cm = self.cost_model
        now = self.bus.clock()
        best = float("inf")
        for q in self._groups.values():
            for r in q:
                if r._deadline == float("inf"):
                    continue
                est = cm.estimate_asr(self, r) if cm else None
                best = min(best, r._deadline - now - (est or 0.0))
        for i, r in enumerate(self.slots):
            if r is None or r._deadline == float("inf"):
                continue
            est = cm.remaining_asr(self, i) if cm else None
            best = min(best, r._deadline - now - (est or 0.0))
        return best

    # ------------------------------------------- feasibility admission
    def _infeasible(self, req: TranscribeRequest,
                    now: float) -> tuple[bool, Any]:
        if req._deadline == float("inf"):
            return False, None
        est = self.cost_model.estimate_asr(self, req)
        if req._deadline < now:
            return True, est
        return (est is not None and now + est > req._deadline), est

    def _reject(self, req: TranscribeRequest, est, now: float) -> None:
        self.rejections += 1
        self.bus.emit(ev.Rejected, req.rid, estimated_s=est or 0.0,
                      budget_s=req._deadline - now,
                      reason="expired" if req._deadline < now
                      else "infeasible")

    def _sweep_infeasible(self) -> None:
        now = self.bus.clock()
        for q in self._groups.values():
            keep = []
            for r in q:
                hopeless, est = self._infeasible(r, now)
                if hopeless:
                    self._reject(r, est, now)
                else:
                    keep.append(r)
            q[:] = keep

    def _edf_key(self, req: TranscribeRequest) -> tuple:
        if not self.edf:
            return (req._seq,)
        expired = req._deadline < self.bus.clock()
        return (expired, req._deadline, -req.priority, req._seq)

    def _pop_round_robin(self) -> TranscribeRequest | None:
        while self._rr:
            gid = self._rr[0]
            if not self._groups[gid]:
                self._rr.popleft()
                del self._groups[gid]
                continue
            self._rr.rotate(-1)
            q = self._groups[gid]
            best = min(range(len(q)), key=lambda i: self._edf_key(q[i]))
            return q.pop(best)
        return None

    def _requeue_front(self, req: TranscribeRequest) -> None:
        self._groups[req.group].insert(0, req)
        self._rr.rotate(1)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue_len:
                continue
            while True:
                req = self._pop_round_robin()
                if req is None or self.cost_model is None:
                    break
                now = self.bus.clock()
                hopeless, est = self._infeasible(req, now)
                if not hopeless:
                    break
                self._reject(req, est, now)
            if req is None:
                break
            remaining = req.max_new - len(req.out)
            reused = self.runtime.admit(i, req._feed, remaining)
            if reused is None:           # decoder pool pressure
                self._requeue_front(req)
                break
            adopted = self.runtime.admit_cross(i, req._audio_key)
            if adopted is None:          # cross pool pressure: full
                self.runtime.release(i)  # rollback, try again later
                self._requeue_front(req)
                break
            self.slots[i] = req
            self._pending[i] = list(req._feed[reused:])
            if adopted:
                self._audio_left[i] = 0  # whole chain shared: no encode
                self.audio_hits += 1
            else:
                self._audio_left[i] = self.cfg.encoder_seq
            self.cache = self._reset_fn(self.cache, jnp.int32(i))
            if self.bus.admitted(req.rid):   # back from preemption
                self.bus.emit(ev.Progress, req.rid, phase="resume",
                              step=len(req.out), total=req.max_new)
            else:
                self.bus.emit(ev.Admitted, req.rid, slot=i)

    def _preempt_slot(self, i: int, reason: str) -> None:
        req = self.slots[i]
        self.runtime.release(i)
        self.runtime.release_cross(i)
        self.slots[i] = None
        self._pending[i] = []
        self._audio_left[i] = 0
        # Resume re-ingests prompt + generated-so-far; the audio chain,
        # if published, is re-adopted at re-admission (encode skipped).
        req._feed = list(req.prompt) + list(req.out)
        self.preemptions += 1
        self.bus.emit(ev.Preempted, req.rid, reason=reason)
        self._enqueue(req)

    def preempt(self, rid: int, reason: str = "explicit") -> bool:
        """Evict a running request back to the wait queue (both pools
        released, resume via re-adopt + re-prefill); True if ``rid``
        held a slot."""
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self._preempt_slot(i, reason)
                return True
        return False

    # ------------------------------------------- fleet migration hooks
    def evacuate(self, reason: str = "evacuate") -> list:
        """Drain hook for fleet migration: preempt every running
        request and pop every queued one; returns them in arrival
        order with no terminal events, for a surviving replica to
        ``adopt()``."""
        for i, r in enumerate(self.slots):
            if r is not None:
                self._preempt_slot(i, reason)
        out = [r for q in self._groups.values() for r in q]
        self._groups.clear()
        self._rr.clear()
        out.sort(key=lambda r: r._seq)
        return out

    def adopt(self, req: TranscribeRequest) -> ev.RequestHandle:
        """Admit a request evacuated from another engine on the same
        shared bus: no duplicate-rid guard, no submit-time rejection,
        and the original absolute deadline is kept.  The adopting
        engine re-encodes the audio from scratch (its own cross pool
        has no published chain for it), which is bit-exact — the
        encode is a pure function of the audio."""
        need = len(req.prompt) + req.max_new - 1
        if need > self.max_len:
            raise ValueError(
                f"adopted rid {req.rid} needs capacity {need} > "
                f"per-request max_len={self.max_len}")
        req._feed = list(req.prompt) + list(req.out)
        if not req._audio_key:
            req._audio_key = audio_fingerprint(req.audio)
        req._seq = self._subseq
        self._subseq += 1
        self._enqueue(req)
        return self.handle(req.rid)

    def cancel(self, rid: int) -> bool:
        """Abort a request wherever it is; a running request frees its
        slot AND both pools' blocks immediately (decoder self-KV and
        encoder cross-KV); emits terminal ``Cancelled``."""
        for gid, q in self._groups.items():
            for r in q:
                if r.rid == rid:
                    q.remove(r)
                    self.bus.emit(ev.Cancelled, rid)
                    return True
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self.runtime.release(i)
                self.runtime.release_cross(i)
                self.slots[i] = None
                self._pending[i] = []
                self._audio_left[i] = 0
                self.runtime.check_consistency()
                self.bus.emit(ev.Cancelled, rid)
                return True
        return False

    # ------------------------------------------------------- scheduling
    def step(self) -> int:
        """One scheduling quantum, encode-prioritized: pending audio
        chunks first, then pending prompt chunks, else one batched
        decode step; returns the number of requests progressed."""
        if self.cost_model is not None and self.queue_len:
            self._sweep_infeasible()
        self._admit()
        self._obs_sched()
        for i, req in enumerate(self.slots):
            if req is not None and self._audio_left[i]:
                return self._encode_quantum(i)
        for i, req in enumerate(self.slots):
            if req is not None and self._pending[i]:
                return self._prefill_quantum(i)
        return self._decode_quantum()

    def _obs_quantum(self, kind: str, t0: float, out, rids: list,
                     args: dict | None = None) -> None:
        if self.metrics is None:
            return
        jax.block_until_ready(out)
        self.metrics.phase("asr", kind, t0, self.bus.clock(),
                           rids=rids, args=args)

    def _obs_sched(self) -> None:
        if self.metrics is None:
            return
        self.metrics.gauge(
            "engine_queue_depth", "queued requests by engine",
            labels=("engine",)).set(self.queue_len, engine="asr")
        self.metrics.gauge(
            "asr_slots_active", "occupied transcription slots").set(
            sum(1 for s in self.slots if s is not None))

    def _observe_quantum(self, key: tuple, shape: tuple,
                         t0: float, out) -> None:
        if self.cost_model is None:
            return
        if shape not in self._cm_warm:
            self._cm_warm.add(shape)
            return
        jax.block_until_ready(out)
        self.cost_model.observe(key, self.bus.clock() - t0)

    def _encode_quantum(self, i: int) -> int:
        t0 = self.bus.clock()
        req = self.slots[i]
        se = self.cfg.encoder_seq
        cursor = se - self._audio_left[i]
        n = min(self.audio_chunk, self._audio_left[i])
        frames = jnp.asarray(
            np.asarray(req.audio)[None, cursor:cursor + n])
        self._frame_buf, self.cache = self._encode_fn(
            self.params, frames, jnp.int32(cursor), jnp.int32(i),
            jnp.asarray(self.runtime.cross_tables[i], jnp.int32),
            self._frame_buf, self.cache)
        self._audio_left[i] -= n
        req.encode_steps += 1
        self.encode_quanta += 1
        self.last_quantum = ("encode", 1)
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.asr_keys(self)[0],
                                  ("encode", n), t0, self._frame_buf)
        self._obs_quantum("encode", t0, self._frame_buf, [req.rid],
                          args={"frames": n, "slot": i,
                                "weight_quant": self.weight_quant})
        self.bus.emit(ev.Progress, req.rid, phase="encode",
                      step=cursor + n, total=se)
        if self._audio_left[i] == 0 and self.audio_share:
            # Publish at encode completion (not retirement): concurrent
            # requests with the same audio share immediately.
            self.runtime.publish_cross(i, req._audio_key)
        return 1

    def _prefill_quantum(self, i: int) -> int:
        t0 = self.bus.clock()
        req = self.slots[i]
        chunk = self._pending[i][:self.prefill_chunk]
        del self._pending[i][:len(chunk)]
        pos = self.runtime.pos[i]
        bs = self.runtime.block_size
        for bi in range(pos // bs, cdiv(pos + len(chunk), bs)):
            self.runtime.ensure_writable(i, bi * bs)
        nxt, self.cache = self._prefill_raw(
            self.params,
            jnp.asarray([chunk], jnp.int32),
            jnp.full((1,), pos, jnp.int32),
            jnp.int32(i),
            jnp.asarray([self.runtime.tables[i]], jnp.int32),
            jnp.asarray([self.runtime.cross_tables[i]], jnp.int32),
            self.cache)
        self.runtime.pos[i] = pos + len(chunk)
        req.prefill_steps += 1
        self.prefill_quanta += 1
        self.prefill_launches += 1 if self.fused_prefill else len(chunk)
        self.last_quantum = ("prefill", 1)
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.asr_keys(self)[1],
                                  ("prefill", len(chunk)), t0, nxt)
        self._obs_quantum("prefill", t0, nxt, [req.rid],
                          args={"tokens": len(chunk), "slot": i,
                                "fused": self.fused_prefill,
                                "quantized_kv": self.quantized_kv,
                                "weight_quant": self.weight_quant})
        self.bus.emit(ev.Progress, req.rid, phase="prefill",
                      step=len(req._feed) - len(self._pending[i]),
                      total=len(req._feed))
        if not self._pending[i]:        # feed done: next token is out
            tok = int(jax.device_get(nxt)[0])
            req.out.append(tok)
            self.bus.emit(ev.TokenDelta, req.rid, token=tok,
                          pos=len(req.out) - 1)
            self._next_tok[i] = tok
            self._maybe_retire(i)
        return 1

    def _decode_quantum(self) -> int:
        t0 = self.bus.clock()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            self.last_quantum = None
            return 0
        for i in active:
            self.runtime.ensure_writable(i, self.runtime.pos[i])
        positions = np.asarray(self.runtime.pos, np.int32)
        tables = np.asarray(self.runtime.tables, np.int32)
        ctables = np.asarray(self.runtime.cross_tables, np.int32)
        nxt, self.cache = self.step_fn(
            self.params, jnp.asarray(self._next_tok[:, None]),
            jnp.asarray(positions), jnp.asarray(tables),
            jnp.asarray(ctables), self.cache)
        self.decode_quanta += 1
        self.last_quantum = ("decode", len(active))
        nxt_host = jax.device_get(nxt)
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.asr_keys(self)[2],
                                  ("decode",), t0, nxt)
        self._obs_quantum("decode", t0, nxt,
                          [self.slots[i].rid for i in active],
                          args={"batch": len(active),
                                "quantized_kv": self.quantized_kv,
                                "weight_quant": self.weight_quant})
        for i in active:
            req = self.slots[i]
            self.runtime.pos[i] += 1
            tok = int(nxt_host[i])
            req.out.append(tok)
            req.decode_steps += 1
            self.bus.emit(ev.TokenDelta, req.rid, token=tok,
                          pos=len(req.out) - 1)
            self._next_tok[i] = tok
            self._maybe_retire(i)
        return len(active)

    def _maybe_retire(self, i: int) -> None:
        req = self.slots[i]
        over = len(req.out) >= req.max_new
        hit_eos = req.eos is not None and req.out \
            and req.out[-1] == req.eos
        trunc = self.runtime.pos[i] >= self.max_len
        if over or hit_eos or trunc:
            req.done = True
            self.finished.append(req)
            # No decoder-prompt donation (prefix sharing is off: the
            # decoder KV depends on the audio); the audio chain, if
            # shared, already lives in the cross prefix cache.
            self.runtime.release(i)
            self.runtime.release_cross(i)
            self.slots[i] = None
            self._pending[i] = []
            self.bus.emit(ev.Finished, req.rid, result=req)

    def run(self, max_steps: int = 10_000) -> list[TranscribeRequest]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        return list(self.finished)
