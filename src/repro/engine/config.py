"""Unified engine configuration.

Every engine in the repo — ``ContinuousBatcher`` (LM), ``AsrEngine``
(encoder-decoder), ``DiffusionEngine`` (SD) — historically grew its own
15-kwarg constructor.  The kwargs fall into two groups:

* **shared** knobs that mean the same thing everywhere: ``bus``, ``clock``,
  ``cost_model``, ``metrics``, ``edf``, ``weight_quant``;
* **per-engine** knobs (block sizes, prefill chunking, speculation, ...).

``EngineConfig`` packages both: the shared knobs live at the top level and
each engine reads its own section (``lm`` / ``asr`` / ``diffusion``).  One
config object therefore describes a whole fleet replica, which is exactly
what ``fleet.ReplicaSpec`` wants: (name, params source, one config).

Backwards compatibility: all three engines still accept every historical
kwarg.  Explicit kwargs override the matching config field, so

    ContinuousBatcher(params, cfg, slots=4, max_len=128)
    ContinuousBatcher(params, cfg, config=EngineConfig(
        lm=LMEngineConfig(slots=4, max_len=128)))

build bit-identical engines (gated in tests/test_engine_config.py).  The
loose kwargs are considered deprecated; new knobs (e.g. ``spec_decode``)
are only reachable through the config.

This module is import-light on purpose (no jax, no engine imports) so it
can be pulled in from anywhere — including ``fleet.py``, which must stay
importable without touching model code.  ``build_engine`` does the lazy
imports at call time.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional


class _Unset:
    """Sentinel distinguishing 'kwarg not passed' from 'passed None'."""

    _instance: Optional["_Unset"] = None

    def __new__(cls) -> "_Unset":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<unset>"

    def __bool__(self) -> bool:
        return False


UNSET: Any = _Unset()


@dataclasses.dataclass(frozen=True)
class SpecDecodeConfig:
    """Draft-model speculative decoding (LM engine only).

    A small draft model proposes ``k`` tokens per slot per decode quantum;
    the target model verifies the whole proposal in one paged-prefill
    launch and the rejected tail rolls back as a pure block-table/position
    truncation.  Greedy acceptance is token-bit-exact against plain decode.

    ``draft_params``/``draft_cfg`` must share the target's vocabulary and
    the draft must be a pure-attention decoder (rollback is a position
    truncation, which recurrent state cannot honour).  ``draft_step_fn``
    optionally overrides the draft's batched decode step — tests use it to
    install adversarial drafts with a known acceptance rate.
    """

    draft_params: Any
    draft_cfg: Any
    k: int = 4
    draft_step_fn: Optional[Callable] = None
    draft_fused_prefill: bool = True


@dataclasses.dataclass(frozen=True)
class LMEngineConfig:
    """Section consumed by ``serving.scheduler.ContinuousBatcher``."""

    slots: int = 4
    max_len: Optional[int] = None
    enc_embeds: Any = None
    decode_fn: Optional[Callable] = None
    quantized_kv: bool = False
    block_size: int = 16
    prefill_chunk: int = 8
    prefix_share: bool = False
    extra_blocks: int = 0
    fused_prefill: bool = True
    preempt_over_budget: bool = False
    spec_decode: Optional[SpecDecodeConfig] = None


@dataclasses.dataclass(frozen=True)
class AsrEngineConfig:
    """Section consumed by ``engine.asr_engine.AsrEngine``."""

    slots: int = 4
    max_len: Optional[int] = None
    decode_fn: Optional[Callable] = None
    quantized_kv: bool = False
    block_size: int = 16
    cross_block_size: Optional[int] = None
    audio_chunk: int = 16
    prefill_chunk: int = 8
    audio_share: bool = True
    extra_blocks: int = 0
    fused_prefill: bool = True


@dataclasses.dataclass(frozen=True)
class DiffusionEngineConfig:
    """Section consumed by ``engine.diffusion_engine.DiffusionEngine``."""

    max_batch: int = 1


_SHARED_FIELDS = ("bus", "clock", "cost_model", "metrics", "edf",
                  "weight_quant")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """One object describing how to run any engine in this repo."""

    bus: Any = None
    clock: Callable[[], float] = time.monotonic
    cost_model: Any = None
    metrics: Any = None
    edf: bool = True
    weight_quant: Optional[str] = None
    lm: LMEngineConfig = dataclasses.field(default_factory=LMEngineConfig)
    asr: AsrEngineConfig = dataclasses.field(default_factory=AsrEngineConfig)
    diffusion: DiffusionEngineConfig = dataclasses.field(
        default_factory=DiffusionEngineConfig)


def resolve(config: Optional[EngineConfig], section: str,
            overrides: dict) -> tuple:
    """Merge legacy constructor kwargs onto an ``EngineConfig``.

    ``overrides`` maps kwarg name -> value, where ``UNSET`` marks kwargs the
    caller did not pass.  Passed kwargs win over config fields (the shim
    that keeps every pre-config call site working).  Returns the merged
    ``(EngineConfig, section_config)`` pair; neither input is mutated.
    """
    cfg = config if config is not None else EngineConfig()
    shared = {k: v for k, v in overrides.items()
              if k in _SHARED_FIELDS and v is not UNSET}
    sec = getattr(cfg, section)
    sec_names = {f.name for f in dataclasses.fields(type(sec))}
    local = {k: v for k, v in overrides.items()
             if k in sec_names and v is not UNSET}
    unknown = [k for k, v in overrides.items()
               if v is not UNSET and k not in _SHARED_FIELDS
               and k not in sec_names]
    if unknown:
        raise TypeError(f"unknown engine kwargs for section {section!r}: "
                        f"{sorted(unknown)}")
    sec = dataclasses.replace(sec, **local)
    cfg = dataclasses.replace(cfg, **shared, **{section: sec})
    return cfg, sec


def build_engine(kind: str, params: Any, model_cfg: Any,
                 config: Optional[EngineConfig] = None):
    """Construct an engine of ``kind`` ("lm" | "asr" | "diffusion").

    The declarative counterpart of calling a constructor by hand — this is
    what ``fleet.ReplicaSpec.make`` runs per replica.  Imports are lazy so
    this module stays free of jax/model dependencies at import time.
    """
    config = config if config is not None else EngineConfig()
    if kind == "lm":
        from repro.serving.scheduler import ContinuousBatcher
        return ContinuousBatcher(params, model_cfg, config=config)
    if kind == "asr":
        from repro.engine.asr_engine import AsrEngine
        return AsrEngine(params, model_cfg, config=config)
    if kind == "diffusion":
        from repro.engine.diffusion_engine import DiffusionEngine
        return DiffusionEngine(params, model_cfg, config=config)
    raise ValueError(f"unknown engine kind {kind!r} "
                     "(expected 'lm', 'asr' or 'diffusion')")
