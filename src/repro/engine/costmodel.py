"""Phase-aware service-time cost model + feasibility admission control.

The paper's evaluation decomposes Stable Diffusion into per-phase
costs — CLIP text encode, per-step UNet denoise, VAE decode (the
Fig. 11 phase breakdown) — and its companion LLM-serving study budgets
prefill and decode separately.  :class:`CostModel` carries that
decomposition into the serving stack: a table of per-phase costs, one
entry per *compiled-program shape*, that every engine can consult to
answer "how long will this request take?" *before* running it.

Phase keys
----------

Diffusion (per jitted program; ``b`` is the engine's batch bucket,
``wq`` the engine's ``weight_quant`` policy name or ``None``)::

    ("diff", model, "clip",      use_cfg, b, wq)        one prompt encode
    ("diff", model, "unet_step", sampler, hw, use_cfg, b, wq)
                                                        one denoise step
    ("diff", model, "vae",       hw, b, wq)             finalize + decode
    ("diff", model, "fused", sampler, sbucket, hw, use_cfg, b, wq)
                                whole fused-scan program (clip + sbucket
                                padded steps + vae in one launch)

LM (per scheduling quantum; ``fused`` is the batcher's *executed*
prefill path — both it and the dispatch in ``lm_prefill_chunk`` derive
from ``models.transformer.prefill_path``, so an estimate can never be
keyed on a path the quantum doesn't take)::

    ("lm", model, "prefill", fused, quantized_kv, wq)   one prompt chunk
    ("lm", model, "decode",  quantized_kv, wq)          one batched token
    ("lm", model, "decode-spec", draft, k, quantized_kv, wq)
                                one speculative round (k draft steps +
                                one verification launch); estimates
                                divide by the batcher's observed
                                tokens-per-round acceptance rate

ASR (per ``AsrEngine`` scheduling quantum — the encoder-decoder
modality adds an ingestion phase in front of the LM pair)::

    ("asr", model, "encode-chunk", wq)                  one audio chunk
    ("asr", model, "prefill", fused, quantized_kv, wq)  one prompt chunk
    ("asr", model, "decode-token", quantized_kv, wq)    one batched token

Seeding and refinement
----------------------

Costs are seeded by **calibration micro-runs** (:func:`calibrate`
submits deadline-free sample requests and drains the engine; the
engine's per-quantum observations land in the table) or explicitly via
:meth:`CostModel.seed`.  They are then refined **online** by an EWMA
over the same observations the engines keep making in production: each
quantum's duration is measured on the engine's injectable clock — the
clock that timestamps the ``EventBus`` events, so virtual-time
benchmarks calibrate in virtual time — and folded in with
``cost = (1 - alpha) * cost + alpha * observed``.  Engines skip the
first observation of each compiled shape (it pays jit tracing, which
would poison the steady-state estimate).

Consumers
---------

* ``submit()`` on both engines rejects a request whose estimated
  service time exceeds its ``deadline_ms`` budget (terminal
  :class:`~repro.engine.events.Rejected` event, no queue/slot/KV state
  ever allocated).
* Both engines sweep queued requests whose deadline expired or became
  infeasible to ``Rejected`` on each ``step()`` (bounded queues).
* :class:`~repro.engine.router.EngineRouter` steps the engine with the
  least *slack* (deadline − now − estimated remaining service) instead
  of the raw earliest deadline.
* ``ContinuousBatcher(preempt_over_budget=True)`` evicts decodes
  *predicted* to overrun (now + remaining tokens × decode cost past
  the deadline) instead of waiting for the overrun to happen.

Estimates are intentionally simple: they return ``None`` — "admit
optimistically" — whenever a needed phase has never been observed.
Submit-time feasibility additionally charges :meth:`CostModel.queue_wait`
— the summed estimates of already-queued work amortized over the
engine's parallelism — so a request that is feasible in isolation but
sits behind a deep queue is correctly ``Rejected`` up front.  The
queue-wait term applies ONLY at submission: expiry sweeps and pop-time
checks re-test against the service estimate alone (the wait already
elapsed on the wall clock by then; charging it again would
double-count).  Diffusion estimates DO apply a co-batching
discount (queued requests sharing a group key ride one compiled
program, so each one's expected cost is the program cost over the
occupancy); the table itself persists across restarts via
:meth:`CostModel.save`/:meth:`CostModel.load` (versioned JSON).
Everything here is pure host Python; no jax imports.
"""
from __future__ import annotations

import json
from typing import Any, Iterable

from repro.engine.api import GenerateRequest, TranscribeRequest, uses_cfg
from repro.engine.diffusion_engine import steps_bucket
from repro.engine.samplers import get_sampler


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


class CostModel:
    """Per-phase EWMA cost table shared by the serving engines.

    One instance may be shared across engines (the keys carry the
    engine kind and model name), or each engine can own its own.
    ``alpha`` is the EWMA weight of a fresh observation.
    """

    def __init__(self, alpha: float = 0.3, metrics=None):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._costs: dict[tuple, float] = {}
        self._counts: dict[tuple, int] = {}
        # Optional telemetry sink (repro.obs.Telemetry or a bare
        # MetricsRegistry, duck-typed): every observe() against an
        # existing estimate records |actual - estimate| / estimate in
        # an error histogram per (engine, model, phase) — the
        # estimate-vs-actual signal the queueing-delay-aware work
        # needs.  Not persisted by save()/load().
        self.metrics = metrics

    # --------------------------------------------------------- table
    def seed(self, key: tuple, cost_s: float) -> None:
        """Set a phase cost directly (calibration table / persisted
        snapshot restore); later ``observe()`` calls refine it."""
        self._costs[key] = float(cost_s)
        self._counts.setdefault(key, 0)

    def observe(self, key: tuple, cost_s: float) -> None:
        """Fold one measured phase duration into the EWMA."""
        cur = self._costs.get(key)
        if cur is not None and self.metrics is not None:
            from repro.obs.metrics import DEFAULT_ERROR_BUCKETS
            rel = abs(float(cost_s) - cur) / max(abs(cur), 1e-12)
            self.metrics.histogram(
                "cost_model_rel_error",
                "relative estimate-vs-actual error per phase "
                "(|actual - estimate| / estimate, pre-EWMA-fold)",
                labels=("engine", "model", "phase"),
                buckets=DEFAULT_ERROR_BUCKETS,
            ).observe(rel, engine=str(key[0]), model=str(key[1]),
                      phase=str(key[2]))
        self._costs[key] = (float(cost_s) if cur is None else
                            (1 - self.alpha) * cur + self.alpha * cost_s)
        self._counts[key] = self._counts.get(key, 0) + 1

    def cost(self, key: tuple) -> float | None:
        """Current estimate for one phase key (None if never seen)."""
        return self._costs.get(key)

    def snapshot(self) -> dict[tuple, tuple[float, int]]:
        """``key -> (cost_s, observation count)`` — introspection and
        cross-engine calibration persistence (see :meth:`save`)."""
        return {k: (v, self._counts.get(k, 0))
                for k, v in self._costs.items()}

    # ---------------------------------------------------- persistence
    SNAPSHOT_VERSION = 1

    def save(self, path: str) -> None:
        """Persist the cost table as versioned JSON so calibration
        survives restarts (and can seed CI runs).  Phase keys are
        tuples of str/int/bool/float — JSON lists round-trip every
        element type exactly, so ``load(save())`` is lossless."""
        rec = {
            "version": self.SNAPSHOT_VERSION,
            "alpha": self.alpha,
            "entries": [{"key": list(k), "cost_s": c,
                         "count": self._counts.get(k, 0)}
                        for k, c in sorted(self._costs.items(),
                                           key=lambda kv: repr(kv[0]))],
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CostModel":
        """Restore a :meth:`save`-d table.  Raises ``ValueError`` on a
        version the current code does not understand (snapshots are a
        contract, not a cache: silently dropping entries would skew
        every estimate built on them)."""
        with open(path) as f:
            rec = json.load(f)
        if not isinstance(rec, dict) \
                or rec.get("version") != cls.SNAPSHOT_VERSION:
            raise ValueError(
                f"cost-model snapshot version "
                f"{rec.get('version') if isinstance(rec, dict) else rec!r}"
                f" != {cls.SNAPSHOT_VERSION}")
        cm = cls(alpha=float(rec.get("alpha", 0.3)))
        for e in rec["entries"]:
            key = tuple(e["key"])
            cm._costs[key] = float(e["cost_s"])
            cm._counts[key] = int(e.get("count", 0))
        return cm

    # ----------------------------------------------- diffusion phases
    def _diff_keys(self, eng: Any, req: GenerateRequest) -> dict:
        cfg = eng.cfg
        hw = req.latent_hw or cfg.latent_hw
        ucfg = uses_cfg(req.neg_tokens, req.guidance_scale)
        steps = get_sampler(req.sampler).fixed_steps or req.steps
        b = eng.max_batch
        m = cfg.name
        wq = getattr(eng, "weight_quant", None)
        return dict(
            steps=steps,
            fused=("diff", m, "fused", req.sampler, steps_bucket(steps),
                   hw, ucfg, b, wq),
            clip=("diff", m, "clip", ucfg, b, wq),
            unet=("diff", m, "unet_step", req.sampler, hw, ucfg, b, wq),
            vae=("diff", m, "vae", hw, b, wq),
        )

    def _co_batch(self, eng: Any, req: GenerateRequest) -> int:
        """Expected program occupancy for ``req``: how many requests
        (itself included, capped at the batch bucket) would share the
        compiled program it joins — queued requests with the same
        group key co-batch into ONE launch."""
        group_key = getattr(eng, "_group_key", None)
        queue = getattr(eng, "queue", None)
        if group_key is None or queue is None:
            return 1
        gk = group_key(req)
        n = 1 + sum(1 for r in queue
                    if r is not req and group_key(r) == gk)
        return max(1, min(n, eng.max_batch))

    def estimate_diffusion(self, eng: Any,
                           req: GenerateRequest) -> float | None:
        """Expected per-request service time for a ``DiffusionEngine``
        request: the fused program's own cost when that exact shape has
        been observed, else the Fig.-11 phase composition
        ``clip + steps x unet_step + vae`` (padded pow2 steps on the
        fused path, exact steps on the segmented preview path).
        ``None`` if a needed phase was never observed.

        The program cost is **amortized over the co-batch**: phase
        costs are observed per compiled program at the engine's batch
        bucket, and queued requests with the same group key ride the
        SAME launch, so a request's expected share is the program cost
        divided by the occupancy (pricing each of n co-batched
        requests at the full program cost would treat them as n serial
        programs and over-reject feasible work)."""
        k = self._diff_keys(eng, req)
        share = self._co_batch(eng, req)
        if not req.preview_every:
            c = self.cost(k["fused"])
            if c is not None:
                return c / share
            eff = steps_bucket(k["steps"])   # fused scan pays padding
        else:
            eff = k["steps"]                 # segmented path is exact
        cc, cu, cv = (self.cost(k["clip"]), self.cost(k["unet"]),
                      self.cost(k["vae"]))
        if cc is None or cu is None or cv is None:
            return None
        return (cc + eff * cu + cv) / share

    def remaining_diffusion(self, eng: Any, req: GenerateRequest,
                            steps_done: int) -> float | None:
        """Remaining service time for a request ``steps_done`` deep in
        a segmented (preview) batch: the steps left plus the VAE tail
        (CLIP already paid)."""
        k = self._diff_keys(eng, req)
        cu, cv = self.cost(k["unet"]), self.cost(k["vae"])
        if cu is None or cv is None:
            return None
        return max(0, k["steps"] - steps_done) * cu + cv

    # ------------------------------------------------------ LM phases
    def lm_keys(self, cb: Any) -> tuple[tuple, tuple]:
        """(prefill key, decode key) for a ``ContinuousBatcher``.

        ``cb.fused_prefill`` is the executed path (the batcher derives
        it from ``prefill_path``, the same predicate that dispatches
        inside ``lm_prefill_chunk``), so calibration seeds the path a
        production quantum will actually take."""
        m = cb.cfg.name
        wq = getattr(cb, "weight_quant", None)
        return (("lm", m, "prefill", cb.fused_prefill, cb.quantized_kv,
                 wq),
                ("lm", m, "decode", cb.quantized_kv, wq))

    def lm_spec_key(self, cb: Any) -> tuple:
        """Phase key for one speculative decode round on a
        ``ContinuousBatcher`` running with ``spec_decode``: keyed on
        (target model, draft model, proposal length K) plus the usual
        pool/weight quantization discriminators — the round's cost is
        K draft steps + one verification launch, so a different draft
        or K compiles (and costs) differently."""
        m = cb.cfg.name
        wq = getattr(cb, "weight_quant", None)
        sp = cb.spec
        return ("lm", m, "decode-spec", sp.draft_cfg.name, sp.k,
                cb.quantized_kv, wq)

    def _lm_decode_term(self, cb: Any, ndec: int) -> float | None:
        """Decode-side service time for ``ndec`` tokens: plain batched
        quanta, or — with speculation on — ``decode-spec`` rounds at
        the batcher's observed tokens-per-round rate."""
        if getattr(cb, "spec", None) is not None:
            cs = self.cost(self.lm_spec_key(cb))
            if cs is None:
                return None
            return ndec / cb.spec_tokens_per_round() * cs
        cd = self.cost(self.lm_keys(cb)[1])
        if cd is None:
            return None
        return ndec * cd

    def estimate_lm(self, cb: Any, req: Any) -> float | None:
        """Whole-request (or, after a preemption, remaining) service
        time for an LM ``serving.Request``: chunked-prefill quanta for
        the feed plus the decode term — one batched decode quantum per
        token still to generate (the final prefill chunk emits the
        first token), or speculative rounds at the observed acceptance
        rate when ``spec_decode`` is on.  ``None`` if prefill or the
        decode phase actually in use has never been observed."""
        kp, _ = self.lm_keys(cb)
        cp = self.cost(kp)
        if cp is None:
            return None
        feed = req._feed if req._feed else list(req.prompt)
        chunks = _cdiv(max(1, len(feed)), cb.prefill_chunk)
        ndec = max(0, req.max_new - len(req.out) - 1)
        dec = self._lm_decode_term(cb, ndec)
        if dec is None:
            return None
        return chunks * cp + dec

    def remaining_lm(self, cb: Any, slot: int) -> float | None:
        """Remaining service time for the request running in ``slot``:
        its pending prefill chunks plus its remaining decode tokens
        (speculation-aware, like :meth:`estimate_lm`)."""
        req = cb.slots[slot]
        if req is None:
            return None
        kp, _ = self.lm_keys(cb)
        cp = self.cost(kp)
        if cp is None:
            return None
        pending = len(cb._pending[slot])
        chunks = _cdiv(pending, cb.prefill_chunk) if pending else 0
        ndec = max(0, req.max_new - len(req.out) - (1 if pending else 0))
        dec = self._lm_decode_term(cb, ndec)
        if dec is None:
            return None
        return chunks * cp + dec

    # ----------------------------------------------------- ASR phases
    def asr_keys(self, eng: Any) -> tuple[tuple, tuple, tuple]:
        """(encode key, prefill key, decode key) for an ``AsrEngine``.

        Encode cost is per audio chunk: each quantum re-runs the full
        encoder over the slot's frame buffer, so its cost is set by
        ``cfg.encoder_seq``, not by how many frames the chunk added —
        one key covers every chunk size."""
        m = eng.cfg.name
        wq = getattr(eng, "weight_quant", None)
        return (("asr", m, "encode-chunk", wq),
                ("asr", m, "prefill", eng.fused_prefill, eng.quantized_kv,
                 wq),
                ("asr", m, "decode-token", eng.quantized_kv, wq))

    def estimate_asr(self, eng: Any, req: Any) -> float | None:
        """Whole-request (or, after a preemption, remaining) service
        time for a ``TranscribeRequest``: encode quanta for the full
        audio span, chunked-prefill quanta for the decoder feed, one
        batched decode quantum per token still to generate.  The
        encode term is conservative — an audio prefix-cache adoption
        would skip it, but admission can't know the cache state at the
        request's eventual admit time.  ``None`` if any needed phase
        was never observed."""
        ke, kp, kd = self.asr_keys(eng)
        ce, cp, cd = self.cost(ke), self.cost(kp), self.cost(kd)
        if ce is None or cp is None or cd is None:
            return None
        enc = _cdiv(eng.cfg.encoder_seq, eng.audio_chunk)
        feed = req._feed if req._feed else list(req.prompt)
        chunks = _cdiv(max(1, len(feed)), eng.prefill_chunk)
        ndec = max(0, req.max_new - len(req.out) - 1)
        return enc * ce + chunks * cp + ndec * cd

    def remaining_asr(self, eng: Any, slot: int) -> float | None:
        """Remaining service time for the request running in ``slot``:
        audio frames still to ingest, pending prefill chunks, then the
        remaining decode tokens."""
        req = eng.slots[slot]
        if req is None:
            return None
        ke, kp, kd = self.asr_keys(eng)
        ce, cp, cd = self.cost(ke), self.cost(kp), self.cost(kd)
        if ce is None or cp is None or cd is None:
            return None
        left = eng._audio_left[slot]
        enc = _cdiv(left, eng.audio_chunk) if left else 0
        pending = len(eng._pending[slot])
        chunks = _cdiv(pending, eng.prefill_chunk) if pending else 0
        ndec = max(0, req.max_new - len(req.out) - (1 if pending else 0))
        return enc * ce + chunks * cp + ndec * cd

    # ------------------------------------------------------- generic
    def estimate(self, engine: Any, request: Any) -> float | None:
        """Dispatch on request type: ``GenerateRequest`` -> diffusion,
        ``TranscribeRequest`` -> ASR, anything else -> LM."""
        if isinstance(request, GenerateRequest):
            return self.estimate_diffusion(engine, request)
        if isinstance(request, TranscribeRequest):
            return self.estimate_asr(engine, request)
        return self.estimate_lm(engine, request)

    def queue_wait(self, engine: Any) -> float:
        """Expected queueing delay a newly submitted request inherits:
        the summed service estimates of everything already queued,
        amortized over the engine's admission parallelism (slot count
        for the slotted engines; 1 for diffusion, whose queue drains
        one program at a time — co-batching is already priced into the
        per-request diffusion estimates).  Unobserved phases contribute
        0 (optimistic, matching the ``None`` admission convention)."""
        groups = getattr(engine, "_groups", None)
        if groups is not None:
            queued = [r for q in groups.values() for r in q]
        else:
            queued = list(getattr(engine, "queue", ()) or ())
        total = 0.0
        for r in queued:
            total += self.estimate(engine, r) or 0.0
        slots = getattr(engine, "slots", None)
        par = len(slots) if isinstance(slots, list) and slots else 1
        return total / par


def calibrate(engine: Any, requests: Iterable[Any],
              max_steps: int = 10_000) -> CostModel:
    """Seed an engine's attached cost model with a calibration
    micro-run: submit the (deadline-free) sample requests and drain
    the engine; its per-quantum observations populate the table.
    Returns the engine's cost model for chaining."""
    cm = engine.cost_model
    if cm is None:
        raise ValueError("engine has no cost model attached")
    for req in requests:
        engine.submit(req)
    engine.run(max_steps)
    return cm
