"""Request-based text-to-image engine (CLIP -> UNet scan -> VAE).

This is the diffusion half of the unified :class:`repro.engine.Engine`
surface.  Design points:

* **One jitted program per (sampler, steps-bucket, shape, cfg?, batch)**
  — ``build_denoise`` emits a pure function whose multi-step denoise
  loop is a single ``lax.scan`` over the sampler's step plan, so an
  N-step generation costs one trace, not N.  ``DiffusionEngine`` keeps
  an explicit compile cache keyed on the bucketed request shape and
  counts traces (``engine.traces``) so tests can assert no retrace.
* **Continuous micro-batching** — concurrent requests are grouped by
  compile key, packed into a fixed batch bucket (padded rows replicate
  row 0 and are discarded), run as one program, and retired.  Mirrors
  the slot mechanics of ``serving.scheduler.ContinuousBatcher``.
* **Per-request state rides in batched arrays** — seeds become
  per-request initial noise rows, guidance scales a ``(B,)`` vector,
  so a request's pixels depend only on its own row and co-batching is
  bit-transparent.
* **Classifier-free guidance** — requests with a negative prompt or a
  non-unit ``guidance_scale`` run the UNet on cond + uncond contexts;
  plain requests compile a single-branch program (the two variants are
  separate compile-cache entries).

Model-file quantization (``quantize_pipeline``) and the role-tagged
offload accounting are unchanged from the paper's study — the engine
only reorganizes the host-side request plumbing and the jit boundary.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.policy import OffloadPolicy
from repro.core.qlinear import quantize_params
from repro.diffusion import schedule as sched_mod
from repro.engine import samplers as samplers_mod
from repro.engine.api import GenerateRequest, GenerateResult, uses_cfg
from repro.models import clip as clip_mod
from repro.models import unet as unet_mod
from repro.models import vae as vae_mod


@dataclasses.dataclass(frozen=True)
class SDConfig:
    name: str = "sd-turbo"
    unet: unet_mod.UNetConfig = unet_mod.SD15_UNET
    vae: vae_mod.VAEConfig = vae_mod.SD15_VAE
    clip: Any = None   # ModelConfig; None -> clip_mod.clip_config()
    latent_hw: int = 64          # 512x512 image -> 64x64 latent
    text_len: int = 77
    steps: int = 1               # SD-Turbo single step

    def clip_cfg(self):
        return self.clip or clip_mod.clip_config()


SD_TURBO = SDConfig()
TINY_SD = SDConfig(name="tiny-sd", unet=unet_mod.TINY_UNET,
                   vae=vae_mod.TINY_VAE, clip=clip_mod.TINY_CLIP,
                   latent_hw=8, steps=1)


def init_pipeline(key: jax.Array, cfg: SDConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "clip": clip_mod.init_clip(ks[0], cfg.clip_cfg()),
        "unet": unet_mod.init_unet(ks[1], cfg.unet),
        "vae": vae_mod.init_vae_decoder(ks[2], cfg.vae),
    }


def quantize_pipeline(params: dict, policy: OffloadPolicy) -> dict:
    """GGML-style model-file quantization (the paper's two models)."""
    return quantize_params(params, policy)


def steps_bucket(steps: int) -> int:
    """Round a step count up to the next power of two.

    All step counts in one bucket share a compiled scan (padding steps
    are masked no-ops in the sampler plan), bounding compile count at
    log2(max_steps) per (sampler, shape).  The trade-off is explicit:
    padded steps still run the UNet (a scan cannot skip iterations),
    so a steps=5 request pays 8 evals — bucketing buys bounded
    compiles (~10s each on CPU) at the cost of up to ~2x steady-state
    denoise work for off-bucket step counts.
    """
    b = 1
    while b < steps:
        b *= 2
    return b


def build_denoise(cfg: SDConfig, sampler_name: str, use_cfg: bool, *,
                  decode: bool = True) -> Callable:
    """Build the pure denoise program for one sampler / guidance mode.

    Returns ``fn(params, tokens, neg_tokens, gscale, noise, plan)``
    mapping ``(B, text_len)`` prompts and ``(B, hw, hw, 4)`` unit noise
    to images (or x0 latents with ``decode=False``).  Fully traceable —
    the engine jits it; ``pipeline.generate`` and ``jax.eval_shape``
    callers use it directly.
    """
    sampler = samplers_mod.get_sampler(sampler_name)
    sched = sched_mod.NoiseSchedule()
    clip_cfg = cfg.clip_cfg()

    def fn(params, tokens, neg_tokens, gscale, noise, plan):
        b = tokens.shape[0]
        ctx = clip_mod.clip_encode(params["clip"], clip_cfg, tokens)
        ctx_u = (clip_mod.clip_encode(params["clip"], clip_cfg, neg_tokens)
                 if use_cfg else None)
        x = sampler.init_latent(noise.astype(jnp.float32), plan)
        g = gscale[:, None, None, None]

        def body(x, step):
            xm, t = sampler.model_input(x, step)
            tb = jnp.broadcast_to(t, (b,)).astype(jnp.int32)
            eps = unet_mod.apply_unet(params["unet"], cfg.unet,
                                      xm.astype(jnp.bfloat16), tb,
                                      ctx).astype(jnp.float32)
            if use_cfg:
                eps_u = unet_mod.apply_unet(params["unet"], cfg.unet,
                                            xm.astype(jnp.bfloat16), tb,
                                            ctx_u).astype(jnp.float32)
                eps = eps_u + g * (eps - eps_u)
            x_new = sampler.update(sched, x, eps, step)
            return jnp.where(step["valid"], x_new, x), None

        x, _ = jax.lax.scan(body, x, plan)
        x0 = sampler.finalize(x)
        if not decode:
            return x0
        return vae_mod.apply_vae_decoder(params["vae"], cfg.vae,
                                         x0.astype(jnp.bfloat16))
    return fn


def request_noise(req: GenerateRequest, hw: int) -> jax.Array:
    """Initial unit-normal latent for one request, from its seed only."""
    return jax.random.normal(jax.random.PRNGKey(req.seed), (hw, hw, 4),
                             jnp.float32)


class DiffusionEngine:
    """Micro-batching diffusion engine (implements the Engine protocol).

    ``step()`` pops up to ``max_batch`` queued requests that share a
    compile group — same (sampler, steps, latent size, guidance mode) —
    pads them to the batch bucket, runs the jitted scan program from
    the compile cache, and retires the batch.  ``run()`` drains the
    queue.  ``engine.traces`` counts actual jit traces.
    """

    def __init__(self, params: dict, cfg: SDConfig, *, max_batch: int = 1):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.queue: deque[GenerateRequest] = deque()
        self.finished: list[GenerateResult] = []
        self.traces = 0
        self._fns: dict[tuple, Callable] = {}   # explicit compile cache

    # ------------------------------------------------------------ API
    def submit(self, request: GenerateRequest) -> None:
        samplers_mod.get_sampler(request.sampler)   # fail fast on typos
        if request.steps < 1:
            raise ValueError(f"steps must be >= 1, got {request.steps}")
        self.queue.append(request)

    def step(self) -> int:
        """Run one micro-batch; returns #requests retired (0 if idle)."""
        if not self.queue:
            return 0
        gkey = self._group_key(self.queue[0])
        batch: list[GenerateRequest] = []
        rest: deque[GenerateRequest] = deque()
        while self.queue:
            r = self.queue.popleft()
            if len(batch) < self.max_batch and self._group_key(r) == gkey:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        self._run_batch(batch, gkey)
        return len(batch)

    def run(self, max_steps: int = 10_000) -> list[GenerateResult]:
        for _ in range(max_steps):
            if not self.queue:
                break
            self.step()
        return list(self.finished)    # snapshot: later runs keep appending

    # ------------------------------------------------------ internals
    def _use_cfg(self, req: GenerateRequest) -> bool:
        return uses_cfg(req.neg_tokens, req.guidance_scale)

    def _group_key(self, req: GenerateRequest) -> tuple:
        fixed = samplers_mod.get_sampler(req.sampler).fixed_steps
        return (req.sampler, fixed or req.steps,
                req.latent_hw or self.cfg.latent_hw, self._use_cfg(req))

    def _compiled(self, sampler: str, sbucket: int, hw: int,
                  use_cfg: bool) -> Callable:
        key = (sampler, sbucket, hw, use_cfg, self.max_batch)
        fn = self._fns.get(key)
        if fn is None:
            inner = build_denoise(self.cfg, sampler, use_cfg)

            def counted(params, tokens, neg, g, noise, plan, _inner=inner):
                self.traces += 1        # runs at trace time only
                return _inner(params, tokens, neg, g, noise, plan)

            fn = jax.jit(counted)
            self._fns[key] = fn
        return fn

    def _run_batch(self, reqs: list[GenerateRequest], gkey: tuple) -> None:
        sampler_name, steps, hw, use_cfg = gkey
        tl = self.cfg.text_len

        def tok_arr(t):
            return jnp.asarray(t, jnp.int32).reshape(tl)

        toks = [tok_arr(r.tokens) for r in reqs]
        negs = [tok_arr(r.neg_tokens) if r.neg_tokens is not None
                else jnp.zeros((tl,), jnp.int32) for r in reqs]
        noises = [request_noise(r, hw) for r in reqs]
        scales = [float(r.guidance_scale) for r in reqs]
        while len(toks) < self.max_batch:    # pad-to-bucket with row 0
            toks.append(toks[0])
            negs.append(negs[0])
            noises.append(noises[0])
            scales.append(scales[0])

        sbucket = steps_bucket(steps)
        sampler = samplers_mod.get_sampler(sampler_name)
        plan = sampler.plan(sched_mod.NoiseSchedule(), steps, sbucket)
        fn = self._compiled(sampler_name, sbucket, hw, use_cfg)
        imgs = fn(self.params, jnp.stack(toks), jnp.stack(negs),
                  jnp.asarray(scales, jnp.float32), jnp.stack(noises), plan)
        for i, r in enumerate(reqs):
            self.finished.append(GenerateResult(
                rid=r.rid, image=imgs[i], sampler=sampler_name,
                steps=steps, seed=r.seed, decode_steps=steps))
