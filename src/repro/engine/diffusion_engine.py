"""Request-based text-to-image engine (CLIP -> UNet scan -> VAE).

This is the diffusion half of the unified :class:`repro.engine.Engine`
surface.  Design points:

* **One jitted program per (sampler, steps-bucket, shape, cfg?, batch)**
  — ``build_denoise`` emits a pure function whose multi-step denoise
  loop is a single ``lax.scan`` over the sampler's step plan, so an
  N-step generation costs one trace, not N.  ``DiffusionEngine`` keeps
  an explicit compile cache keyed on the bucketed request shape and
  counts traces (``engine.traces``) so tests can assert no retrace.
* **Continuous micro-batching** — concurrent requests are grouped by
  compile key, packed into a fixed batch bucket (padded rows replicate
  row 0 and are discarded), run as one program, and retired.  Mirrors
  the slot mechanics of ``serving.scheduler.ContinuousBatcher``.
* **Per-request state rides in batched arrays** — seeds become
  per-request initial noise rows, guidance scales a ``(B,)`` vector,
  so a request's pixels depend only on its own row and co-batching is
  bit-transparent.
* **Classifier-free guidance** — requests with a negative prompt or a
  non-unit ``guidance_scale`` run the UNet on cond + uncond contexts;
  plain requests compile a single-branch program (the two variants are
  separate compile-cache entries).
* **Streaming lifecycle** — ``submit()`` returns a
  :class:`repro.engine.events.RequestHandle`; the engine emits typed
  events (``Admitted``/``Progress``/``PreviewLatent``/``Finished``/
  ``Cancelled``) on its :class:`~repro.engine.events.EventBus`.
  Requests with ``preview_every > 0`` run on a *segmented* program set
  (one jitted CLIP encode + one jitted single-solver-step program
  applied ``steps`` times + one jitted finalize/VAE-decode) so the
  host sees an x0-space ``PreviewLatent`` every N steps and can
  ``cancel()`` between steps; plain requests keep the original fused
  single-``lax.scan`` program, so existing ``run()`` callers stay
  bit-identical.  Both program sets live in the same explicit compile
  cache (segment programs need no steps bucket: a 1-step program
  serves every step count).
* **SLO-aware admission** — queued requests are popped
  earliest-deadline-first (``deadline_ms``, ties broken by
  ``priority`` then arrival); with no deadlines this reduces exactly
  to the old FIFO order.
* **Feasibility admission control (opt-in)** — with a
  :class:`repro.engine.costmodel.CostModel` attached
  (``cost_model=...``), ``submit()`` rejects a request whose
  estimated phase-composed service time (CLIP + steps x UNet + VAE,
  or the observed fused-program cost) exceeds its ``deadline_ms``
  budget — terminal :class:`~repro.engine.events.Rejected`, nothing
  enqueued — and each ``step()`` sweeps queued requests whose
  deadline expired or became infeasible while they waited.  The
  engine feeds the model online: every quantum's duration (measured
  on the event clock, first-trace observations skipped) refines the
  per-phase EWMA.  With ``cost_model=None`` (the default) every code
  path is bit-identical to the model-free engine.

Model-file quantization (``quantize_pipeline``) and the role-tagged
offload accounting are unchanged from the paper's study — the engine
only reorganizes the host-side request plumbing and the jit boundary.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.policy import OffloadPolicy, get_policy
from repro.core.qlinear import quantize_params
from repro.diffusion import schedule as sched_mod
from repro.engine import events as ev
from repro.engine import samplers as samplers_mod
from repro.engine.api import GenerateRequest, GenerateResult, uses_cfg
from repro.engine.config import EngineConfig, UNSET, resolve
from repro.models import clip as clip_mod
from repro.models import unet as unet_mod
from repro.models import vae as vae_mod


@dataclasses.dataclass(frozen=True)
class SDConfig:
    name: str = "sd-turbo"
    unet: unet_mod.UNetConfig = unet_mod.SD15_UNET
    vae: vae_mod.VAEConfig = vae_mod.SD15_VAE
    clip: Any = None   # ModelConfig; None -> clip_mod.clip_config()
    latent_hw: int = 64          # 512x512 image -> 64x64 latent
    text_len: int = 77
    steps: int = 1               # SD-Turbo single step

    def clip_cfg(self):
        return self.clip or clip_mod.clip_config()


SD_TURBO = SDConfig()
TINY_SD = SDConfig(name="tiny-sd", unet=unet_mod.TINY_UNET,
                   vae=vae_mod.TINY_VAE, clip=clip_mod.TINY_CLIP,
                   latent_hw=8, steps=1)


def init_pipeline(key: jax.Array, cfg: SDConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "clip": clip_mod.init_clip(ks[0], cfg.clip_cfg()),
        "unet": unet_mod.init_unet(ks[1], cfg.unet),
        "vae": vae_mod.init_vae_decoder(ks[2], cfg.vae),
    }


def quantize_pipeline(params: dict, policy: OffloadPolicy) -> dict:
    """GGML-style model-file quantization (the paper's two models)."""
    return quantize_params(params, policy)


def steps_bucket(steps: int) -> int:
    """Round a step count up to the next power of two.

    All step counts in one bucket share a compiled scan (padding steps
    are masked no-ops in the sampler plan), bounding compile count at
    log2(max_steps) per (sampler, shape).  The trade-off is explicit:
    padded steps still run the UNet (a scan cannot skip iterations),
    so a steps=5 request pays 8 evals — bucketing buys bounded
    compiles (~10s each on CPU) at the cost of up to ~2x steady-state
    denoise work for off-bucket step counts.
    """
    b = 1
    while b < steps:
        b *= 2
    return b


def build_denoise(cfg: SDConfig, sampler_name: str, use_cfg: bool, *,
                  decode: bool = True) -> Callable:
    """Build the pure denoise program for one sampler / guidance mode.

    Returns ``fn(params, tokens, neg_tokens, gscale, noise, plan)``
    mapping ``(B, text_len)`` prompts and ``(B, hw, hw, 4)`` unit noise
    to images (or x0 latents with ``decode=False``).  Fully traceable —
    the engine jits it; ``pipeline.generate`` and ``jax.eval_shape``
    callers use it directly.
    """
    sampler = samplers_mod.get_sampler(sampler_name)
    sched = sched_mod.NoiseSchedule()
    clip_cfg = cfg.clip_cfg()

    def fn(params, tokens, neg_tokens, gscale, noise, plan):
        b = tokens.shape[0]
        ctx = clip_mod.clip_encode(params["clip"], clip_cfg, tokens)
        ctx_u = (clip_mod.clip_encode(params["clip"], clip_cfg, neg_tokens)
                 if use_cfg else None)
        x = sampler.init_latent(noise.astype(jnp.float32), plan)
        g = gscale[:, None, None, None]

        def body(x, step):
            xm, t = sampler.model_input(x, step)
            tb = jnp.broadcast_to(t, (b,)).astype(jnp.int32)
            eps = unet_mod.apply_unet(params["unet"], cfg.unet,
                                      xm.astype(jnp.bfloat16), tb,
                                      ctx).astype(jnp.float32)
            if use_cfg:
                eps_u = unet_mod.apply_unet(params["unet"], cfg.unet,
                                            xm.astype(jnp.bfloat16), tb,
                                            ctx_u).astype(jnp.float32)
                eps = eps_u + g * (eps - eps_u)
            x_new = sampler.update(sched, x, eps, step)
            return jnp.where(step["valid"], x_new, x), None

        x, _ = jax.lax.scan(body, x, plan)
        x0 = sampler.finalize(x)
        if not decode:
            return x0
        return vae_mod.apply_vae_decoder(params["vae"], cfg.vae,
                                         x0.astype(jnp.bfloat16))
    return fn


def build_encode(cfg: SDConfig, use_cfg: bool) -> Callable:
    """Prompt-encoding half of the segmented (preview-streaming) path:
    ``fn(params, tokens, neg_tokens) -> (ctx, ctx_uncond|None)``."""
    clip_cfg = cfg.clip_cfg()

    def fn(params, tokens, neg_tokens):
        ctx = clip_mod.clip_encode(params["clip"], clip_cfg, tokens)
        ctx_u = (clip_mod.clip_encode(params["clip"], clip_cfg, neg_tokens)
                 if use_cfg else None)
        return ctx, ctx_u
    return fn


def build_denoise_step(cfg: SDConfig, sampler_name: str,
                       use_cfg: bool) -> Callable:
    """One solver step of the segmented path — the same math as the
    ``lax.scan`` body in :func:`build_denoise`, exposed as its own
    program so the host can observe/cancel between steps:
    ``fn(params, ctx, ctx_u, gscale, x, step) -> x`` where ``step`` is
    one per-step slice of the sampler plan (scalars)."""
    sampler = samplers_mod.get_sampler(sampler_name)
    sched = sched_mod.NoiseSchedule()

    def fn(params, ctx, ctx_u, gscale, x, step):
        b = x.shape[0]
        g = gscale[:, None, None, None]
        xm, t = sampler.model_input(x, step)
        tb = jnp.broadcast_to(t, (b,)).astype(jnp.int32)
        eps = unet_mod.apply_unet(params["unet"], cfg.unet,
                                  xm.astype(jnp.bfloat16), tb,
                                  ctx).astype(jnp.float32)
        if use_cfg:
            eps_u = unet_mod.apply_unet(params["unet"], cfg.unet,
                                        xm.astype(jnp.bfloat16), tb,
                                        ctx_u).astype(jnp.float32)
            eps = eps_u + g * (eps - eps_u)
        x_new = sampler.update(sched, x, eps, step)
        return jnp.where(step["valid"], x_new, x)
    return fn


def build_finalize_decode(cfg: SDConfig, sampler_name: str) -> Callable:
    """Tail of the segmented path: ``fn(params, x) -> images`` applies
    the sampler's finalize then the VAE decoder."""
    sampler = samplers_mod.get_sampler(sampler_name)

    def fn(params, x):
        x0 = sampler.finalize(x)
        return vae_mod.apply_vae_decoder(params["vae"], cfg.vae,
                                         x0.astype(jnp.bfloat16))
    return fn


def request_noise(req: GenerateRequest, hw: int) -> jax.Array:
    """Initial unit-normal latent for one request, from its seed only."""
    return jax.random.normal(jax.random.PRNGKey(req.seed), (hw, hw, 4),
                             jnp.float32)


class DiffusionEngine(ev.EventStreamMixin):
    """Micro-batching diffusion engine (implements the Engine protocol).

    ``step()`` pops up to ``max_batch`` queued requests that share a
    compile group — same (sampler, steps, latent size, guidance mode,
    preview cadence) — seeded earliest-deadline-first, pads them to
    the batch bucket, and either runs the jitted scan program from the
    compile cache and retires the batch (no previews: the original
    fused path, bit-identical results) or advances the segmented
    per-step program by one denoise step, emitting
    ``Progress``/``PreviewLatent`` events and honoring ``cancel()``
    between steps.  ``run()`` drains the queue.  ``engine.traces``
    counts actual jit traces across all program kinds.
    """

    def __init__(self, params: dict, cfg: SDConfig, *,
                 config: EngineConfig | None = None,
                 max_batch: int = UNSET,
                 bus: ev.EventBus | None = UNSET,
                 clock: Callable[[], float] = UNSET,
                 cost_model=UNSET, metrics=UNSET,
                 weight_quant: str | None = UNSET):
        # Config-first construction (PR 10): loose kwargs are a
        # deprecation shim resolved onto config.diffusion — explicit
        # kwargs win, gated bit-identical in tests.
        self.config, diffc = resolve(config, "diffusion", dict(
            max_batch=max_batch, bus=bus, clock=clock,
            cost_model=cost_model, metrics=metrics,
            weight_quant=weight_quant))
        max_batch = diffc.max_batch
        weight_quant = self.config.weight_quant
        bus, clock = self.config.bus, self.config.clock
        cost_model, metrics = (self.config.cost_model,
                               self.config.metrics)
        if weight_quant is not None:
            # Opt-in quantized weights (GGML model-file semantics):
            # CLIP/UNet/VAE linears move to blocked storage and route
            # through core.qlinear onto the quantized matmul kernels.
            params = quantize_pipeline(params, get_policy(weight_quant))
        self.weight_quant = weight_quant
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.queue: deque[GenerateRequest] = deque()
        self.finished: list[GenerateResult] = []
        self.traces = 0
        self._fns: dict[tuple, Callable] = {}   # explicit compile cache
        self.bus = bus if bus is not None else ev.EventBus(clock)
        self._inflight: dict | None = None      # segmented batch state
        self._meta: dict[int, tuple] = {}       # rid -> (seq, deadline, prio)
        self._subseq = 0
        self.cost_model = cost_model            # None -> no admission ctrl
        self.rejections = 0
        self.metrics = metrics                  # None -> no instrumentation
        self.quanta = 0                         # non-idle step() count

    # ------------------------------------------------------------ API
    def submit(self, request: GenerateRequest) -> ev.RequestHandle:
        samplers_mod.get_sampler(request.sampler)   # fail fast on typos
        if request.steps < 1:
            raise ValueError(f"steps must be >= 1, got {request.steps}")
        if request.preview_every < 0:
            raise ValueError(
                f"preview_every must be >= 0, got {request.preview_every}")
        hw = (self.cfg.latent_hw if request.latent_hw is None
              else request.latent_hw)    # 0 is invalid, not "default"
        down = 2 ** (len(self.cfg.unet.channel_mult) - 1)
        if hw < down or hw % down:
            raise ValueError(
                f"latent_hw={hw} must be a positive multiple of the "
                f"UNet downsample factor {down}")
        if request.rid in self._meta \
                or self.bus.terminal(request.rid) is not None:
            raise ValueError(f"duplicate rid {request.rid}")
        if self.metrics is not None:
            # Before admission control: rejected-at-submit requests are
            # telemetry-visible too (submission is not a bus event).
            self.metrics.request_submitted(request.rid, "diffusion",
                                           self.bus.clock())
        if self.cost_model is not None and request.deadline_ms is not None:
            est = self.cost_model.estimate_diffusion(self, request)
            if est is not None:
                # Queueing-delay-aware admission: charge the expected
                # wait behind already-queued work, so a feasible-in-
                # isolation request behind a deep queue is rejected up
                # front instead of expiring in the sweep later.
                est += self.cost_model.queue_wait(self)
            budget = request.deadline_ms / 1e3
            if est is not None and est > budget:
                self.rejections += 1
                self.bus.emit(ev.Rejected, request.rid, estimated_s=est,
                              budget_s=budget, reason="infeasible")
                return self.handle(request.rid)
        deadline = (float("inf") if request.deadline_ms is None
                    else self.bus.clock() + request.deadline_ms / 1e3)
        request._deadline = deadline
        self._meta[request.rid] = (self._subseq, deadline, request.priority)
        self._subseq += 1
        self.queue.append(request)
        self._obs_sched()
        return self.handle(request.rid)

    # ------------------------------------------- fleet migration hooks
    def evacuate(self, reason: str = "evacuate") -> list[GenerateRequest]:
        """Drain hook for fleet migration: return every live request —
        in-flight segmented ones first (``Preempted`` emitted, their
        partial denoise is abandoned), then the queue in arrival order —
        with no terminal events, so a surviving replica can ``adopt()``
        them.  Restarting from the original seed is bit-exact: the seed
        alone determines the initial latent and the solver is
        deterministic, so a rerun matches an uninterrupted run."""
        out: list[GenerateRequest] = []
        st = self._inflight
        if st is not None:
            for r in st["reqs"]:
                if r.rid not in st["cancelled"]:
                    self.bus.emit(ev.Preempted, r.rid, reason=reason)
                    out.append(r)
            self._inflight = None
        out.extend(self.queue)
        self.queue = deque()
        for r in out:
            self._meta.pop(r.rid, None)
        return out

    def adopt(self, request: GenerateRequest) -> ev.RequestHandle:
        """Admit a request evacuated from another engine on the same
        shared bus.  Unlike ``submit()`` this skips the duplicate-rid
        guard (the rid's prior admission legitimately lives on the bus)
        and submit-time feasibility rejection (the request was already
        admitted once; the per-step queue sweep still applies), and it
        keeps the request's original absolute deadline
        (``request._deadline``) instead of restarting the budget.  At
        batch pop an already-admitted rid re-enters via
        ``Progress(phase="resume")``, never a second ``Admitted``."""
        self._meta[request.rid] = (self._subseq, request._deadline,
                                   request.priority)
        self._subseq += 1
        self.queue.append(request)
        return self.handle(request.rid)

    def has_work(self) -> bool:
        return bool(self.queue) or self._inflight is not None

    def next_deadline(self) -> float:
        """Earliest SLO deadline over queued + in-flight requests
        (+inf if none declare one) — the router's multiplex key."""
        cands = [self._meta[r.rid][1] for r in self.queue]
        if self._inflight is not None:
            cands += [self._meta[r.rid][1] for r in self._inflight["reqs"]
                      if r.rid not in self._inflight["cancelled"]]
        return min(cands, default=float("inf"))

    def next_slack(self) -> float:
        """Minimum estimated *slack* — deadline minus now minus the
        estimated (remaining) service time — over queued + in-flight
        requests; +inf when none declares a deadline.  The router's
        multiplex key when cost models are attached; requests the
        model cannot price yet fall back to raw deadline ordering
        (estimate 0)."""
        cm = self.cost_model
        now = self.bus.clock()
        best = float("inf")
        for r in self.queue:
            dl = self._meta[r.rid][1]
            if dl == float("inf"):
                continue
            est = cm.estimate_diffusion(self, r) if cm else None
            best = min(best, dl - now - (est or 0.0))
        st = self._inflight
        if st is not None:
            for r in st["reqs"]:
                if r.rid in st["cancelled"]:
                    continue
                dl = self._meta[r.rid][1]
                if dl == float("inf"):
                    continue
                est = (cm.remaining_diffusion(self, r, st["i"])
                       if cm else None)
                best = min(best, dl - now - (est or 0.0))
        return best

    def cancel(self, rid: int) -> bool:
        """Abort a request: queued requests leave the queue; requests
        inside a segmented batch stop emitting and are dropped at the
        batch's end (their rows keep computing — co-batched rows cannot
        shrink a compiled shape).  Requests already in a *fused-scan*
        batch retire atomically and cannot be cancelled mid-program.
        """
        for r in self.queue:
            if r.rid == rid:
                self.queue.remove(r)
                self.bus.emit(ev.Cancelled, rid)
                return True
        st = self._inflight
        if st is not None:
            for r in st["reqs"]:
                if r.rid == rid and rid not in st["cancelled"]:
                    st["cancelled"].add(rid)
                    self.bus.emit(ev.Cancelled, rid)
                    return True
        return False

    def step(self) -> int:
        """One scheduling quantum: advance the in-flight segmented
        batch by one denoise step, or pop + run a new micro-batch;
        returns #requests progressed (0 if idle)."""
        if self.cost_model is not None and self.queue:
            self._sweep_infeasible()
        if self._inflight is not None:
            self.quanta += 1
            self._obs_sched()
            return self._segment_quantum()
        if not self.queue:
            return 0
        self.quanta += 1
        self._obs_sched()
        seed = min(self.queue, key=self._edf_key)
        gkey = self._group_key(seed)
        batch: list[GenerateRequest] = [seed]
        rest: deque[GenerateRequest] = deque()
        for r in self.queue:
            if r is seed:
                continue
            if len(batch) < self.max_batch and self._group_key(r) == gkey:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        for i, r in enumerate(batch):
            if self.bus.admitted(r.rid):   # adopted after a migration
                self.bus.emit(ev.Progress, r.rid, phase="resume",
                              step=0, total=gkey[1])
            else:
                self.bus.emit(ev.Admitted, r.rid, slot=i)
        if gkey[4]:                      # preview_every > 0: segmented
            self._start_segmented(batch, gkey)
            return self._segment_quantum()
        self._run_batch(batch, gkey)
        return len(batch)

    def run(self, max_steps: int = 10_000) -> list[GenerateResult]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        return list(self.finished)    # snapshot: later runs keep appending

    # ------------------------------------------------------ internals
    def _use_cfg(self, req: GenerateRequest) -> bool:
        return uses_cfg(req.neg_tokens, req.guidance_scale)

    def _edf_key(self, req: GenerateRequest) -> tuple:
        """Same policy as the LM scheduler: expired deadlines sort
        behind every still-feasible request, then EDF, then priority,
        then arrival (no deadlines -> exact FIFO)."""
        seq, deadline, prio = self._meta[req.rid]
        expired = deadline < self.bus.clock()
        return (expired, deadline, -prio, seq)

    def _sweep_infeasible(self) -> None:
        """Cost-model housekeeping, once per ``step()``: queued
        requests whose deadline already expired — or can provably no
        longer be met (now + estimated service > deadline) — go
        straight to terminal ``Rejected`` instead of sorting behind
        feasible work forever (the queue stays bounded by live,
        winnable requests)."""
        now = self.bus.clock()
        keep: deque[GenerateRequest] = deque()
        for r in self.queue:
            dl = self._meta[r.rid][1]
            if dl == float("inf"):
                keep.append(r)
                continue
            expired = dl < now
            est = self.cost_model.estimate_diffusion(self, r)
            if expired or (est is not None and now + est > dl):
                self.rejections += 1
                self.bus.emit(ev.Rejected, r.rid, estimated_s=est or 0.0,
                              budget_s=dl - now,
                              reason="expired" if expired
                              else "infeasible")
            else:
                keep.append(r)
        self.queue = keep

    def _observe(self, key: tuple, t0: float, traces0: int, out) -> None:
        """Feed one measured program duration into the cost model.
        Skips quanta that paid a jit trace (compile time would poison
        the steady-state EWMA) and blocks on the output so async
        dispatch cannot under-report device time."""
        if self.cost_model is None or self.traces != traces0:
            return
        jax.block_until_ready(out)
        self.cost_model.observe(key, self.bus.clock() - t0)

    def _obs_phase(self, phase: str, t0: float, out, rids: list,
                   args: dict | None = None) -> None:
        """Phase telemetry mark (histogram + trace span).  Unlike the
        cost-model ``_observe`` this never skips first-trace quanta —
        phase counts must reconcile exactly with emitted events, so
        first observations simply include compile time (documented in
        the metric help text)."""
        if self.metrics is None:
            return
        jax.block_until_ready(out)
        self.metrics.phase("diffusion", phase, t0, self.bus.clock(),
                           rids=rids, args=args)

    def _obs_sched(self) -> None:
        if self.metrics is None:
            return
        self.metrics.gauge(
            "engine_queue_depth", "queued requests by engine",
            labels=("engine",)).set(len(self.queue), engine="diffusion")
        st = self._inflight
        live = 0 if st is None else sum(
            1 for r in st["reqs"] if r.rid not in st["cancelled"])
        self.metrics.gauge(
            "diffusion_inflight",
            "live requests in the segmented in-flight batch").set(live)
        self.metrics.gauge("diffusion_traces",
                           "cumulative jit traces").set(self.traces)

    def _group_key(self, req: GenerateRequest) -> tuple:
        fixed = samplers_mod.get_sampler(req.sampler).fixed_steps
        # preview_decode joins the key only when previews actually
        # stream (it is inert on the fused path), so plain requests
        # never split batches over it.
        return (req.sampler, fixed or req.steps,
                req.latent_hw or self.cfg.latent_hw, self._use_cfg(req),
                req.preview_every,
                bool(req.preview_every and req.preview_decode))

    def _counted_jit(self, key: tuple, inner: Callable) -> Callable:
        """Compile-cache lookup; wraps ``inner`` so ``self.traces``
        counts actual jit traces."""
        fn = self._fns.get(key)
        if fn is None:
            def counted(*args, _inner=inner):
                self.traces += 1        # runs at trace time only
                return _inner(*args)

            fn = jax.jit(counted)
            self._fns[key] = fn
        return fn

    def _compiled(self, sampler: str, sbucket: int, hw: int,
                  use_cfg: bool) -> Callable:
        return self._counted_jit(
            (sampler, sbucket, hw, use_cfg, self.max_batch),
            build_denoise(self.cfg, sampler, use_cfg))

    def _pack(self, reqs: list[GenerateRequest], hw: int) -> tuple:
        """Batch request rows, padding to the bucket with row 0
        (padded rows are replicas and are discarded at retire)."""
        tl = self.cfg.text_len

        def tok_arr(t):
            return jnp.asarray(t, jnp.int32).reshape(tl)

        toks = [tok_arr(r.tokens) for r in reqs]
        negs = [tok_arr(r.neg_tokens) if r.neg_tokens is not None
                else jnp.zeros((tl,), jnp.int32) for r in reqs]
        noises = [request_noise(r, hw) for r in reqs]
        scales = [float(r.guidance_scale) for r in reqs]
        while len(toks) < self.max_batch:    # pad-to-bucket with row 0
            toks.append(toks[0])
            negs.append(negs[0])
            noises.append(noises[0])
            scales.append(scales[0])
        return (jnp.stack(toks), jnp.stack(negs),
                jnp.asarray(scales, jnp.float32), jnp.stack(noises))

    # ------------------------------------------------- fused scan path
    def _run_batch(self, reqs: list[GenerateRequest], gkey: tuple) -> None:
        sampler_name, steps, hw, use_cfg = gkey[:4]
        toks, negs, scales, noises = self._pack(reqs, hw)
        sbucket = steps_bucket(steps)
        sampler = samplers_mod.get_sampler(sampler_name)
        plan = sampler.plan(sched_mod.NoiseSchedule(), steps, sbucket)
        fn = self._compiled(sampler_name, sbucket, hw, use_cfg)
        t0, tr0 = self.bus.clock(), self.traces
        imgs = fn(self.params, toks, negs, scales, noises, plan)
        self._observe(("diff", self.cfg.name, "fused", sampler_name,
                       sbucket, hw, use_cfg, self.max_batch,
                       self.weight_quant), t0, tr0, imgs)
        self._obs_phase("fused", t0, imgs, [r.rid for r in reqs],
                        args={"steps": steps, "batch": len(reqs),
                              "weight_quant": self.weight_quant})
        for i, r in enumerate(reqs):
            res = GenerateResult(
                rid=r.rid, image=imgs[i], sampler=sampler_name,
                steps=steps, seed=r.seed, decode_steps=steps)
            self.finished.append(res)
            self.bus.emit(ev.Finished, r.rid, result=res)

    # ------------------------------------------------- segmented path
    def _start_segmented(self, reqs: list[GenerateRequest],
                         gkey: tuple) -> None:
        sampler_name, steps, hw, use_cfg = gkey[:4]
        toks, negs, scales, noises = self._pack(reqs, hw)
        enc = self._counted_jit(("enc", use_cfg, self.max_batch),
                                build_encode(self.cfg, use_cfg))
        t0, tr0 = self.bus.clock(), self.traces
        ctx, ctx_u = enc(self.params, toks, negs)
        self._observe(("diff", self.cfg.name, "clip", use_cfg,
                       self.max_batch, self.weight_quant), t0, tr0, ctx)
        self._obs_phase("clip", t0, ctx, [r.rid for r in reqs],
                        args={"batch": len(reqs),
                              "weight_quant": self.weight_quant})
        sampler = samplers_mod.get_sampler(sampler_name)
        # Unpadded plan: the 1-step segment program serves any step
        # count, so segmented requests never pay pow2 padding steps.
        plan = sampler.plan(sched_mod.NoiseSchedule(), steps, steps)
        self._inflight = dict(
            reqs=reqs, key=(sampler_name, steps, hw, use_cfg),
            x=sampler.init_latent(noises, plan), ctx=ctx, ctx_u=ctx_u,
            g=scales, plan=plan, i=0, cancelled=set())

    def _segment_quantum(self) -> int:
        st = self._inflight
        sampler_name, steps, hw, use_cfg = st["key"]
        live = [(row, r) for row, r in enumerate(st["reqs"])
                if r.rid not in st["cancelled"]]
        if not live:                     # everyone cancelled mid-flight
            self._inflight = None
            return 0
        i = st["i"]
        step_slice = {k: v[i] for k, v in st["plan"].items()}
        fn = self._counted_jit(
            ("seg", sampler_name, hw, use_cfg, self.max_batch),
            build_denoise_step(self.cfg, sampler_name, use_cfg))
        t0, tr0 = self.bus.clock(), self.traces
        st["x"] = fn(self.params, st["ctx"], st["ctx_u"], st["g"],
                     st["x"], step_slice)
        self._observe(("diff", self.cfg.name, "unet_step", sampler_name,
                       hw, use_cfg, self.max_batch, self.weight_quant),
                      t0, tr0, st["x"])
        self._obs_phase("unet_step", t0, st["x"],
                        [r.rid for _row, r in live],
                        args={"step": i + 1, "total": steps,
                              "weight_quant": self.weight_quant})
        st["i"] = i + 1
        sampler = samplers_mod.get_sampler(sampler_name)
        at_stride = [(row, r) for row, r in live
                     if st["i"] % r.preview_every == 0 or st["i"] == steps]
        pv_imgs = None
        if any(r.preview_decode for _row, r in at_stride):
            # Pixel-space previews: run the (cached) finalize+VAE
            # program on the current latent.  Same compiled program as
            # the final decode — co-batched rows share one launch, and
            # preview_decode is in the group key so every row opted in.
            dec = self._counted_jit(("dec", sampler_name, hw,
                                     self.max_batch),
                                    build_finalize_decode(self.cfg,
                                                          sampler_name))
            t0, tr0 = self.bus.clock(), self.traces
            pv_imgs = dec(self.params, st["x"])
            self._observe(("diff", self.cfg.name, "vae", hw,
                           self.max_batch, self.weight_quant), t0, tr0,
                          pv_imgs)
            self._obs_phase("vae", t0, pv_imgs,
                            [r.rid for _row, r in at_stride],
                            args={"preview": True,
                                  "weight_quant": self.weight_quant})
        for row, r in live:
            self.bus.emit(ev.Progress, r.rid, step=st["i"], total=steps,
                          phase="denoise")
        for row, r in at_stride:
            if r.preview_decode and pv_imgs is not None:
                self.bus.emit(ev.PreviewLatent, r.rid, step=st["i"],
                              total=steps, latent=pv_imgs[row],
                              decoded=True)
            else:
                self.bus.emit(ev.PreviewLatent, r.rid, step=st["i"],
                              total=steps,
                              latent=sampler.finalize(st["x"][row]))
        if st["i"] >= steps:
            dec = self._counted_jit(("dec", sampler_name, hw,
                                     self.max_batch),
                                    build_finalize_decode(self.cfg,
                                                          sampler_name))
            t0, tr0 = self.bus.clock(), self.traces
            imgs = dec(self.params, st["x"])
            self._observe(("diff", self.cfg.name, "vae", hw,
                           self.max_batch, self.weight_quant), t0, tr0,
                          imgs)
            self._obs_phase("vae", t0, imgs,
                            [r.rid for _row, r in live],
                            args={"weight_quant": self.weight_quant})
            for row, r in live:
                res = GenerateResult(
                    rid=r.rid, image=imgs[row], sampler=sampler_name,
                    steps=steps, seed=r.seed, decode_steps=steps)
                self.finished.append(res)
                self.bus.emit(ev.Finished, r.rid, result=res)
            self._inflight = None
        return len(live)
