"""Typed event stream for the serving engines (the streaming API core).

The paper frames both Stable Diffusion and LM decode as *serving*
workloads on one host-driven platform; a host that can only
batch-and-drain (``run()``) cannot stream tokens, show x0 previews,
cancel a request, or enforce latency SLOs.  This module is the shared
lifecycle vocabulary that makes the request observable:

* **Events** — frozen dataclasses emitted by the engines in one
  totally-ordered log per :class:`EventBus` (``seq``) with host
  timestamps (``ts``, from the engine's injectable clock).  The
  taxonomy:

  ========================  ==========================================
  ``Admitted``              request left the wait queue (slot / batch)
  ``TokenDelta``            one generated LM token (``pos`` strictly
                            increasing per rid, resumes included)
  ``PreviewLatent``         diffusion x0-space latent at ``step``
  ``Progress``              phase heartbeat (prefill chunk, denoise
                            step, resume)
  ``Preempted``             evicted back to the wait queue (KV blocks
                            released; resume is bit-exact on the
                            scan-prefill path)
  ``Rejected``              terminal: infeasible under the engine's
                            cost model (estimated service time exceeds
                            the remaining deadline budget) — never
                            admitted to a slot/batch
  ``Cancelled``             terminal: request abandoned, state freed
  ``Finished``              terminal: carries the engine's result
  ========================  ==========================================

* **Invariants** (enforced by :meth:`EventBus.emit`, asserted again by
  the CI streaming smoke): at most one ``Admitted`` per rid
  (re-admission after preemption is a ``Progress(phase="resume")``),
  exactly one terminal event per rid, and no events after a terminal.

* **:class:`RequestHandle`** — what ``submit()`` returns.  Iterating
  ``handle.events()`` *drives* the engine (each exhausted buffer pumps
  one ``step()``) until the request reaches a terminal event;
  ``handle.result()`` drains and returns a typed
  :class:`repro.engine.results.TerminalResult` with a common
  ``outcome``/``stats`` shape; ``handle.cancel()`` routes back to the
  engine.  ``handle.state`` exposes the lifecycle state machine
  (``QUEUED -> ADMITTED/RUNNING -> PREEMPTED -> ... -> FINISHED |
  CANCELLED``, or straight to ``REJECTED`` when the engine's cost
  model deems the request infeasible at submission).

* **:class:`EventStreamMixin`** — gives an engine ``stream()`` (a
  drain-and-step generator over the whole bus) and ``handle()``;
  engines provide ``step()``, ``cancel()`` and ``has_work()``.

Everything here is pure host Python: no jax imports, no device state,
so the lifecycle layer is unit-testable without a model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

# ---------------------------------------------------------------- events


@dataclasses.dataclass(frozen=True)
class Event:
    """Base event: ``rid`` it belongs to, engine-clock ``ts`` seconds,
    and the bus-global emission sequence number ``seq``."""
    rid: int
    ts: float
    seq: int


@dataclasses.dataclass(frozen=True)
class Admitted(Event):
    """Request left the wait queue: LM slot index or diffusion batch."""
    slot: int | None = None


@dataclasses.dataclass(frozen=True)
class TokenDelta(Event):
    """One generated token; ``pos`` is the index in the request's
    output sequence (strictly increasing, preemption-proof)."""
    token: int = 0
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class PreviewLatent(Event):
    """Diffusion x0-space working latent after ``step`` of ``total``
    denoise steps.  ``decoded`` marks requests submitted with
    ``preview_decode=True``: ``latent`` then already carries the
    VAE-decoded (H, W, 3) pixel image; otherwise decode it with the
    VAE for a visual preview."""
    step: int = 0
    total: int = 0
    latent: Any = None
    decoded: bool = False


@dataclasses.dataclass(frozen=True)
class Progress(Event):
    """Phase heartbeat: ``phase`` is ``"prefill"`` (one prompt chunk),
    ``"denoise"`` (one diffusion step), ``"encode"`` (one ASR audio
    chunk), or ``"resume"`` (re-admission after preemption)."""
    step: int = 0
    total: int = 0
    phase: str = "decode"


@dataclasses.dataclass(frozen=True)
class Preempted(Event):
    """Evicted back to the wait queue (blocks released); the request
    resumes later via prefill of its prompt + generated tokens."""
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class Cancelled(Event):
    """Terminal: request abandoned; queue entry / slot / blocks freed."""


@dataclasses.dataclass(frozen=True)
class Rejected(Event):
    """Terminal: admission control refused the request — its estimated
    service time (``estimated_s``, from the engine's phase-aware cost
    model) exceeds the remaining deadline budget (``budget_s``), or its
    deadline expired while it waited (``reason``: ``"infeasible"`` |
    ``"expired"``).  A request rejected at submission never occupies a
    slot, batch row, or KV block; the one admitted-then-rejected path
    is a preempted over-budget decode that can no longer meet its
    deadline (``Preempted`` precedes ``Rejected`` in that log).
    ``handle.result()`` returns a ``TerminalResult`` with
    ``outcome == "rejected"`` carrying this ``reason``."""
    estimated_s: float = 0.0
    budget_s: float = 0.0
    reason: str = "infeasible"


@dataclasses.dataclass(frozen=True)
class Finished(Event):
    """Terminal: ``result`` is the engine's finished object
    (``GenerateResult`` for diffusion, ``serving.Request`` for LM)."""
    result: Any = None


TERMINAL_EVENTS = (Cancelled, Rejected, Finished)

# Lifecycle states derived from the event log (handle.state).
QUEUED = "QUEUED"
RUNNING = "RUNNING"
PREEMPTED = "PREEMPTED"
FINISHED = "FINISHED"
CANCELLED = "CANCELLED"
REJECTED = "REJECTED"


class EventBus:
    """Totally-ordered event log shared by every request of an engine
    (or, through :class:`repro.engine.router.EngineRouter`, by several
    engines — the router rebinds its engines onto one bus so merged
    streams need no cross-bus ordering)."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.log: list[Event] = []
        self._seq = 0
        self._base = 0            # seq of log[0] (prefix compaction)
        self._admitted: set[int] = set()
        self._terminal: dict[int, Event] = {}
        self._subs: list[Callable[[Event], None]] = []

    def subscribe(self, fn: Callable[[Event], None]) -> Callable:
        """Register a synchronous observer called once per emitted
        event, after it is appended to the log (the observability
        layer's tap — ``repro.obs.Telemetry.attach``).  Observers must
        not emit or mutate the bus; subscriptions live on this bus
        object, so attach only after router/fleet bus rebinding."""
        self._subs.append(fn)
        return fn

    def emit(self, cls: type, rid: int, **fields) -> Event:
        """Append one event; enforces the per-rid lifecycle invariants
        (single admission, single terminal, silence after terminal)."""
        if rid in self._terminal:
            raise RuntimeError(
                f"event {cls.__name__} after terminal "
                f"{type(self._terminal[rid]).__name__} for rid={rid}")
        if cls is Admitted:
            if rid in self._admitted:
                raise RuntimeError(f"duplicate Admitted for rid={rid} "
                                   "(re-admission must emit "
                                   "Progress(phase='resume'))")
            self._admitted.add(rid)
        ev = cls(rid=rid, ts=self.clock(), seq=self._seq, **fields)
        self._seq += 1
        if isinstance(ev, TERMINAL_EVENTS):
            self._terminal[rid] = ev
        self.log.append(ev)
        for fn in self._subs:
            fn(ev)
        return ev

    def admitted(self, rid: int) -> bool:
        return rid in self._admitted

    def terminal(self, rid: int) -> Event | None:
        return self._terminal.get(rid)

    def events_for(self, rid: int) -> list[Event]:
        return [e for e in self.log if e.rid == rid]

    def since(self, cursor: int) -> tuple[list[Event], int]:
        """Retained events with ``seq >= cursor`` plus the next cursor
        (consumers track absolute seq so compaction cannot skew them)."""
        lo = max(cursor, self._base)
        return self.log[lo - self._base:], self._seq

    def compact(self) -> int:
        """Drop the longest log *prefix* whose events all belong to
        terminal rids — the payload-bearing history (``PreviewLatent``
        latents, token streams) of finished/cancelled requests.  A
        long-lived server calls this periodically; terminal verdicts
        (and ``Finished`` results) stay available via ``terminal()``.
        Returns the number of events dropped."""
        k = 0
        while k < len(self.log) and self.log[k].rid in self._terminal:
            k += 1
        del self.log[:k]
        self._base += k
        return k


class RequestHandle:
    """Host-side handle for one submitted request.

    ``pump`` is the callable that advances the owning engine by one
    scheduling quantum (``engine.step`` — or ``router.step`` when the
    request was submitted through a router, so a handle consumer keeps
    *all* multiplexed work moving while it waits on its own events).
    """

    def __init__(self, rid: int, bus: EventBus,
                 pump: Callable[[], int],
                 canceller: Callable[[int], bool] | None = None,
                 has_work: Callable[[], bool] | None = None):
        self.rid = rid
        self.bus = bus
        self._pump = pump
        self._canceller = canceller
        self._has_work = has_work
        self._cursor = 0          # absolute bus seq already consumed

    # ------------------------------------------------------------ state
    @property
    def done(self) -> bool:
        return self.bus.terminal(self.rid) is not None

    @property
    def state(self) -> str:
        term = self.bus.terminal(self.rid)
        if term is not None:
            if isinstance(term, Finished):
                return FINISHED
            return REJECTED if isinstance(term, Rejected) else CANCELLED
        last = None
        for e in self.bus.log:
            if e.rid == self.rid and isinstance(
                    e, (Admitted, Progress, Preempted, TokenDelta)):
                last = e
        if last is None:
            return QUEUED
        return PREEMPTED if isinstance(last, Preempted) else RUNNING

    def cancel(self) -> bool:
        if self._canceller is None:
            raise RuntimeError(f"rid={self.rid}: engine has no cancel()")
        return self._canceller(self.rid)

    # ----------------------------------------------------------- stream
    def events(self, max_pumps: int = 100_000) -> Iterator[Event]:
        """Yield this request's events, pumping the engine whenever the
        buffer runs dry, until the terminal event has been yielded."""
        pumps = 0
        while True:
            batch, self._cursor = self.bus.since(self._cursor)
            fresh = [e for e in batch if e.rid == self.rid]
            yield from fresh
            if fresh and isinstance(fresh[-1], TERMINAL_EVENTS):
                return
            if self.done:
                # Terminal already reached but not in this read: it was
                # consumed by an earlier iteration's drain or dropped by
                # bus.compact().  Nothing more will ever arrive.
                return
            before = self.bus._seq
            progressed = self._pump()
            pumps += 1
            if pumps > max_pumps:
                raise RuntimeError(
                    f"rid={self.rid}: no terminal event after "
                    f"{max_pumps} engine steps")
            # Idle means stuck only when the engine really has nothing
            # left: a quantum may legitimately progress 0 requests and
            # emit nothing (e.g. clearing a fully-cancelled batch)
            # while queued work remains for the next pump.
            if progressed == 0 and self.bus._seq == before \
                    and not self.done \
                    and not (self._has_work is not None
                             and self._has_work()):
                raise RuntimeError(
                    f"rid={self.rid}: engine idle but request not "
                    "finished (submitted to a different engine?)")

    def result(self) -> Any:
        """Drive to completion and return the typed terminal result.

        Every observable terminal maps to a
        :class:`repro.engine.results.TerminalResult` subclass with a
        common ``outcome``/``stats`` shape (``LMResult`` /
        ``TranscriptResult`` / ``ImageResult`` for finished requests, a
        bare ``TerminalResult`` for cancellations and rejections).
        ``None`` only when no terminal event can be observed at all
        (evicted by ``bus.compact()`` before the handle saw it)."""
        from repro.engine.results import from_terminal
        term = self.bus.terminal(self.rid)
        if term is None:
            for term in self.events():
                pass
        if term is None or not isinstance(term, TERMINAL_EVENTS):
            return None
        if isinstance(term, Finished):
            return from_terminal(self.rid, "finished", term.result)
        if isinstance(term, Rejected):
            return from_terminal(self.rid, "rejected",
                                 reason=term.reason)
        return from_terminal(self.rid, "cancelled")


class EventStreamMixin:
    """Streaming surface shared by the engines and the router.

    Requires ``self.bus`` (:class:`EventBus`), ``self.step() -> int``
    and ``self.has_work() -> bool``; provides ``stream()`` and
    ``handle()``.
    """

    bus: EventBus

    def stream(self, max_steps: int = 100_000) -> Iterator[Event]:
        """Drain-and-step generator: runs the engine while yielding
        every event in emission order; returns when the engine idles.
        The consumer may call ``cancel()``/``submit()`` mid-iteration:
        the cursor advances past exactly the events yielded, so events
        emitted while the generator is suspended are never skipped."""
        cursor = 0
        for _ in range(max_steps):
            batch, cursor = self.bus.since(cursor)
            yield from batch
            if not self.has_work():
                break
            self.step()                               # type: ignore[attr-defined]
        while cursor < self.bus._seq:
            batch, cursor = self.bus.since(cursor)
            yield from batch

    def handle(self, rid: int) -> RequestHandle:
        return RequestHandle(
            rid, self.bus, self.step,                 # type: ignore[attr-defined]
            getattr(self, "cancel", None), self.has_work)
