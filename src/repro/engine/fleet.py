"""Replica fleet serving: one front door over N data-parallel engines.

The paper's end goal is energy-efficient *serving* of generative
workloads, and its companion LLM-on-CGLA study evaluates exactly the
multi-unit scale-out axis: many identical accelerator units behind one
host.  :class:`FleetManager` is that host role — it fronts N
data-parallel engine replicas (each a ``DiffusionEngine``, an LM
``ContinuousBatcher``, an ASR
:class:`~repro.engine.asr_engine.AsrEngine`, or an
:class:`~repro.engine.router.EngineRouter` over any mix,
instantiated in-process from a :class:`ReplicaSpec`) behind
the same ``submit()``/``step()``/``stream()``/``cancel()`` ``Engine``
protocol on ONE shared :class:`~repro.engine.events.EventBus`, so hosts
and benchmarks are replica-count-agnostic: a handle from a fleet pumps
the fleet, a mixed stream stays totally ordered, and the per-rid
lifecycle invariants (one ``Admitted``, one terminal, silence after
terminal) hold fleet-wide.

**Dispatch** is cost-model-balanced: a new request goes to the replica
with the least estimated *completion* time — its live backlog (the sum
of the cost-model estimates captured when each outstanding request was
placed) plus the new request's own estimate from that replica's
:class:`~repro.engine.costmodel.CostModel`.  When any candidate lacks
a model (``cost_model=None``), placement falls back to
least-outstanding-requests.  Ties rotate round-robin.

**Health** is per-replica, driven by the step-latency
:class:`~repro.distributed.fault_tolerance.Watchdog` through the
:class:`~repro.distributed.fault_tolerance.ReplicaHealth` state
machine (HEALTHY -> SUSPECT -> EVICTED, plus DRAINING for planned
removal via :meth:`FleetManager.drain`).  Every ``step()`` the fleet
advances the most urgent busy replica (earliest ``next_deadline()``,
or least ``next_slack()`` when every busy replica carries cost
models — the same multiplex rule ``EngineRouter`` applies to its
engines), measures the quantum on the shared bus clock, and feeds the
replica's watchdog.  A replica whose step *raises*
:class:`ReplicaFault` is evicted immediately.

**Eviction migrates, never drops**: the dead replica's live requests
are pulled out host-side (``evacuate()`` — ``Preempted`` for running
ones, nothing for queued ones) and re-placed on surviving replicas via
``adopt()``, which re-enters them through the engines' bit-exact
resume paths: an LM request re-prefills prompt + generated-so-far
(the PR 4 preemption contract, now across engine instances) and a
diffusion request simply reruns from its seed (the seed alone
determines the initial latent, so a restart is bit-identical to an
uninterrupted run).  Re-admission emits ``Progress(phase="resume")``,
never a second ``Admitted``, and never double-runs a request.

**Replacement (opt-in)**: with ``replace_evicted=True`` an eviction
(except a planned ``drain``) immediately rebuilds a fresh replica from
the evicted slot's :class:`ReplicaSpec` — new engine, new health state
machine, a ``~N``-suffixed name for uniqueness — *before* migration,
so the evacuated requests can land on the replacement and fleet
capacity recovers instead of decaying toward zero across faults
(``stats()["replacements"]`` records each respawn; the gating
``fleet_smoke`` asserts post-kill capacity recovery).

**Fault injection** is deterministic and test-facing:
:class:`FaultInjector` kills (raise at the replica's K-th quantum),
hangs (infinite observed step time from quantum K on), or slows
(fixed extra seconds per quantum) a named replica, keyed on the
replica's own step counter so runs replay exactly.  The gating CI
smoke (``benchmarks/fleet_smoke.py``) uses it to assert zero lost
requests and bit-identical outputs across an injected replica death.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

from repro.distributed.fault_tolerance import (DRAINING, EVICTED,
                                               ReplicaHealth, Watchdog)
from repro.engine import events as ev
from repro.engine.api import GenerateRequest, TranscribeRequest
from repro.engine.asr_engine import AsrEngine
from repro.engine.diffusion_engine import DiffusionEngine
from repro.engine.router import EngineRouter


class ReplicaFault(RuntimeError):
    """A replica's step died (injected or real): the fleet evicts the
    replica and migrates its live requests to survivors."""


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """Recipe for one in-process replica.  ``name`` keys health, stats,
    and fault plans.

    Since PR 10 a spec is declarative: (name, params source, one
    :class:`~repro.engine.config.EngineConfig`) — ``params`` is the
    weight pytree or a zero-arg callable returning one (lazy load per
    replica), ``model_cfg`` the model config, ``engine`` the kind
    (``"lm"`` | ``"asr"`` | ``"diffusion"``), and ``config`` the shared
    engine config (a single instance may back every replica: replicas
    then share its cost model / metrics registry, while each still owns
    its cache and bus — the fleet rebinds the bus before any event is
    emitted).  The legacy ``build`` closure is still honoured and wins
    when set."""
    name: str
    build: Callable[[], Any] | None = None
    params: Any = None
    model_cfg: Any = None
    engine: str = "lm"
    config: Any = None          # engine.config.EngineConfig | None

    def make(self) -> Any:
        """Construct this replica's engine."""
        if self.build is not None:
            return self.build()
        if self.params is None or self.model_cfg is None:
            raise ValueError(
                f"replica {self.name!r} needs either build= or "
                "(params, model_cfg[, config])")
        from repro.engine.config import build_engine
        params = self.params() if callable(self.params) else self.params
        return build_engine(self.engine, params, self.model_cfg,
                            self.config)


class FaultInjector:
    """Deterministic fault plan, keyed on (replica name, that
    replica's own quantum index K) so a run replays exactly:

    * ``kill(name, at_step)`` — the K-th quantum raises
      :class:`ReplicaFault` before the engine runs (a crashed unit);
    * ``hang(name, at_step)`` — quanta >= K observe infinite duration
      (a wedged unit: the watchdog escalates SUSPECT -> EVICTED);
    * ``slow(name, at_step, extra_s, for_steps)`` — quanta in
      [K, K+for_steps) observe ``extra_s`` additional seconds (a
      straggler: one SUSPECT mark, recovering if the window ends).
    """

    def __init__(self):
        self._kill: dict[str, int] = {}
        self._hang: dict[str, int] = {}
        self._slow: dict[str, tuple[int, float, int | None]] = {}

    def kill(self, name: str, at_step: int) -> "FaultInjector":
        self._kill[name] = at_step
        return self

    def hang(self, name: str, at_step: int) -> "FaultInjector":
        self._hang[name] = at_step
        return self

    def slow(self, name: str, at_step: int, extra_s: float,
             for_steps: int | None = None) -> "FaultInjector":
        self._slow[name] = (at_step, float(extra_s), for_steps)
        return self

    def check(self, name: str, k: int) -> None:
        """Raise :class:`ReplicaFault` if ``name`` is scheduled to die
        at its quantum ``k``."""
        if self._kill.get(name) == k:
            raise ReplicaFault(f"injected kill of {name} at step {k}")

    def extra_s(self, name: str, k: int) -> float:
        """Synthetic extra duration observed for quantum ``k``."""
        if name in self._hang and k >= self._hang[name]:
            return float("inf")
        if name in self._slow:
            start, extra, width = self._slow[name]
            if k >= start and (width is None or k < start + width):
                return extra
        return 0.0


@dataclasses.dataclass
class _Replica:
    spec: ReplicaSpec
    engine: Any
    health: ReplicaHealth
    steps: int = 0            # quanta this replica has run (busy only)
    evicted: bool = False     # eviction (incl. migration) already ran


class FleetManager(ev.EventStreamMixin):
    """N data-parallel replicas behind one streaming Engine surface."""

    def __init__(self, specs: list[ReplicaSpec], *,
                 clock: Callable[[], float] = time.monotonic,
                 injector: FaultInjector | None = None,
                 watchdog_threshold: float = 3.0,
                 watchdog_alpha: float = 0.2,
                 suspect_limit: int = 2,
                 replace_evicted: bool = False,
                 metrics=None):
        if not specs:
            raise ValueError("fleet needs at least one replica")
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("replica names must be unique")
        self.bus = ev.EventBus(clock)
        self.injector = injector
        self.metrics = metrics          # None -> no instrumentation
        self.replace_evicted = replace_evicted
        self._wd_params = (watchdog_threshold, watchdog_alpha,
                           suspect_limit)
        self.replicas: list[_Replica] = []
        for spec in specs:
            self._spawn(spec)
        self._owner: dict[int, _Replica] = {}     # rid -> replica
        self._est: dict[int, float] = {}          # rid -> placed estimate
        self._rr_place = 0                        # placement tie rotation
        self._rr_step = 0                         # urgency tie rotation
        self.migrations = 0
        self.evictions: list[tuple[str, str]] = []
        self.replacements: list[tuple[str, str]] = []  # evicted -> fresh
        self._respawns = 0
        self.lost: list[int] = []     # rids with no survivor to adopt them

    def _spawn(self, spec: ReplicaSpec) -> _Replica:
        """Build one replica from its spec, rebind it onto the shared
        bus, and register it with a fresh health state machine."""
        threshold, alpha, suspect_limit = self._wd_params
        engine = spec.make()
        self._rebind(engine)
        rep = _Replica(
            spec, engine,
            ReplicaHealth(Watchdog(threshold=threshold, alpha=alpha),
                          suspect_limit=suspect_limit,
                          name=spec.name, metrics=self.metrics))
        self.replicas.append(rep)
        return rep

    def _rebind(self, engine: Any) -> None:
        """Move a replica (and, for a router, the engines behind it)
        onto the fleet's shared bus — one clock, one total order."""
        for e in [engine] + list(getattr(engine, "engines", [])):
            if e.bus.log:
                raise ValueError(
                    "replica engines must join the fleet before "
                    "emitting events (buses are rebound to a shared one)")
            e.bus = self.bus

    # ---------------------------------------------------------- dispatch
    @staticmethod
    def _serving_engine(engine: Any, request: Any) -> Any:
        """The concrete engine inside ``engine`` that would serve
        ``request`` (None if the replica cannot take this type)."""
        if isinstance(engine, EngineRouter):
            if isinstance(request, GenerateRequest):
                return engine.diffusion
            if isinstance(request, TranscribeRequest):
                return engine.asr
            return engine.lm
        if isinstance(request, GenerateRequest):
            return engine if isinstance(engine, DiffusionEngine) else None
        if isinstance(request, TranscribeRequest):
            return engine if isinstance(engine, AsrEngine) else None
        return (None if isinstance(engine, (DiffusionEngine, AsrEngine))
                else engine)

    def _estimate(self, rep: _Replica, request: Any) -> float | None:
        sub = self._serving_engine(rep.engine, request)
        cm = getattr(sub, "cost_model", None)
        return None if cm is None else cm.estimate(sub, request)

    def _gc(self) -> None:
        """Forget terminal rids so backlog sums stay O(live)."""
        dead = [rid for rid in self._owner
                if self.bus.terminal(rid) is not None]
        for rid in dead:
            self._owner.pop(rid, None)
            self._est.pop(rid, None)

    def _outstanding(self, rep: _Replica) -> int:
        return sum(1 for rid, r in self._owner.items() if r is rep)

    def _backlog_s(self, rep: _Replica) -> float:
        return sum(self._est.get(rid, 0.0)
                   for rid, r in self._owner.items() if r is rep)

    def _place(self, cands: list[_Replica],
               request: Any) -> tuple[_Replica, float | None]:
        """Least-estimated-completion-time placement: backlog + the
        request's own estimate on each candidate; falls back to
        least-outstanding when any candidate cannot price the request
        (no cost model, or a never-observed phase).  Ties rotate."""
        self._gc()
        ests = [self._estimate(r, request) for r in cands]
        if all(e is not None for e in ests):
            keys = [self._backlog_s(r) + e for r, e in zip(cands, ests)]
        else:
            keys = [float(self._outstanding(r)) for r in cands]
        best = min(keys)
        tied = [i for i, k in enumerate(keys) if k == best]
        i = tied[self._rr_place % len(tied)]
        self._rr_place += 1
        return cands[i], ests[i]

    def _dispatchable(self, request: Any) -> list[_Replica]:
        return [r for r in self.replicas if r.health.dispatchable
                and self._serving_engine(r.engine, request) is not None]

    # --------------------------------------------------------------- API
    def submit(self, request: Any) -> ev.RequestHandle:
        rid = request.rid
        if rid in self._owner or self.bus.admitted(rid) \
                or self.bus.terminal(rid) is not None:
            raise ValueError(f"duplicate rid {rid} across fleet")
        cands = self._dispatchable(request)
        if not cands:
            raise RuntimeError(
                f"no dispatchable replica accepts "
                f"{type(request).__name__} "
                f"(states: {[r.health.state for r in self.replicas]})")
        rep, est = self._place(cands, request)
        rep.engine.submit(request)
        self._owner[rid] = rep
        if self.metrics is not None:
            self.metrics.counter(
                "fleet_dispatch_total",
                "requests placed per replica",
                labels=("replica",)).inc(replica=rep.spec.name)
        # A submit-time Rejected is terminal already: no backlog entry.
        if est is not None and self.bus.terminal(rid) is None:
            self._est[rid] = est
        return ev.RequestHandle(rid, self.bus, self.step, self.cancel,
                                self.has_work)

    def cancel(self, rid: int) -> bool:
        rep = self._owner.get(rid)
        return rep.engine.cancel(rid) if rep is not None else False

    def has_work(self) -> bool:
        return any(r.engine.has_work() for r in self.replicas
                   if r.health.live)

    def next_deadline(self) -> float:
        return min((r.engine.next_deadline() for r in self.replicas
                    if r.health.live), default=float("inf"))

    @property
    def cost_model(self):
        """The fleet "has a cost model" (e.g. for ``calibrate()``)
        only when every live replica does; typically one shared
        :class:`~repro.engine.costmodel.CostModel` instance, so any
        replica's observations refine every replica's estimates."""
        models = [getattr(r.engine, "cost_model", None)
                  for r in self.replicas if r.health.live]
        return (models[0] if models and all(m is not None for m in models)
                else None)

    def drain(self, name: str) -> None:
        """Planned removal: stop dispatching to ``name``; its in-flight
        work runs to completion, then the replica retires (EVICTED with
        reason "drained", zero migrations)."""
        self._by_name(name).health.drain()

    def _by_name(self, name: str) -> _Replica:
        for r in self.replicas:
            if r.spec.name == name:
                return r
        raise KeyError(f"no replica named {name!r}")

    def step(self) -> int:
        """Advance the most urgent busy replica by one quantum,
        watching its step latency; returns #requests progressed.
        Urgency is least estimated slack when every busy replica
        carries cost models, else earliest deadline (ties rotate) —
        the same rule ``EngineRouter.step()`` applies one level down.
        """
        # Retire replicas that finished draining (even while idle).
        for r in self.replicas:
            if r.health.state == DRAINING and not r.engine.has_work():
                self._evict(r, "drained")
        busy = [r for r in self.replicas
                if r.health.live and r.engine.has_work()]
        if not busy:
            return 0
        if all(getattr(r.engine, "cost_model", None) is not None
               for r in busy):
            keys = [r.engine.next_slack() for r in busy]
        else:
            keys = [r.engine.next_deadline() for r in busy]
        best = min(keys)
        tied = [r for r, k in zip(busy, keys) if k == best]
        rep = tied[self._rr_step % len(tied)]
        self._rr_step += 1
        k = rep.steps
        try:
            if self.injector is not None:
                self.injector.check(rep.spec.name, k)
            t0 = self.bus.clock()
            n = rep.engine.step()
            dt = self.bus.clock() - t0
        except ReplicaFault as fault:
            self._evict(rep, str(fault))
            return 0
        rep.steps += 1
        extra = (self.injector.extra_s(rep.spec.name, k)
                 if self.injector is not None else 0.0)
        if rep.health.observe_step(k, dt + extra) == EVICTED:
            self._evict(rep, rep.health.reason)
        return n

    # ---------------------------------------------------------- eviction
    def _evict(self, rep: _Replica, reason: str) -> None:
        """Evict ``rep`` and migrate every live request it held to
        surviving replicas (bit-exact resume; ``Progress(resume)`` at
        re-admission, never a second ``Admitted``).  Idempotent."""
        if rep.evicted:
            return
        rep.evicted = True
        rep.health.evict(reason)
        self.evictions.append((rep.spec.name, reason))
        if self.metrics is not None:
            self.metrics.counter(
                "fleet_evictions_total", "replica evictions",
                labels=("replica",)).inc(replica=rep.spec.name)
        if self.replace_evicted and reason != "drained":
            # Capacity self-healing: rebuild a fresh replica from the
            # evicted slot's spec (new params/cache/health, suffixed
            # name for uniqueness) BEFORE migrating, so the evacuated
            # requests can land on the replacement too.  Drained
            # replicas are deliberate removals and are not replaced.
            fresh = ReplicaSpec(f"{rep.spec.name}~{self._respawns}",
                                rep.spec.build)
            self._respawns += 1
            self._spawn(fresh)
            self.replacements.append((rep.spec.name, fresh.name))
            if self.metrics is not None:
                self.metrics.counter(
                    "fleet_replacements_total",
                    "fresh replicas spawned after evictions",
                    labels=("replica",)).inc(replica=fresh.name)
        moved = rep.engine.evacuate("replica-evicted")
        for req in moved:
            cands = self._dispatchable(req)
            if not cands:
                # No survivor can take it: terminal Cancelled so the
                # handle resolves instead of spinning forever.
                self.lost.append(req.rid)
                self.bus.emit(ev.Cancelled, req.rid)
                self._owner.pop(req.rid, None)
                self._est.pop(req.rid, None)
                if self.metrics is not None:
                    self.metrics.counter(
                        "fleet_lost_total",
                        "requests with no survivor to adopt them").inc()
                continue
            target, est = self._place(cands, req)
            target.engine.adopt(req)
            self._owner[req.rid] = target
            if est is not None:
                self._est[req.rid] = est
            self.migrations += 1
            if self.metrics is not None:
                self.metrics.counter(
                    "fleet_migrations_total",
                    "requests migrated off evicted replicas").inc()

    # ------------------------------------------------------------- drain
    def run(self, max_steps: int = 100_000) -> list:
        """Drain-the-stream compatibility wrapper: every ``Finished``
        payload in completion order (mixed types across replicas)."""
        return [e.result for e in self.stream(max_steps)
                if isinstance(e, ev.Finished)]

    def stream(self, max_steps: int = 100_000) -> Iterator[ev.Event]:
        return super().stream(max_steps)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Fleet observability: per-replica health/quanta/outstanding
        plus migration and eviction counters (what ``fleet_smoke``
        reports and gates on)."""
        self._gc()
        return {
            "replicas": [{
                "name": r.spec.name,
                "state": r.health.state,
                "steps": r.steps,
                "outstanding": self._outstanding(r),
                "suspects": len(r.health.watchdog.suspects),
            } for r in self.replicas],
            "migrations": self.migrations,
            "evictions": list(self.evictions),
            "replacements": list(self.replacements),
            "lost": list(self.lost),
        }
