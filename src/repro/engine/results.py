"""Typed terminal results for :meth:`RequestHandle.result`.

Before PR 10, ``handle.result()`` returned the raw ``Finished`` payload for
successful requests and ``None`` for everything else — callers had to know
that ``None`` could mean "cancelled", "rejected" *or* "bus already
evicted the terminal", and had to duck-type the payload per modality.

Now every terminal maps to a :class:`TerminalResult` with a common
``outcome``/``stats`` shape, specialised per modality:

* LM generate      -> :class:`LMResult` (``prompt``/``tokens``)
* ASR transcribe   -> :class:`TranscriptResult` (``prompt``/``transcript``)
* diffusion        -> :class:`ImageResult` (``image`` + the full
  ``GenerateResult`` under ``generate``)
* cancelled/rejected -> plain :class:`TerminalResult` with the outcome set
  (and the scheduler's reason string for rejections).

``result()`` only returns ``None`` when no terminal event is observable at
all.  Like ``events.py``, this module is pure host Python — importing it
must never pull in jax, so it stays safe for control planes that only
route events.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

OUTCOME_FINISHED = "finished"
OUTCOME_CANCELLED = "cancelled"
OUTCOME_REJECTED = "rejected"


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request work accounting, uniform across modalities.

    ``proposed``/``accepted`` are speculative-decoding counters (0 unless
    the LM engine ran with ``SpecDecodeConfig``): draft tokens offered to
    the verifier vs. draft tokens the target model accepted.
    """

    prefill_steps: int = 0
    decode_steps: int = 0
    encode_steps: int = 0
    proposed: int = 0
    accepted: int = 0


@dataclasses.dataclass(frozen=True)
class TerminalResult:
    """Common shape of every terminal: what happened and how much work."""

    rid: int
    outcome: str
    stats: RequestStats = dataclasses.field(default_factory=RequestStats)
    reason: str = ""

    @property
    def finished(self) -> bool:
        return self.outcome == OUTCOME_FINISHED


@dataclasses.dataclass(frozen=True)
class LMResult(TerminalResult):
    """LM completion: the prompt and the generated token ids."""

    prompt: Tuple[int, ...] = ()
    tokens: Tuple[int, ...] = ()
    request: Any = None


@dataclasses.dataclass(frozen=True)
class TranscriptResult(TerminalResult):
    """ASR completion: decoder prompt and emitted transcript token ids."""

    prompt: Tuple[int, ...] = ()
    transcript: Tuple[int, ...] = ()
    request: Any = None


@dataclasses.dataclass(frozen=True)
class ImageResult(TerminalResult):
    """Diffusion completion: the decoded image plus the full payload."""

    image: Any = None
    generate: Any = None


def _stats_of(payload: Any) -> RequestStats:
    return RequestStats(
        prefill_steps=int(getattr(payload, "prefill_steps", 0) or 0),
        decode_steps=int(getattr(payload, "decode_steps", 0) or 0),
        encode_steps=int(getattr(payload, "encode_steps", 0) or 0),
        proposed=int(getattr(payload, "proposed", 0) or 0),
        accepted=int(getattr(payload, "accepted", 0) or 0),
    )


def from_terminal(rid: int, outcome: str, payload: Any = None,
                  reason: str = "") -> TerminalResult:
    """Build the typed result for a terminal event.

    ``payload`` is the ``Finished.result`` object (a scheduler ``Request``,
    ASR request, or diffusion ``GenerateResult``); modality is duck-typed
    the same way the event bus does it: images have ``.image``, transcribe
    requests have ``.audio``, everything else with a token stream is LM.
    """
    if payload is None:
        return TerminalResult(rid=rid, outcome=outcome, reason=reason)
    stats = _stats_of(payload)
    if hasattr(payload, "image"):
        return ImageResult(rid=rid, outcome=outcome, stats=stats,
                           reason=reason, image=payload.image,
                           generate=payload)
    prompt = tuple(getattr(payload, "prompt", ()) or ())
    out = tuple(getattr(payload, "out", ()) or ())
    if hasattr(payload, "audio"):
        return TranscriptResult(rid=rid, outcome=outcome, stats=stats,
                                reason=reason, prompt=prompt,
                                transcript=out, request=payload)
    return LMResult(rid=rid, outcome=outcome, stats=stats, reason=reason,
                    prompt=prompt, tokens=out, request=payload)
