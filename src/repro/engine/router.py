"""SLO-aware multiplexer: one streaming surface over every engine.

The paper serves Stable Diffusion and LM decode on the same
general-purpose platform (and its companion Whisper study adds speech
recognition); :class:`EngineRouter` is the host-side counterpart — a
single ``submit()/step()/stream()/cancel()`` surface multiplexing a
:class:`repro.engine.DiffusionEngine`, an LM
``serving.ContinuousBatcher``, and an encoder-decoder
:class:`repro.engine.asr_engine.AsrEngine` (any object with the
structural ``Engine`` protocol plus
``has_work()``/``next_deadline()``/``bus``) in one host loop:

* **Dispatch** — :class:`repro.engine.api.GenerateRequest` goes to the
  diffusion engine, :class:`repro.engine.api.TranscribeRequest` to the
  ASR engine, everything else (``serving.Request``) to the LM engine;
  rids must be globally unique across the router.
* **One event bus** — at construction the router rebinds all engines
  onto a single :class:`~repro.engine.events.EventBus` (they must not
  have emitted yet), so ``stream()`` yields a totally-ordered merge of
  every modality's events with no cross-bus reconciliation, and the
  handles it returns pump the *router* (all multiplexed work keeps
  moving while a consumer waits on one request).
* **SLO-aware scheduling** — each ``step()`` advances the engine whose
  pending work has the earliest deadline (``next_deadline()``);
  deadline ties fall back to round-robin so a deadline-free diffusion
  backlog cannot starve LM decode or vice versa.  Within each engine,
  admission is EDF-within-fairness-groups and the LM engine can
  preempt over-budget decodes (see ``serving.scheduler``).
* **Cost-model-informed urgency** — when *every* busy engine carries a
  :class:`repro.engine.costmodel.CostModel`, the multiplex key becomes
  estimated **slack** (``next_slack()``: deadline − now − estimated
  remaining service time) instead of the raw deadline, so a request
  with a later deadline but a long predicted tail (a multi-step
  denoise) is stepped ahead of an earlier-deadline request that needs
  only a few cheap decode tokens.  Engines without a model (the
  default) keep the PR 4 earliest-deadline behavior bit-identically.
* **``run()`` compatibility** — drains the stream and returns every
  ``Finished`` payload in completion order, mirroring the engines' own
  drain-the-queue ``run()``.
"""
from __future__ import annotations

from typing import Any, Iterator

from repro.engine import events as ev
from repro.engine.api import GenerateRequest, TranscribeRequest


class EngineRouter(ev.EventStreamMixin):
    """Multiplexes diffusion, LM, and ASR engines behind one streaming
    Engine surface (any may be ``None``, at least one required)."""

    def __init__(self, diffusion: Any = None, lm: Any = None,
                 asr: Any = None, metrics=None):
        if diffusion is None and lm is None and asr is None:
            raise ValueError("router needs at least one engine")
        self.diffusion = diffusion
        self.lm = lm
        self.asr = asr
        self.metrics = metrics          # None -> no instrumentation
        self.engines = [e for e in (diffusion, lm, asr)
                        if e is not None]
        # Rebind every engine onto one shared bus (single clock, one
        # total event order).  Refuse once events exist: merging
        # populated buses would reorder history.
        self.bus = self.engines[0].bus
        for e in self.engines:
            if e.bus.log:
                raise ValueError(
                    "engines must join the router before emitting "
                    "events (their buses are rebound to a shared one)")
        for e in self.engines:
            e.bus = self.bus
        self._owner: dict[int, Any] = {}      # rid -> engine
        self._rr = 0                          # deadline-tie rotation

    def _dispatch(self, request: Any) -> Any:
        if isinstance(request, GenerateRequest):
            return self.diffusion
        if isinstance(request, TranscribeRequest):
            return self.asr
        return self.lm

    # --------------------------------------------------------------- API
    def submit(self, request: Any) -> ev.RequestHandle:
        engine = self._dispatch(request)
        if engine is None:
            raise ValueError(
                f"no engine for {type(request).__name__} "
                f"(router has diffusion={self.diffusion is not None}, "
                f"lm={self.lm is not None}, "
                f"asr={self.asr is not None})")
        if request.rid in self._owner:
            raise ValueError(f"duplicate rid {request.rid} across router")
        engine.submit(request)
        self._owner[request.rid] = engine
        # The handle pumps the router, not the owning engine, so a
        # consumer blocked on one request keeps all work moving.
        return ev.RequestHandle(request.rid, self.bus, self.step,
                                self.cancel, self.has_work)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def next_deadline(self) -> float:
        return min((e.next_deadline() for e in self.engines),
                   default=float("inf"))

    def next_slack(self) -> float:
        """Minimum estimated slack over every engine's pending work
        (+inf when none declares a deadline) — the key a
        :class:`repro.engine.fleet.FleetManager` multiplexes replica
        routers on, mirroring how ``step()`` multiplexes the engines
        inside one router.  Engines without a cost model price their
        work at zero remaining service (raw deadline ordering)."""
        return min((e.next_slack() for e in self.engines),
                   default=float("inf"))

    @property
    def cost_model(self):
        """A router "has a cost model" (for slack-based multiplexing
        above it) only when every engine behind it does."""
        models = [getattr(e, "cost_model", None) for e in self.engines]
        return models[0] if all(m is not None for m in models) else None

    def cancel(self, rid: int) -> bool:
        engine = self._owner.get(rid)
        return engine.cancel(rid) if engine is not None else False

    # ------------------------------------------- fleet migration hooks
    def evacuate(self, reason: str = "evacuate") -> list:
        """Drain hook for fleet migration: evacuate every engine behind
        the router and forget ownership; returns the mixed-type live
        requests for a surviving replica to ``adopt()``."""
        out: list = []
        for e in self.engines:
            out.extend(e.evacuate(reason))
        for r in out:
            self._owner.pop(r.rid, None)
        return out

    def adopt(self, request: Any) -> ev.RequestHandle:
        """Admit a request evacuated from another replica (see the
        engines' ``adopt()``): dispatched by type like ``submit()`` but
        without the duplicate-rid guard — the rid's prior admission
        lives on the shared bus."""
        engine = self._dispatch(request)
        if engine is None:
            raise ValueError(
                f"no engine for adopted {type(request).__name__}")
        engine.adopt(request)
        self._owner[request.rid] = engine
        return ev.RequestHandle(request.rid, self.bus, self.step,
                                self.cancel, self.has_work)

    def step(self) -> int:
        """Advance the engine with the most urgent pending work by one
        quantum (ties rotate round-robin); returns #requests
        progressed.  Urgency is estimated slack (``next_slack()``)
        when every busy engine has a cost model attached, else the raw
        earliest deadline (``next_deadline()`` — exactly the
        pre-cost-model behavior)."""
        busy = [e for e in self.engines if e.has_work()]
        if not busy:
            return 0
        if all(getattr(e, "cost_model", None) is not None for e in busy):
            keys = [e.next_slack() for e in busy]
        else:
            keys = [e.next_deadline() for e in busy]
        best = min(keys)
        tied = [e for e, k in zip(busy, keys) if k == best]
        engine = tied[self._rr % len(tied)]
        self._rr += 1
        if self.metrics is not None:
            self.metrics.counter(
                "router_steps_total",
                "scheduling quanta granted by the router, per engine",
                labels=("engine",)).inc(
                engine="diffusion" if engine is self.diffusion
                else ("asr" if engine is self.asr else "lm"))
        return engine.step()

    def run(self, max_steps: int = 100_000) -> list:
        """Drain-the-stream compatibility wrapper: returns every
        ``Finished`` payload in completion order (mixed types:
        ``GenerateResult``, LM ``Request``, and ``TranscribeRequest``
        objects)."""
        return [e.result for e in self.stream(max_steps)
                if isinstance(e, ev.Finished)]

    def stream(self, max_steps: int = 100_000) -> Iterator[ev.Event]:
        """Merged event stream over every engine (see
        :class:`~repro.engine.events.EventStreamMixin`)."""
        return super().stream(max_steps)
