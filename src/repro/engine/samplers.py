"""Sampler registry: named denoise strategies over one scan skeleton.

Replaces the hardcoded ``if steps == 1`` / DDIM-else branch that used
to live in ``diffusion.pipeline.generate``.  A sampler contributes
four pure pieces to the jitted denoise ``lax.scan`` (built in
:mod:`repro.engine.diffusion_engine`):

* ``plan(sched, num_steps, num_padded)`` — per-step scan inputs as a
  dict of arrays with leading dim ``num_padded`` and a ``valid`` mask.
  Padding steps are no-ops (masked with ``jnp.where``), which is what
  lets the engine bucket step counts: every request whose steps round
  up to the same bucket shares one compiled program.
* ``init_latent(noise, plan)`` — map unit-normal noise to the
  sampler's working latent (VP space for ddim/turbo, VE for euler).
* ``model_input(x, step)`` — what the eps-prediction UNet sees.
* ``update(sched, x, eps, step)`` — one solver step; the actual math
  stays in :mod:`repro.diffusion.schedule` (``ddim_step``,
  ``euler_step``, ``turbo_step``) so sampler classes are thin wiring.

Register new samplers with ``@register_sampler("name")``; look them up
by name with ``get_sampler`` (the engine and ``GenerateRequest`` refer
to samplers only by name).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.diffusion import schedule as S

_REGISTRY: dict[str, "Sampler"] = {}


def register_sampler(name: str):
    """Class decorator: register a Sampler subclass under ``name``."""
    def deco(cls):
        _REGISTRY[name] = cls()
        return cls
    return deco


def get_sampler(name: str) -> "Sampler":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown sampler {name!r}; registered samplers: "
                       f"{sorted(_REGISTRY)}") from None


def list_samplers() -> list[str]:
    return sorted(_REGISTRY)


class Sampler:
    """Stateless sampler strategy (see module docstring for the hooks)."""

    # When set, the sampler always runs this many solver steps and the
    # engine normalizes request step counts to it (e.g. turbo is
    # single-step by construction).
    fixed_steps: int | None = None

    def plan(self, sched: S.NoiseSchedule, num_steps: int,
             num_padded: int) -> dict[str, jax.Array]:
        raise NotImplementedError

    def init_latent(self, noise: jax.Array,
                    plan: dict[str, jax.Array]) -> jax.Array:
        return noise

    def model_input(self, x: jax.Array,
                    step: dict[str, jax.Array]) -> tuple[jax.Array, jax.Array]:
        return x, step["t"]

    def update(self, sched: S.NoiseSchedule, x: jax.Array, eps: jax.Array,
               step: dict[str, jax.Array]) -> jax.Array:
        raise NotImplementedError

    def finalize(self, x: jax.Array) -> jax.Array:
        return x


def _pad_plan(plan: dict[str, jax.Array], num_steps: int, num_padded: int,
              pad_vals: dict[str, float]) -> dict[str, jax.Array]:
    """Extend per-step arrays to ``num_padded`` with masked filler steps.

    Pad values must keep the masked step math finite (``jnp.where``
    evaluates both branches); validity is carried in ``valid``.
    """
    out = {"valid": jnp.arange(num_padded) < num_steps}
    for k, v in plan.items():
        pad = jnp.full((num_padded - num_steps,), pad_vals[k], v.dtype)
        out[k] = jnp.concatenate([v, pad])
    return out


@register_sampler("ddim")
class DDIMSampler(Sampler):
    """Deterministic DDIM (eta=0) over evenly spaced VP timesteps."""

    def plan(self, sched, num_steps, num_padded):
        ts = S.ddim_timesteps(num_steps, sched.num_train_timesteps)
        ts = ts.astype(jnp.int32)
        n = int(ts.shape[0])            # ddim_timesteps clamps to train len
        ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])
        return _pad_plan({"t": ts, "t_prev": ts_prev}, n, num_padded,
                         {"t": 0, "t_prev": -1})

    def update(self, sched, x, eps, step):
        return S.ddim_step(sched, x, eps, step["t"], step["t_prev"])


@register_sampler("euler")
class EulerSampler(Sampler):
    """Euler ancestral-free ODE solver in the VE (sigma) view.

    The latent is initialized as ``noise * sqrt(1 + sigma_max^2)`` (not
    the k-diffusion ``noise * sigma_max``) so the first model input is
    exactly the unit noise — at SD's sigma_max the two differ by ~0.2%,
    and this choice makes 1-step Euler agree with ``turbo_step``.
    """

    def plan(self, sched, num_steps, num_padded):
        num_steps = max(1, min(num_steps, sched.num_train_timesteps))
        sigmas = S.euler_sigmas(sched, num_steps)      # (num_steps + 1,)
        ts = S.euler_timestep_indices(sched, num_steps)
        return _pad_plan({"t": ts, "sigma": sigmas[:-1],
                          "sigma_next": sigmas[1:]},
                         num_steps, num_padded,
                         {"t": 0, "sigma": 0.0, "sigma_next": 0.0})

    def init_latent(self, noise, plan):
        return noise * jnp.sqrt(1.0 + plan["sigma"][0] ** 2)

    def model_input(self, x, step):
        return x / jnp.sqrt(1.0 + step["sigma"] ** 2), step["t"]

    def update(self, sched, x, eps, step):
        return S.euler_step(x, eps, step["sigma"], step["sigma_next"])


@register_sampler("turbo")
class TurboSampler(Sampler):
    """SD-Turbo: one step from pure noise to the x0 estimate (the
    paper's experiment).  ``fixed_steps`` tells the engine to
    normalize any requested step count to 1 — turbo is single-step by
    construction."""

    fixed_steps = 1

    def plan(self, sched, num_steps, num_padded):
        t_max = sched.num_train_timesteps - 1
        return _pad_plan({"t": jnp.array([t_max], jnp.int32)}, 1,
                         num_padded, {"t": t_max})

    def update(self, sched, x, eps, step):
        return S.turbo_step(sched, x, eps, step["t"])
