"""Pallas TPU kernel: flash attention (causal / sliding-window).

Online-softmax tiled attention for the prefill path.  The paper keeps
attention ("F16 dot products") on the host; on TPU attention is the
other big matmul consumer, so we provide a VMEM-tiled kernel — this is
the non-quantized bf16 share of the paper's Table I executed on-device.

Supports causal masking and a sliding window (h2o-danube SWA).  GQA is
handled by folding KV heads outside the kernel.  Grid is
(B*H, Sq/bq, Sk/bk) with running (max, sum) rescaling in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, window: int | None,
                  nk: int, bq: int, bk: int, sk_total: int, sq_total: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    qpos = (pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)) + (sk_total - sq_total)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                    # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    bq = min(bq, sq)
    bk = min(bk, sk)
    nk = pl.cdiv(sk, bk)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    grid = (b * h, pl.cdiv(sq, bq), nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            nk=nk, bq=bq, bk=bk, sk_total=sk, sq_total=sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
