"""Pallas TPU kernel: flash-decode (one-token GQA attention vs cache).

The decode-path analogue of flash attention: queries are the G query
heads per KV head at a single position; keys/values are the (possibly
ring-buffer) cache.  Validity is a *dynamic* length (`kv_len`, an SMEM
scalar): slots >= kv_len are masked.  Online-softmax over cache chunks
keeps the (G, C) logits in VMEM — on HBM the step reads only the cache
and writes (G, hd).

q: (B, Hkv, G, hd); k/v: (B, Hkv, C, hd); kv_len: (1,) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 1024
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, nk: int,
                   bk: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (G, hd)
    k = k_ref[0]                                    # (bk, hd)
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bk)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(kpos < len_ref[0], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                    ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array, *, scale: float | None = None,
                 bk: int = DEFAULT_BK,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, hd); k/v: (B, Hkv, C, hd); kv_len: (1,) int32."""
    b, h, g, d = q.shape
    c = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    bk = min(bk, c)
    nk = pl.cdiv(c, bk)
    qf = q.reshape(b * h, g, d)
    kf = k.reshape(b * h, c, d)
    vf = v.reshape(b * h, c, d)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, nk=nk, bk=bk),
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda gi, j: (gi, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda gi, j: (gi, j, 0)),
            pl.BlockSpec((1, bk, d), lambda gi, j: (gi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda gi, j: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, h, g, d)


def flash_decode_ref(q, k, v, kv_len, *, scale=None):
    """Oracle: masked softmax attention at one position."""
    b, h, g, d = q.shape
    c = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhgd,bhcd->bhgc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(c)[None, None, None, :] < kv_len[0]
    logits = jnp.where(valid, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhgc,bhcd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
