"""Pallas TPU kernel: flash-decode (one-token GQA attention vs cache).

The decode-path analogue of flash attention: queries are the G query
heads per KV head at a single position; keys/values are the (possibly
ring-buffer) cache.  Validity is a *dynamic* length (`kv_len`, an SMEM
scalar): slots >= kv_len are masked.  Online-softmax over cache chunks
keeps the (G, C) logits in VMEM — on HBM the step reads only the cache
and writes (G, hd).

q: (B, Hkv, G, hd); k/v: (B, Hkv, C, hd); kv_len: (1,) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 1024
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, nk: int,
                   bk: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (G, hd)
    k = k_ref[0]                                    # (bk, hd)
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bk)
    kpos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(kpos < len_ref[0], logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                    ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array, *, scale: float | None = None,
                 bk: int = DEFAULT_BK,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, hd); k/v: (B, Hkv, C, hd); kv_len: (1,) int32."""
    b, h, g, d = q.shape
    c = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    bk = min(bk, c)
    nk = pl.cdiv(c, bk)
    qf = q.reshape(b * h, g, d)
    kf = k.reshape(b * h, c, d)
    vf = v.reshape(b * h, c, d)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, nk=nk, bk=bk),
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda gi, j: (gi, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda gi, j: (gi, j, 0)),
            pl.BlockSpec((1, bk, d), lambda gi, j: (gi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda gi, j: (gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, h, g, d)


def _paged_decode_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, mb: int,
                         bs: int):
    bi = pl.program_id(0)
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                 # (G, hd)
    k = k_ref[0, 0]                                 # (bs, hd)
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bs)
    # Per-row position mask: logical index ji*bs + c is valid iff it is
    # <= positions[bi] (positions = last written index, inclusive).
    kpos = ji * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(kpos <= pos_ref[bi], logits, NEG_INF)
    # Pool blocks are recycled, not zeroed: the masked tail of a block
    # may hold stale bytes.  Masked probabilities are (near) zero, but
    # 0 * NaN = NaN, so neutralize the values too.
    vpos = ji * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    v = jnp.where(vpos <= pos_ref[bi], v_ref[0, 0], 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ji == mb - 1)
    def _done():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                       ).astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, positions: jax.Array, *,
                       scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Paged flash-decode: gather-by-block-table with per-row masking.

    q: (B, Hkv, G, hd); k/v pools: (NB, Hkv, bs, hd);
    block_tables: (B, MB) int32 physical block ids per slot;
    positions: (B,) int32 — last valid logical index per row
    (inclusive; the serving runtime passes the position it just wrote).

    The block table and positions ride in as scalar-prefetch operands,
    so each grid step's DMA fetches exactly one physical block — the
    HBM traffic of a decode step is the slot's *logical* cache, not the
    whole pool.  Rows must have at least one valid position.
    """
    b, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_tables.shape[1]
    if scale is None:
        scale = d ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, ji, tbl, pos: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda bi, hi, ji, tbl, pos:
                         (tbl[bi, ji], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda bi, hi, ji, tbl, pos:
                         (tbl[bi, ji], hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, hi, ji, tbl, pos: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, mb=mb, bs=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      q, k_pool, v_pool)
    return out


def flash_decode_paged_ref(q, k_pool, v_pool, block_tables, positions, *,
                           scale=None):
    """Oracle: gather blocks, mask idx <= positions[b], softmax."""
    b, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_tables.shape[1]
    if scale is None:
        scale = d ** -0.5

    def gather(pool):
        gth = pool[block_tables]                   # (B, MB, Hkv, bs, hd)
        return gth.transpose(0, 2, 1, 3, 4).reshape(b, h, mb * bs, d)

    keys, vals = gather(k_pool), gather(v_pool)
    logits = jnp.einsum("bhgd,bhcd->bhgc", q.astype(jnp.float32),
                        keys.astype(jnp.float32)) * scale
    valid = jnp.arange(mb * bs)[None, :] <= positions[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    vals = jnp.where(valid[:, None, :, None], vals, 0)  # 0 * NaN guard
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhgc,bhcd->bhgd", p,
                      vals.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q, k, v, kv_len, *, scale=None):
    """Oracle: masked softmax attention at one position."""
    b, h, g, d = q.shape
    c = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhgd,bhcd->bhgc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(c)[None, None, None, :] < kv_len[0]
    logits = jnp.where(valid, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhgc,bhcd->bhgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
