"""Pallas TPU kernel: fused paged flash-prefill (multi-token chunk).

Admission used to scan the decode step token-by-token; this kernel
attends an entire prompt chunk ``(T, Hkv, G, hd)`` in ONE program
against the paged KV pool — the fused multi-token prefill the CGLA-LLM
companion study singles out as the phase where kernel fusion pays off.

Per grid step ``(h, j)`` the kernel

1. **writes** the chunk's keys/values that land in physical block
   ``table[j]`` (an in-kernel scatter expressed as a one-hot matmul, so
   it lowers to the MXU instead of a per-row dynamic store), then
2. **attends** all T queries to that block with online softmax:
   causal masking *within* the chunk (query ``t`` sees chunk tokens
   ``<= t``) and per-row position masking against prior blocks
   (positions ``< pos0`` are history, positions ``>= pos0 + T`` are a
   recycled block's stale bytes and are value-neutralized like the
   decode kernel).

The pool outputs are aliased onto the pool inputs
(``input_output_aliases``), so blocks not named by the table are
untouched and the chunk's KV lands in place — one kernel launch per
chunk replaces T decode-step launches.

Layouts: q ``(T, Hkv, G, hd)``; k_new/v_new ``(T, Hkv, hd)``;
pools ``(NB, Hkv, bs, hd)``; block_table ``(MB,)`` int32;
pos0 scalar int32 (tokens already cached for this slot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(tbl_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
                    o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref, *,
                    scale: float, g: int, t: int, bs: int, mb: int,
                    window: int | None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos0 = pos_ref[0]
    # ---- in-kernel KV write: chunk rows landing in this block ----
    # Global position of block offset c is j*bs + c; the chunk row that
    # lands there is r = j*bs + c - pos0 (if 0 <= r < t).  Expressed as
    # a one-hot (bs, t) matmul so the scatter runs on the MXU.
    kcol = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    row = kcol - pos0                                       # (bs, 1)
    write = (row >= 0) & (row < t)                          # (bs, 1)
    onehot = (row == jax.lax.broadcasted_iota(
        jnp.int32, (bs, t), 1)).astype(jnp.float32)         # (bs, t)
    k_wr = jax.lax.dot_general(
        onehot, kn_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())))         # (bs, hd)
    v_wr = jax.lax.dot_general(
        onehot, vn_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())))
    k_blk = jnp.where(write, k_wr.astype(kp_ref.dtype), kp_ref[0, 0])
    v_blk = jnp.where(write, v_wr.astype(vp_ref.dtype), vp_ref[0, 0])
    ko_ref[0, 0] = k_blk
    vo_ref[0, 0] = v_blk

    # ---- attend all T queries to the (now current) block ----
    q = q_ref[0]                                            # (t*g, hd)
    logits = jax.lax.dot_general(
        q, k_blk, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale         # (t*g, bs)
    qpos = pos0 + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 0) // g
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos <= qpos                  # history + intra-chunk causal
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    # Positions past the chunk's last token are a recycled block's
    # stale bytes; masked p is ~0 but 0 * NaN = NaN, so zero the values.
    v_use = jnp.where(kcol < pos0 + t, v_blk, 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_use.dtype), v_use,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == mb - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                    ).astype(o_ref.dtype)


def flash_prefill_paged(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                        k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, pos0: jax.Array, *,
                        scale: float | None = None,
                        window: int | None = None,
                        interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused prefill of one chunk for one slot.

    q: (T, Hkv, G, hd); k_new/v_new: (T, Hkv, hd);
    k/v pools: (NB, Hkv, bs, hd); block_table: (MB,) int32;
    pos0: scalar int32 — tokens already cached (the chunk occupies
    positions ``pos0 .. pos0+T-1``).

    Returns ``(out (T, Hkv, G, hd), k_pool', v_pool')`` where the
    pools carry the chunk's KV written in place (outputs are aliased
    onto the pool inputs; unlisted blocks are untouched).
    """
    t, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_table.shape[0]
    if scale is None:
        scale = d ** -0.5
    qf = q.transpose(1, 0, 2, 3).reshape(h, t * g, d)
    knf = k_new.transpose(1, 0, 2)
    vnf = v_new.transpose(1, 0, 2)
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h, mb),
        in_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, d), jnp.float32),
        ],
    )
    out, kp, vp = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, g=g, t=t, bs=bs,
                          mb=mb, window=window),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((h, t * g, d), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # Inputs are numbered incl. the two scalar-prefetch operands:
        # 5/6 are k_pool/v_pool -> outputs 1/2 (in-place KV writes).
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos0, qf, knf, vnf, k_pool, v_pool)
    return out.reshape(h, t, g, d).transpose(1, 0, 2, 3), kp, vp


def flash_prefill_paged_ref(q, k_new, v_new, k_pool, v_pool, block_table,
                            pos0, *, scale=None, window=None):
    """Oracle (plain XLA): scatter the chunk into the pools, gather the
    table, causal + position-masked softmax.  Also the CPU serving path
    (`ops.paged_prefill_attention` dispatches here off-TPU)."""
    t, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_table.shape[0]
    if scale is None:
        scale = d ** -0.5
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(())
    chunk_pos = pos0 + jnp.arange(t)
    bids = block_table[chunk_pos // bs]
    offs = chunk_pos % bs
    k_pool = k_pool.at[bids, :, offs].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[bids, :, offs].set(v_new.astype(v_pool.dtype))

    def gather(pool):
        gth = pool[block_table]                # (MB, Hkv, bs, hd)
        return gth.transpose(1, 0, 2, 3).reshape(h, mb * bs, d)

    keys, vals = gather(k_pool), gather(v_pool)
    logits = jnp.einsum("thgd,hcd->thgc", q.astype(jnp.float32),
                        keys.astype(jnp.float32)) * scale
    qpos = chunk_pos[:, None]
    kpos = jnp.arange(mb * bs)[None, :]
    mask = kpos <= qpos                                     # (t, C)
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    # Stale bytes past the chunk's last token: 0 * NaN guard.
    vals = jnp.where((kpos[0] < pos0 + t)[None, :, None], vals, 0)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("thgc,hcd->thgd", p, vals.astype(jnp.float32))
    return out.astype(q.dtype), k_pool, v_pool
