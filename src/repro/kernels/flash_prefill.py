"""Pallas TPU kernel: fused paged flash-prefill (multi-token chunk).

Admission used to scan the decode step token-by-token; this kernel
attends an entire prompt chunk ``(T, Hkv, G, hd)`` in ONE program
against the paged KV pool — the fused multi-token prefill the CGLA-LLM
companion study singles out as the phase where kernel fusion pays off.

Per grid step ``(h, j)`` the kernel

1. **writes** the chunk's keys/values that land in physical block
   ``table[j]`` (an in-kernel scatter expressed as a one-hot matmul, so
   it lowers to the MXU instead of a per-row dynamic store), then
2. **attends** all T queries to that block with online softmax:
   causal masking *within* the chunk (query ``t`` sees chunk tokens
   ``<= t``) and per-row position masking against prior blocks
   (positions ``< pos0`` are history, positions ``>= pos0 + T`` are a
   recycled block's stale bytes and are value-neutralized like the
   decode kernel).

The pool outputs are aliased onto the pool inputs
(``input_output_aliases``), so blocks not named by the table are
untouched and the chunk's KV lands in place — one kernel launch per
chunk replaces T decode-step launches.

Layouts: q ``(T, Hkv, G, hd)``; k_new/v_new ``(T, Hkv, hd)``;
pools ``(NB, Hkv, bs, hd)``; block_table ``(MB,)`` int32;
pos0 scalar int32 (tokens already cached for this slot).

``flash_prefill_paged_q8`` is the Q8_0 sibling for quantized KV pools:
same grid and write discipline, but the chunk's KV is **requantized
in-kernel** (per-32 blocks along ``hd``, GGML Q8_0 semantics identical
to ``core.quant.quantize_q8_0``) and scattered into int8 quant pools
plus fp16 scale pools — four aliased pool outputs instead of two.  The
block is dequantized to bf16 after the merge — the precision the scan
path's ``_dequantize_kv`` reads the pool at — so the chunk's own tokens
attend to exactly what later decode steps will read (matching the scan
path's quantize-then-dequantize round trip).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant

NEG_INF = -1e30
QK = quant.QK8_0  # 32: Q8_0 block size along head_dim


def _prefill_kernel(tbl_ref, pos_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref,
                    o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref, *,
                    scale: float, g: int, t: int, bs: int, mb: int,
                    window: int | None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos0 = pos_ref[0]
    # ---- in-kernel KV write: chunk rows landing in this block ----
    # Global position of block offset c is j*bs + c; the chunk row that
    # lands there is r = j*bs + c - pos0 (if 0 <= r < t).  Expressed as
    # a one-hot (bs, t) matmul so the scatter runs on the MXU.
    kcol = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    row = kcol - pos0                                       # (bs, 1)
    write = (row >= 0) & (row < t)                          # (bs, 1)
    onehot = (row == jax.lax.broadcasted_iota(
        jnp.int32, (bs, t), 1)).astype(jnp.float32)         # (bs, t)
    k_wr = jax.lax.dot_general(
        onehot, kn_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())))         # (bs, hd)
    v_wr = jax.lax.dot_general(
        onehot, vn_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())))
    k_blk = jnp.where(write, k_wr.astype(kp_ref.dtype), kp_ref[0, 0])
    v_blk = jnp.where(write, v_wr.astype(vp_ref.dtype), vp_ref[0, 0])
    ko_ref[0, 0] = k_blk
    vo_ref[0, 0] = v_blk

    # ---- attend all T queries to the (now current) block ----
    q = q_ref[0]                                            # (t*g, hd)
    logits = jax.lax.dot_general(
        q, k_blk, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale         # (t*g, bs)
    qpos = pos0 + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 0) // g
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos <= qpos                  # history + intra-chunk causal
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)
    # Positions past the chunk's last token are a recycled block's
    # stale bytes; masked p is ~0 but 0 * NaN = NaN, so zero the values.
    v_use = jnp.where(kcol < pos0 + t, v_blk, 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_use.dtype), v_use,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == mb - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                    ).astype(o_ref.dtype)


def flash_prefill_paged(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                        k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, pos0: jax.Array, *,
                        scale: float | None = None,
                        window: int | None = None,
                        interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused prefill of one chunk for one slot.

    q: (T, Hkv, G, hd); k_new/v_new: (T, Hkv, hd);
    k/v pools: (NB, Hkv, bs, hd); block_table: (MB,) int32;
    pos0: scalar int32 — tokens already cached (the chunk occupies
    positions ``pos0 .. pos0+T-1``).

    Returns ``(out (T, Hkv, G, hd), k_pool', v_pool')`` where the
    pools carry the chunk's KV written in place (outputs are aliased
    onto the pool inputs; unlisted blocks are untouched).
    """
    t, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_table.shape[0]
    if scale is None:
        scale = d ** -0.5
    qf = q.transpose(1, 0, 2, 3).reshape(h, t * g, d)
    knf = k_new.transpose(1, 0, 2)
    vnf = v_new.transpose(1, 0, 2)
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h, mb),
        in_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, d), jnp.float32),
        ],
    )
    out, kp, vp = pl.pallas_call(
        functools.partial(_prefill_kernel, scale=scale, g=g, t=t, bs=bs,
                          mb=mb, window=window),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((h, t * g, d), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # Inputs are numbered incl. the two scalar-prefetch operands:
        # 5/6 are k_pool/v_pool -> outputs 1/2 (in-place KV writes).
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos0, qf, knf, vnf, k_pool, v_pool)
    return out.reshape(h, t, g, d).transpose(1, 0, 2, 3), kp, vp


def flash_prefill_paged_ref(q, k_new, v_new, k_pool, v_pool, block_table,
                            pos0, *, scale=None, window=None):
    """Oracle (plain XLA): scatter the chunk into the pools, gather the
    table, causal + position-masked softmax.  Also the CPU serving path
    (`ops.paged_prefill_attention` dispatches here off-TPU)."""
    t, h, g, d = q.shape
    bs = k_pool.shape[2]
    mb = block_table.shape[0]
    if scale is None:
        scale = d ** -0.5
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(())
    chunk_pos = pos0 + jnp.arange(t)
    bids = block_table[chunk_pos // bs]
    offs = chunk_pos % bs
    k_pool = k_pool.at[bids, :, offs].set(k_new.astype(k_pool.dtype))
    v_pool = v_pool.at[bids, :, offs].set(v_new.astype(v_pool.dtype))

    def gather(pool):
        gth = pool[block_table]                # (MB, Hkv, bs, hd)
        return gth.transpose(1, 0, 2, 3).reshape(h, mb * bs, d)

    keys, vals = gather(k_pool), gather(v_pool)
    logits = jnp.einsum("thgd,hcd->thgc", q.astype(jnp.float32),
                        keys.astype(jnp.float32)) * scale
    qpos = chunk_pos[:, None]
    kpos = jnp.arange(mb * bs)[None, :]
    mask = kpos <= qpos                                     # (t, C)
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    # Stale bytes past the chunk's last token: 0 * NaN guard.
    vals = jnp.where((kpos[0] < pos0 + t)[None, :, None], vals, 0)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("thgc,hcd->thgd", p, vals.astype(jnp.float32))
    return out.astype(q.dtype), k_pool, v_pool


# ------------------------------------------------------------- Q8_0 KV


def _q8_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Row-wise Q8_0 over per-32 blocks of the last axis.

    Delegates to ``quant.quantize_q8_0`` so the in-kernel requantization
    is definitionally the same math as the scan path's ``_quantize_kv``
    (fp16 scale saturation included).  Returns ``(q f32, d f32-via-f16)``
    — quants stay f32 so the scatter runs on the MXU; the f32<->int8 and
    f32<->f16 round trips are exact for these values.
    """
    t8 = quant.quantize_q8_0(x.astype(jnp.float32))
    return t8.qs.astype(jnp.float32), t8.d.astype(jnp.float32)


def _prefill_kernel_q8(tbl_ref, pos_ref, q_ref, kn_ref, vn_ref,
                       kqp_ref, vqp_ref, ksp_ref, vsp_ref,
                       o_ref, kqo_ref, vqo_ref, kso_ref, vso_ref,
                       m_ref, l_ref, acc_ref, *,
                       scale: float, g: int, t: int, bs: int, mb: int,
                       window: int | None):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos0 = pos_ref[0]
    ds = q_ref.shape[-1] // QK                              # scale cols
    # ---- requantize the chunk's KV rows (Q8_0 per 32 along hd) ----
    k_q, k_d = _q8_rows(kn_ref[0])                          # (t,d) (t,ds)
    v_q, v_d = _q8_rows(vn_ref[0])
    # ---- in-kernel scatter of quants AND scales into this block ----
    kcol = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
    row = kcol - pos0                                       # (bs, 1)
    write = (row >= 0) & (row < t)                          # (bs, 1)
    onehot = (row == jax.lax.broadcasted_iota(
        jnp.int32, (bs, t), 1)).astype(jnp.float32)         # (bs, t)

    def scatter(chunk_rows, pool_ref):
        wr = jax.lax.dot_general(
            onehot, chunk_rows,
            dimension_numbers=(((1,), (0,)), ((), ())))
        return jnp.where(write, wr, pool_ref[0, 0].astype(jnp.float32))

    kq_blk = scatter(k_q, kqp_ref)                          # (bs, d) f32
    vq_blk = scatter(v_q, vqp_ref)
    ks_blk = scatter(k_d, ksp_ref)                          # (bs, ds) f32
    vs_blk = scatter(v_d, vsp_ref)
    kqo_ref[0, 0] = kq_blk.astype(kqo_ref.dtype)            # int8, exact
    vqo_ref[0, 0] = vq_blk.astype(vqo_ref.dtype)
    kso_ref[0, 0] = ks_blk.astype(kso_ref.dtype)            # f16, exact
    vso_ref[0, 0] = vs_blk.astype(vso_ref.dtype)

    # ---- dequantize the merged block and attend ----
    # Dequant rounds through bf16 — the precision the scan path's
    # _dequantize_kv reads the pool at — then computes in f32 exactly
    # like the decode oracle, so fused and scan attention see
    # bit-identical K/V and diverge only by accumulation order.
    d = q_ref.shape[-1]
    k_deq = (kq_blk.reshape(bs, ds, QK) * ks_blk[..., None]
             ).reshape(bs, d).astype(jnp.bfloat16).astype(jnp.float32)
    v_deq = (vq_blk.reshape(bs, ds, QK) * vs_blk[..., None]
             ).reshape(bs, d).astype(jnp.bfloat16).astype(jnp.float32)
    q = q_ref[0].astype(jnp.float32)                        # (t*g, hd)
    logits = jax.lax.dot_general(
        q, k_deq, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale         # (t*g, bs)
    qpos = pos0 + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 0) // g
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    mask = kpos <= qpos                  # history + intra-chunk causal
    if window is not None:
        mask &= kpos > qpos - window
    # Stale scales in a recycled block may be NaN: every stale column is
    # masked (kpos >= pos0 + t > qpos), so `where` replaces its NaN
    # logits with NEG_INF before the row max.
    logits = jnp.where(mask, logits, NEG_INF)
    v_use = jnp.where(kcol < pos0 + t, v_deq, 0.0)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v_use, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == mb - 1)
    def _done():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)
                    ).astype(o_ref.dtype)


def flash_prefill_paged_q8(q: jax.Array, k_new: jax.Array,
                           v_new: jax.Array,
                           kq_pool: jax.Array, vq_pool: jax.Array,
                           ks_pool: jax.Array, vs_pool: jax.Array,
                           block_table: jax.Array, pos0: jax.Array, *,
                           scale: float | None = None,
                           window: int | None = None,
                           interpret: bool = False):
    """Fused Q8_0 prefill of one chunk for one slot.

    q: (T, Hkv, G, hd); k_new/v_new: (T, Hkv, hd) **unquantized**;
    kq/vq pools: (NB, Hkv, bs, hd) int8; ks/vs pools:
    (NB, Hkv, bs, hd // 32) fp16; block_table: (MB,) int32; pos0:
    scalar int32.

    Returns ``(out, kq_pool', vq_pool', ks_pool', vs_pool')`` with the
    chunk's KV requantized in-kernel and written in place (all four
    pool outputs aliased; unlisted blocks untouched).
    """
    t, h, g, d = q.shape
    if d % QK:
        raise ValueError(f"head_dim {d} not divisible by {QK}")
    bs = kq_pool.shape[2]
    ds = d // QK
    mb = block_table.shape[0]
    if scale is None:
        scale = d ** -0.5
    qf = q.transpose(1, 0, 2, 3).reshape(h, t * g, d)
    knf = k_new.transpose(1, 0, 2)
    vnf = v_new.transpose(1, 0, 2)
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(1)
    quant_spec = pl.BlockSpec((1, 1, bs, d),
                              lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0))
    scale_spec = pl.BlockSpec((1, 1, bs, ds),
                              lambda hi, j, tbl, pos: (tbl[j], hi, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h, mb),
        in_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            pl.BlockSpec((1, t, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            quant_spec, quant_spec, scale_spec, scale_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, t * g, d),
                         lambda hi, j, tbl, pos: (hi, 0, 0)),
            quant_spec, quant_spec, scale_spec, scale_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, d), jnp.float32),
        ],
    )
    out, kq, vq, ks, vs = pl.pallas_call(
        functools.partial(_prefill_kernel_q8, scale=scale, g=g, t=t,
                          bs=bs, mb=mb, window=window),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((h, t * g, d), q.dtype),
            jax.ShapeDtypeStruct(kq_pool.shape, kq_pool.dtype),
            jax.ShapeDtypeStruct(vq_pool.shape, vq_pool.dtype),
            jax.ShapeDtypeStruct(ks_pool.shape, ks_pool.dtype),
            jax.ShapeDtypeStruct(vs_pool.shape, vs_pool.dtype),
        ],
        # Inputs numbered incl. the two scalar-prefetch operands: 5..8
        # are kq/vq/ks/vs pools -> outputs 1..4 (in-place KV writes).
        input_output_aliases={5: 1, 6: 2, 7: 3, 8: 4},
        interpret=interpret,
    )(block_table.astype(jnp.int32), pos0, qf, knf, vnf,
      kq_pool, vq_pool, ks_pool, vs_pool)
    return out.reshape(h, t, g, d).transpose(1, 0, 2, 3), kq, vq, ks, vs


def flash_prefill_paged_q8_ref(q, k_new, v_new, kq_pool, vq_pool,
                               ks_pool, vs_pool, block_table, pos0, *,
                               scale=None, window=None):
    """Oracle (plain XLA) for the Q8_0 fused prefill: requantize the
    chunk with ``quant.quantize_q8_0``, scatter quants + scales, gather
    the table, dequantize to bf16, causal + position-masked softmax.
    Also the CPU serving path for quantized pools."""
    t, h, g, d = q.shape
    bs = kq_pool.shape[2]
    mb = block_table.shape[0]
    ds = d // QK
    if scale is None:
        scale = d ** -0.5
    pos0 = jnp.asarray(pos0, jnp.int32).reshape(())
    chunk_pos = pos0 + jnp.arange(t)
    bids = block_table[chunk_pos // bs]
    offs = chunk_pos % bs
    k8 = quant.quantize_q8_0(k_new.astype(jnp.float32))  # (t, Hkv, d)
    v8 = quant.quantize_q8_0(v_new.astype(jnp.float32))
    kq_pool = kq_pool.at[bids, :, offs].set(k8.qs)
    vq_pool = vq_pool.at[bids, :, offs].set(v8.qs)
    ks_pool = ks_pool.at[bids, :, offs].set(k8.d.astype(ks_pool.dtype))
    vs_pool = vs_pool.at[bids, :, offs].set(v8.d.astype(vs_pool.dtype))

    def gather_deq(qpool, spool):
        gq = qpool[block_table].astype(jnp.float32)  # (MB, Hkv, bs, d)
        gs = spool[block_table].astype(jnp.float32)  # (MB, Hkv, bs, ds)
        deq = (gq.reshape(mb, h, bs, ds, QK) * gs[..., None]
               ).reshape(mb, h, bs, d)
        # Round through bf16 — the precision the scan path's
        # _dequantize_kv reads the pool at — then compute in f32 like
        # the decode oracle.
        return (deq.transpose(1, 0, 2, 3).reshape(h, mb * bs, d)
                .astype(jnp.bfloat16).astype(jnp.float32))

    keys, vals = gather_deq(kq_pool, ks_pool), gather_deq(vq_pool,
                                                          vs_pool)
    logits = jnp.einsum("thgd,hcd->thgc", q.astype(jnp.float32),
                        keys) * scale
    qpos = chunk_pos[:, None]
    kpos = jnp.arange(mb * bs)[None, :]
    mask = kpos <= qpos                                     # (t, C)
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    # Stale bytes (possibly NaN scales) past the chunk's last token.
    vals = jnp.where((kpos[0] < pos0 + t)[None, :, None], vals, 0)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("thgc,hcd->thgd", p, vals)
    return out.astype(q.dtype), kq_pool, vq_pool, ks_pool, vs_pool
