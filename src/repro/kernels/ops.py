"""jit'd public entry points for the kernels, with backend dispatch.

On TPU the Pallas kernels run natively.  On CPU (this container, and the
multi-pod dry-run's 512 host devices) we lower the *same math* through
plain-XLA paths (``ref``-equivalent) so that:

* smoke tests and the end-to-end examples run fast on CPU;
* the dry-run HLO carries the true quantized dtypes (int8/uint8 weight
  buffers), so ``cost_analysis`` byte counts reflect the paper's
  bandwidth savings;
* Pallas kernels are still exercised in ``interpret=True`` mode by the
  kernel test-suite.

Set ``force="pallas" | "xla" | "interpret"`` to override dispatch.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import Q3KTensor, Q4_0Tensor, Q8_0Tensor
from repro.kernels import ref
from repro.kernels import q8_matmul as _q8
from repro.kernels import q4_matmul as _q4
from repro.kernels import q3k_matmul as _q3k
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_prefill as _fp

Force = Literal["auto", "pallas", "xla", "interpret"]


def _use_pallas(force: Force) -> tuple[bool, bool]:
    """-> (use_pallas_kernel, interpret)."""
    if force == "pallas":
        return True, False
    if force == "interpret":
        return True, True
    if force == "xla":
        return False, False
    return (jax.default_backend() == "tpu"), False


def _flatten_lead(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def quantized_matmul(x: jax.Array, w, *, force: Force = "auto",
                     out_dtype=None) -> jax.Array:
    """y[..., n] = x[..., k] @ dequant(w)[n, k] for Q8_0 / Q3_K weights.

    The weight tensor keeps its quantized storage in HBM; dequantization
    is fused into the matmul (Pallas) or expressed as an int8-load +
    convert + dot in XLA (same byte traffic).
    """
    out_dtype = out_dtype or x.dtype
    xf, lead = _flatten_lead(x)
    use_pallas, interp = _use_pallas(force)
    if isinstance(w, Q8_0Tensor):
        n = w.qs.shape[0]
        # Tail-padded (ragged) tensors go through the ref path: the
        # Pallas kernels expect x and w to share a 32-aligned K.
        if use_pallas and w.logical is None:
            y = _q8.q8_matmul(xf, w.qs, w.d.astype(jnp.float32),
                              interpret=interp)
        else:
            y = ref.q8_matmul_ref(xf, w)
    elif isinstance(w, Q4_0Tensor):
        n = w.qs.shape[0]
        if use_pallas and w.logical is None:
            y = _q4.q4_matmul(xf, w.qs, w.d.astype(jnp.float32),
                              interpret=interp)
        else:
            y = ref.q4_matmul_ref(xf, w)
    elif isinstance(w, Q3KTensor):
        n = w.ql.shape[0]
        if use_pallas:
            sc = quant.unpack_scales6(w.scales).reshape(n, -1)
            y = _q3k.q3k_matmul(xf, w.ql, w.qh, sc,
                                w.d.astype(jnp.float32), interpret=interp)
        else:
            y = ref.q3k_matmul_ref(xf, w)
    else:  # plain dense fallback: w is (N, K) array
        n = w.shape[0]
        y = jax.lax.dot_general(
            xf.astype(w.dtype), w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    return y.reshape(*lead, n).astype(out_dtype)


def quantized_matmul_w8a8(x: jax.Array, w: Q8_0Tensor, *,
                          force: Force = "auto",
                          out_dtype=None) -> jax.Array:
    """Integer-path (OP_SML8) matmul: activations quantized to Q8 blocks."""
    out_dtype = out_dtype or x.dtype
    xf, lead = _flatten_lead(x)
    xa = quant.quantize_q8_0(xf)
    xs = xa.d.astype(jnp.float32)
    use_pallas, interp = _use_pallas(force)
    if use_pallas:
        y = _q8.q8_matmul_w8a8(xa.qs, xs, w.qs, w.d.astype(jnp.float32),
                               interpret=interp)
    else:
        y = ref.q8_matmul_w8a8_ref(xa.qs, xs, w)
    return y.reshape(*lead, w.qs.shape[0]).astype(out_dtype)


def _chunked_attention(q, k, v, *, causal, window, scale,
                       q_chunk: int) -> jax.Array:
    """Query-chunked attention for the XLA path: peak intermediate is
    (B, H, q_chunk, Sk) instead of (B, H, Sq, Sk) — the flash-kernel
    memory behaviour expressed in plain XLA (scan over query chunks)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    nc = sq // q_chunk
    qs = q.reshape(b, h, nc, q_chunk, d).transpose(2, 0, 1, 3, 4)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def body(_, args):
        ci, qc = args                              # qc: (B,H,bq,D)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                            kf) * scale
        qpos = ci * q_chunk + jnp.arange(q_chunk)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        mask = jnp.ones((q_chunk, sk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        return None, jnp.einsum("bhqk,bhkd->bhqd", p, vf)

    _, out = jax.lax.scan(body, None, (jnp.arange(nc), qs))
    return out.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, d).astype(q.dtype)


ATTN_CHUNK = 1024


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None,
              force: Force = "auto",
              q_chunk: int | None = None) -> jax.Array:
    """Flash attention with GQA folding. q:(B,Hq,Sq,D), k/v:(B,Hkv,Sk,D).

    ``q_chunk=0`` forces the unchunked XLA path (cost probes).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    from repro.core import qlinear as _ql
    _ql.record_matmul("attn_scores", "activation", sq, k.shape[2], d,
                      count=b * hq, act_act=True)
    _ql.record_matmul("attn_pv", "activation", sq, d, k.shape[2],
                      count=b * hq, act_act=True)
    if hq != hkv:
        assert hq % hkv == 0
        rep = hq // hkv
        from repro.distributed import ctx as _ctx
        k = _ctx.heads(jnp.repeat(k, rep, axis=1))
        v = _ctx.heads(jnp.repeat(v, rep, axis=1))
    use_pallas, interp = _use_pallas(force)
    if use_pallas and sq >= 8:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, interpret=interp)
    if scale is None:
        scale = d ** -0.5
    chunk = ATTN_CHUNK if q_chunk is None else q_chunk
    if chunk and sq > chunk and sq % chunk == 0:
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, q_chunk=chunk)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def paged_prefill_attention(q, k_new, v_new, k_pool, v_pool, block_table,
                            pos0, *, window: int | None = None,
                            scale: float | None = None,
                            force: Force = "auto",
                            k_scale_pool=None, v_scale_pool=None):
    """Fused paged prefill of one chunk for one slot (see
    ``kernels.flash_prefill``): writes the chunk's KV into its
    destination blocks and attends all T queries in one program.

    q: (T, Hkv, G, hd); k_new/v_new: (T, Hkv, hd) unquantized; pools:
    (NB, Hkv, bs, hd); block_table: (MB,) int32; pos0: scalar int32.
    Returns ``(out, k_pool', v_pool')``.

    With ``k_scale_pool``/``v_scale_pool`` given, the pools are Q8_0
    (int8 quants + fp16 per-32 scales): dispatches the quantized sibling
    kernel, which requantizes the chunk in-kernel, and returns the
    5-tuple ``(out, kq', vq', ks', vs')``.
    """
    use_pallas, interp = _use_pallas(force)
    if k_scale_pool is not None:
        if use_pallas:
            return _fp.flash_prefill_paged_q8(
                q, k_new, v_new, k_pool, v_pool, k_scale_pool,
                v_scale_pool, block_table, pos0, scale=scale,
                window=window, interpret=interp)
        return _fp.flash_prefill_paged_q8_ref(
            q, k_new, v_new, k_pool, v_pool, k_scale_pool, v_scale_pool,
            block_table, pos0, scale=scale, window=window)
    if use_pallas:
        return _fp.flash_prefill_paged(q, k_new, v_new, k_pool, v_pool,
                                       block_table, pos0, scale=scale,
                                       window=window, interpret=interp)
    return _fp.flash_prefill_paged_ref(q, k_new, v_new, k_pool, v_pool,
                                       block_table, pos0, scale=scale,
                                       window=window)
