"""Pallas TPU kernel: fused-unpack Q3_K matmul.

TPU adaptation of the paper's Q3_K pipeline (Fig. 4).  IMAX3 adds
OP_CVT53 to repack the 6-bit scales / 2+1-bit quants into a unified
SIMD-friendly format inside the PE array; here the same restructuring
happens in VMEM with vectorized shifts/masks on the VPU:

* ``ql`` (2-bit low parts, 4/byte) and ``qh`` (high bits, 8/byte) are
  unpacked and combined to signed 3-bit values in [-4, 3];
* sub-block scales arrive as int8 codes (unpacked from the 12-byte
  6-bit packing by the wrapper — a K/16-sized side input, ~2% of the
  weight bytes) and are expanded to effective multipliers d*(sc-32);
* dequantized bf16 weights feed the MXU; accumulation is f32.

Only ~3.4 bits/weight cross the HBM boundary, which is the paper's core
insight applied to the TPU memory hierarchy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import Q3K_SUB

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _unpack_q3_block(ql, qh, bn, bk):
    """(bn,bk/4) uint8 + (bn,bk/8) uint8 -> (bn,bk) int8 in [-4,3]."""
    shifts = jnp.arange(4, dtype=jnp.int32) * 2
    low = (ql[..., None].astype(jnp.int32) >> shifts) & 3     # (bn,bk/4,4)
    low = low.reshape(bn, bk)
    hshifts = jnp.arange(8, dtype=jnp.int32)
    hi = (qh[..., None].astype(jnp.int32) >> hshifts) & 1     # (bn,bk/8,8)
    hi = hi.reshape(bn, bk)
    return (low | (hi << 2)) - 4                              # int32 in [-4,3]


def _q3k_kernel(x_ref, ql_ref, qh_ref, sc_ref, d_ref, o_ref, acc_ref,
                *, nk: int):
    """x:(bm,bk) bf16 | ql:(bn,bk/4) | qh:(bn,bk/8) | sc:(bn,bk/16) int8
    | d:(bn,bk/256) f32 -> o:(bm,bn) f32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bn = ql_ref.shape[0]
    bk = ql_ref.shape[1] * 4
    q = _unpack_q3_block(ql_ref[...], qh_ref[...], bn, bk)    # OP_CVT53
    # Effective scale per 16-weight sub-block: d * (sc - 32).
    nsb = bk // Q3K_SUB
    d = d_ref[...]                                            # (bn, bk/256)
    d16 = jnp.repeat(d, nsb // d.shape[1], axis=1)            # (bn, nsb)
    eff = d16 * (sc_ref[...].astype(jnp.float32) - 32.0)
    w = (q.astype(jnp.float32).reshape(bn, nsb, Q3K_SUB)
         * eff[:, :, None]).reshape(bn, bk).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def q3k_matmul(x: jax.Array, ql: jax.Array, qh: jax.Array,
               sc: jax.Array, d: jax.Array,
               *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
               bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """y = x @ dequant(w).T with w in Q3_K.

    x: (M, K) bf16; ql: (N, K/4) uint8; qh: (N, K/8) uint8;
    sc: (N, K/16) uint8 6-bit codes; d: (N, K/256) f32. Returns (M, N) f32.
    """
    m, k = x.shape
    n = ql.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert bk % 256 == 0, "bk must cover whole Q3_K super-blocks"
    nk = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nk)
    return pl.pallas_call(
        functools.partial(_q3k_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 4), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 8), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 16), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 256), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), ql, qh, sc, d)
