"""Pallas TPU kernel: fused-unpack Q4_0 matmul.

Same structure as the Q8_0 kernel, with an in-VMEM nibble unpack
(two 4-bit quants per byte, offset 8): only 4.5 bits/weight cross the
HBM boundary.  Grid (M/bm, N/bn, K/bk), K innermost accumulating into
a VMEM scratch tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QK8_0

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _q4_kernel(x_ref, qs_ref, ws_ref, o_ref, acc_ref, *, nk: int):
    """x:(bm,bk) bf16 | qs:(bn,bk/2) uint8 | ws:(bn,bk/32) f32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bn = qs_ref.shape[0]
    bk = qs_ref.shape[1] * 2
    qs = qs_ref[...].astype(jnp.int32)
    lo = (qs & 0x0F) - 8
    hi = ((qs >> 4) & 0x0F) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(bn, bk)   # nibble unpack
    w = (q.astype(jnp.float32).reshape(bn, bk // QK8_0, QK8_0)
         * ws_ref[...][:, :, None]).reshape(bn, bk).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def q4_matmul(x: jax.Array, qs: jax.Array, ws: jax.Array,
              *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """y = x @ dequant(w).T with w in Q4_0.

    x: (M, K) bf16; qs: (N, K/2) uint8; ws: (N, K/32) f32 -> (M, N) f32.
    """
    m, k = x.shape
    n = qs.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert bk % QK8_0 == 0
    nk = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nk)
    return pl.pallas_call(
        functools.partial(_q4_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // QK8_0), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), qs, ws)
