"""Pallas TPU kernel: fused-dequant Q8_0 matmul (+ integer w8a8 variant).

TPU adaptation of the paper's IMAX3 Q8_0 dot-product pipeline (Fig. 3):

* IMAX streams 32-element quantized blocks through PE-local LMM; the
  int8 multiply-adds (OP_SML8) accumulate into 24-bit (OP_AD24) and a
  final fp32 scale multiply produces the output.
* Here the quantized blocks are staged HBM->VMEM by ``BlockSpec`` tiles;
  only *quantized bytes* cross the bandwidth-limited HBM boundary.  The
  ``dequant`` variant expands int8->bf16 in VMEM (VPU) and feeds the MXU
  — optimal when the layer is memory-bound (decode).  The ``int8``
  variant keeps the integer dot (MXU int8 path, int32 accumulate — a
  superset of OP_AD24's 24 bits) and applies the per-block scale product
  afterwards, faithful to the paper's dataflow.

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost ("arbitrary")
accumulating into a VMEM scratch tile; M/N are parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import QK8_0

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _dequant_kernel(x_ref, wq_ref, ws_ref, o_ref, acc_ref, *, nk: int):
    """x:(bm,bk) bf16 | wq:(bn,bk) int8 | ws:(bn,bk/32) f32 -> o:(bm,bn) f32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bn, bk = wq_ref.shape
    # In-VMEM dequantization: int8 -> f32 -> scaled bf16 (never touches HBM).
    w = wq_ref[...].astype(jnp.float32).reshape(bn, bk // QK8_0, QK8_0)
    w = (w * ws_ref[...][:, :, None]).reshape(bn, bk).astype(jnp.bfloat16)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def q8_matmul(x: jax.Array, wq: jax.Array, ws: jax.Array,
              *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """y = x @ dequant(w).T with w in Q8_0 (fused dequant).

    x: (M, K) bf16; wq: (N, K) int8; ws: (N, K/32) f32.  Returns (M, N) f32.
    """
    m, k = x.shape
    n = wq.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert bk % QK8_0 == 0
    nk = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nk)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // QK8_0), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.bfloat16), wq, ws)


def _w8a8_kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, acc_ref, *, nk: int):
    """Integer path: per-32-block int8 dot + scale product accumulate."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm, bk = xq_ref.shape
    bn = wq_ref.shape[0]
    nb = bk // QK8_0
    a = xq_ref[...].reshape(bm, nb, QK8_0)
    b = wq_ref[...].reshape(bn, nb, QK8_0)
    # OP_SML8 analogue: int8 x int8 -> int32 block dots (batched over nb).
    ints = jax.lax.dot_general(
        a, b, dimension_numbers=(((2,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.int32)                    # (nb, bm, bn)
    scaled = (ints.astype(jnp.float32)
              * xs_ref[...].T[:, :, None]
              * ws_ref[...].T[:, None, :])
    acc_ref[...] += jnp.sum(scaled, axis=0)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def q8_matmul_w8a8(xq: jax.Array, xs: jax.Array, wq: jax.Array,
                   ws: jax.Array, *, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Integer-path Q8_0 matmul. xq:(M,K) int8, xs:(M,K/32) f32,
    wq:(N,K) int8, ws:(N,K/32) f32 -> (M,N) f32."""
    m, k = xq.shape
    n = wq.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert bk % QK8_0 == 0
    nk = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), nk)
    return pl.pallas_call(
        functools.partial(_w8a8_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // QK8_0), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // QK8_0), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xq, xs, wq, ws)
