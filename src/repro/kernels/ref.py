"""Pure-jnp oracles for every Pallas kernel.

Each oracle implements *exactly* the semantics the corresponding kernel
claims (same quantization math, same accumulation dtype), so the
per-kernel allclose tests are tight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import Q4_0Tensor, Q8_0Tensor, Q3KTensor, QK8_0, \
    Q3K_SUB


def q8_matmul_ref(x: jax.Array, w: Q8_0Tensor) -> jax.Array:
    """Weight-only-quantized matmul: y = x @ dequant(w).T.

    x: (M, K) bf16/f32 activations; w: Q8_0 of logical shape (N, K).
    Dequant to bf16 (the in-VMEM compute type on TPU), accumulate f32.
    """
    wd = quant.dequantize_q8_0(w, jnp.bfloat16)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), wd,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def q8_matmul_w8a8_ref(xq: jax.Array, xs: jax.Array,
                       w: Q8_0Tensor) -> jax.Array:
    """Integer-path matmul (paper's OP_SML8/OP_AD24 analogue).

    xq: (M, K) int8; xs: (M, K/32) f32 block scales; w: Q8_0 (N, K).
    y[m,n] = sum_b xs[m,b] * ws[n,b] * (xq[m,b,:] . wq[n,b,:])_int32
    """
    m, k = xq.shape
    n = w.qs.shape[0]
    nb = k // QK8_0
    a = xq.reshape(m, nb, QK8_0)
    b = w.qs.reshape(n, nb, QK8_0)
    # int8 x int8 -> int32 per-block dot (24-bit accumulate fits in i32).
    ints = jax.lax.dot_general(
        a, b, dimension_numbers=(((2,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.int32)          # (nb, M, N)
    ws = w.d.astype(jnp.float32)                   # (N, nb)
    scaled = (ints.astype(jnp.float32)
              * xs.T[:, :, None]                   # (nb, M, 1)
              * ws.T[:, None, :])                  # (nb, 1, N)
    return jnp.sum(scaled, axis=0)


def q4_matmul_ref(x: jax.Array, w: Q4_0Tensor) -> jax.Array:
    """Weight-only Q4_0 matmul: y = x @ dequant(w).T (bf16 compute)."""
    wd = quant.dequantize_q4_0(w, jnp.bfloat16)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), wd,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def q3k_matmul_ref(x: jax.Array, w: Q3KTensor) -> jax.Array:
    """Weight-only Q3_K matmul: y = x @ dequant(w).T (bf16 compute)."""
    wd = quant.dequantize_q3_k(w, jnp.bfloat16)
    return jax.lax.dot_general(
        x.astype(jnp.bfloat16), wd,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def q3k_matmul_w8a8_ref(xq: jax.Array, xs: jax.Array,
                        w: Q3KTensor) -> jax.Array:
    """Integer-path Q3_K x Q8-activation matmul.

    xq: (M, K) int8; xs: (M, K/16) f32 per-sub-block activation scales
    (Q8_K quantized activations, scales broadcast to 16-granularity);
    w: Q3KTensor (N, K).
    """
    m, k = xq.shape
    qw = quant.unpack_q3(w.ql, w.qh)               # (N, K) int8 in [-4,3]
    n = qw.shape[0]
    eff = quant.q3k_effective_scales(w)            # (N, K/16)
    nsb = k // Q3K_SUB
    a = xq.reshape(m, nsb, Q3K_SUB)
    b = qw.reshape(n, nsb, Q3K_SUB)
    ints = jax.lax.dot_general(
        a, b, dimension_numbers=(((2,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.int32)          # (nsb, M, N)
    scaled = (ints.astype(jnp.float32)
              * xs.T[:, :, None]
              * eff.T[:, None, :])
    return jnp.sum(scaled, axis=0)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Softmax attention oracle.

    q: (B, H, Sq, D); k, v: (B, H, Sk, D)  (GQA folded outside).
    ``window``: sliding-window width (attend to keys in
    (i - window, i]) — h2o-danube-style SWA.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
