import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first init, and the dry-run needs 512 placeholder
# devices to build the production meshes.
#
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes, with no real allocation (ShapeDtypeStruct inputs).
# For each cell this proves the sharding config is coherent (compile
# succeeds, collectives are legal), that it fits (memory_analysis), and
# produces the roofline inputs (cost_analysis + HLO collective bytes).
#
# Usage:
#   python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
#   python -m repro.launch.dryrun --all [--both-meshes] [--out DIR]

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, ARCHS, cell_supported, get_config,
                           input_specs)
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core.policy import get_policy
from repro.core.qlinear import Linear, quantize_params
from repro.core.quant import Q3KTensor, Q8_0Tensor
from repro.distributed import ctx as axctx
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_cache, init_lm
from repro.optim import adamw
from repro.profiling import roofline
from repro.train.serve_step import make_decode, make_prefill
from repro.train.train_step import make_train_step

from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------ helpers

def _sds_size(tree) -> int:
    import numpy as np
    tot = 0
    for leaf in jax.tree.leaves(tree):
        tot += int(np.prod(leaf.shape)) if hasattr(leaf, "shape") else 0
    return tot


def active_param_count(params_sds, cfg: ModelConfig) -> float:
    """Logical params active per token (MoE experts scaled by top_k/E)."""
    import numpy as np
    total = 0.0
    frac = 1.0
    if cfg.moe is not None:
        frac = cfg.moe.top_k / cfg.moe.num_experts

    def walk(node, scale):
        nonlocal total
        if isinstance(node, Linear):
            s = scale * (frac if node.role.startswith("expert") else 1.0)
            for leaf in jax.tree.leaves(
                    node, is_leaf=lambda x: isinstance(
                        x, (Q8_0Tensor, Q3KTensor))):
                if isinstance(leaf, (Q8_0Tensor, Q3KTensor)):
                    total += s * float(np.prod(leaf.shape))
                elif hasattr(leaf, "shape"):
                    total += s * float(np.prod(leaf.shape))
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v, scale)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v, scale)
        elif hasattr(node, "shape"):
            total += scale * float(np.prod(node.shape))
    walk(params_sds, 1.0)
    return total


def dryrun_train_cfg(cfg: ModelConfig, shape: ShapeConfig) -> TrainConfig:
    """Per-arch training config for the dry-run lowering."""
    del cfg, shape
    return TrainConfig(microbatch=0, remat="full")


def probe_cfg(cfg: ModelConfig, k: int, shape: ShapeConfig) -> ModelConfig:
    """k-period fully-unrolled variant for cost probing.

    XLA's cost_analysis counts while-loop bodies once, so the real
    (scanned) program under-reports FLOPs/bytes/collectives.  Probes
    unroll everything at k=1 and k=2 periods; compile_cell extrapolates
    ``total = outer + n_periods * (c2 - c1)`` — exact because the stack
    is periodic and all other loop structure is removed in probes.
    """
    import dataclasses
    plen = len(tuple(cfg.block_pattern))
    rep = dict(num_layers=k * plen, scan_unroll=True,
               mamba_chunk=shape.seq_len)
    if cfg.encoder_layers:
        # Encoder periods must scale with decoder periods for the
        # linear extrapolation to hold.
        assert cfg.encoder_layers == cfg.num_layers // plen, cfg.name
        rep["encoder_layers"] = k
    return dataclasses.replace(cfg, **rep)


# -------------------------------------------------------- cell lowering

def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               policy_name: str | None = None,
               train_cfg: TrainConfig | None = None,
               cfg_override: ModelConfig | None = None,
               quantized_kv: bool = False,
               donate: bool = True):
    """Lower one (arch, shape, mesh) cell. Returns (lowered, meta)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"cell {arch} x {shape_name} skipped: {why}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(partial(init_lm, cfg=cfg), key)
    specs = input_specs(cfg, shape)

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "params_logical": _sds_size(params_sds),
    }

    ns = lambda tree: sharding.to_named(tree, mesh)
    # DP-MoE (weights-gather instead of buffer all-to-all) was
    # REFUTED for this mesh: it leaves the model axis idle on expert
    # compute (6.7x compute-term regression, EXPERIMENTS.md B3).  EP
    # stays the default; the knob remains for narrow-expert archs.
    moe_mode = "ep"
    meta["moe_mode"] = moe_mode if cfg.moe is not None else None
    with mesh, axctx.axis_env(mesh, moe_mode=moe_mode):
        if shape.kind == "train":
            tcfg = train_cfg or dryrun_train_cfg(cfg, shape)
            opt_sds = jax.eval_shape(
                partial(adamw.init_adam, cfg=tcfg), params_sds)
            pspec = sharding.param_specs(params_sds, mesh)
            ospec = adamw.AdamState(step=P(),
                                    m=sharding.param_specs(opt_sds.m, mesh),
                                    v=sharding.param_specs(opt_sds.v, mesh))
            bspec = sharding.batch_specs(specs, mesh)
            step_fn = make_train_step(cfg, tcfg)

            def train_fn(p, o, b):
                new_p, new_o, _, metrics = step_fn(p, o, None, b)
                return new_p, new_o, metrics

            jitted = jax.jit(
                train_fn,
                in_shardings=ns((pspec, ospec, bspec)),
                out_shardings=ns((pspec, ospec)) + (None,),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_sds, opt_sds, specs)
            meta["tokens_per_step"] = shape.global_batch * shape.seq_len
            meta["active_params"] = active_param_count(params_sds, cfg)
            meta["model_flops"] = roofline.model_flops(
                meta["active_params"], meta["tokens_per_step"], "train")
        else:
            policy = get_policy(policy_name or cfg.default_policy)
            qparams_sds = jax.eval_shape(
                partial(quantize_params, policy=policy), params_sds)
            # Serving: TP-only weights (no FSDP) — GGML-style, no
            # per-layer weight gathers; quantized bytes stay quantized.
            pspec = sharding.param_specs(qparams_sds, mesh, fsdp=False)
            meta["policy"] = policy.name
            meta["active_params"] = active_param_count(qparams_sds, cfg)
            if shape.kind == "prefill":
                bspec = sharding.batch_specs(specs, mesh)
                prefill = make_prefill(cfg)
                jitted = jax.jit(prefill,
                                 in_shardings=ns((pspec, bspec)),
                                 out_shardings=None)
                lowered = jitted.lower(qparams_sds, specs)
                meta["tokens_per_step"] = shape.global_batch * shape.seq_len
                meta["model_flops"] = roofline.model_flops(
                    meta["active_params"], meta["tokens_per_step"],
                    "inference")
            else:  # decode
                enc_sds = None
                if cfg.family == "audio":
                    enc_sds = jax.ShapeDtypeStruct(
                        (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                        jnp.bfloat16)
                cache_sds = jax.eval_shape(
                    partial(init_cache, cfg=cfg, batch=shape.global_batch,
                            max_len=shape.seq_len,
                            quantized_kv=quantized_kv),
                    params_sds if policy.name == "none" else qparams_sds,
                    enc_embeds=enc_sds)
                meta["quantized_kv"] = quantized_kv
                cspec = sharding.cache_specs(cache_sds, mesh)
                tspec = sharding.batch_specs(
                    {"token": specs["token"]}, mesh)["token"]
                decode = make_decode(cfg)
                jitted = jax.jit(
                    decode,
                    in_shardings=ns((pspec, tspec, P(), cspec)),
                    out_shardings=(ns(tspec), None, ns(cspec)),
                    donate_argnums=(3,) if donate else ())
                pos = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jitted.lower(qparams_sds, specs["token"], pos,
                                       cache_sds)
                meta["tokens_per_step"] = shape.global_batch
                meta["model_flops"] = roofline.model_flops(
                    meta["active_params"], meta["tokens_per_step"],
                    "inference")
    return lowered, meta


def _cost_triple(arch, shape_name, *, multi_pod, policy_name, train_cfg,
                 cfg_override, quantized_kv=False):
    """(flops, bytes, wire_bytes_per_chip, coll_ops) of one lowering."""
    import dataclasses as dc
    if train_cfg is not None:
        train_cfg = dc.replace(train_cfg, scan_unroll=True)
    lowered, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                            policy_name=policy_name, train_cfg=train_cfg,
                            cfg_override=cfg_override,
                            quantized_kv=quantized_kv, donate=True)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    from repro.profiling import hlo as hlo_mod
    coll = hlo_mod.collective_bytes(compiled.as_text(),
                                    512 if multi_pod else 256)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll.wire_bytes_per_chip, coll.op_count)


def probe_costs(arch: str, shape_name: str, *, multi_pod: bool,
                policy_name, train_cfg, quantized_kv=False) -> dict:
    """Loop-corrected cost via 1-period/2-period unrolled probes."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    plen = len(tuple(cfg.block_pattern))
    n_periods = cfg.num_layers // plen
    tcfg = train_cfg or (dryrun_train_cfg(cfg, shape)
                         if shape.kind == "train" else None)
    c1 = _cost_triple(arch, shape_name, multi_pod=multi_pod,
                      policy_name=policy_name, train_cfg=tcfg,
                      cfg_override=probe_cfg(cfg, 1, shape),
                      quantized_kv=quantized_kv)
    c2 = _cost_triple(arch, shape_name, multi_pod=multi_pod,
                      policy_name=policy_name, train_cfg=tcfg,
                      cfg_override=probe_cfg(cfg, 2, shape),
                      quantized_kv=quantized_kv)
    body = [b - a for a, b in zip(c1, c2)]
    total = [a - b + n_periods * b for a, b in zip(c1, body)]
    return {"flops": max(total[0], 0.0), "bytes accessed": max(total[1], 0.0),
            "wire_bytes": max(total[2], 0.0),
            "coll_ops": int(max(total[3], 0)),
            "probe_1": c1, "probe_2": c2, "n_periods": n_periods}


def compile_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 policy_name: str | None = None,
                 train_cfg: TrainConfig | None = None,
                 probe: bool | None = None,
                 quantized_kv: bool = False,
                 keep_hlo: bool = False) -> dict:
    if probe is None:
        probe = not multi_pod
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               policy_name=policy_name, train_cfg=train_cfg,
                               quantized_kv=quantized_kv)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = dict(compiled.cost_analysis())
    hlo_text = compiled.as_text()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        0),
    }
    raw = {"flops_raw": float(cost.get("flops", 0.0)),
           "bytes_raw": float(cost.get("bytes accessed", 0.0))}
    probe_info = None
    if probe:
        probe_info = probe_costs(arch, shape_name, multi_pod=multi_pod,
                                 policy_name=policy_name,
                                 train_cfg=train_cfg,
                                 quantized_kv=quantized_kv)
        cost["flops"] = probe_info["flops"]
        cost["bytes accessed"] = probe_info["bytes accessed"]
    r = roofline.analyze(
        arch=arch, shape=shape_name, mesh_name=meta["mesh"],
        chips=meta["chips"], cost=cost, hlo_text=hlo_text,
        model_flops_total=meta["model_flops"], memory_analysis=mem_d)
    if probe_info is not None:
        # Collective bytes from probes too (loops hide collectives).
        r.wire_bytes_per_chip = probe_info["wire_bytes"]
        r.collective_ops = probe_info["coll_ops"]
        r.collective_s = probe_info["wire_bytes"] / roofline.LINK_BW
    out = {**meta, **r.to_dict(), **raw,
           "cost_source": "probe" if probe else "raw(loops-once)",
           "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1)}
    if probe_info is not None:
        out["probe"] = {k: probe_info[k] for k in
                        ("probe_1", "probe_2", "n_periods")}
    if keep_hlo:
        out["hlo_text"] = hlo_text
    print(f"[dryrun] {arch} x {shape_name} ({meta['mesh']}): "
          f"bound={r.bound} compute={r.compute_s:.4e}s "
          f"memory={r.memory_s:.4e}s collective={r.collective_s:.4e}s "
          f"frac={r.roofline_fraction:.3f} "
          f"mem/device={mem_d['argument_bytes']/1e9:.2f}+"
          f"{mem_d['temp_bytes']/1e9:.2f}GB "
          f"(lower {out['lower_s']}s, compile {out['compile_s']}s)",
          flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quantized-kv", action="store_true",
                    help="Q8_0 KV cache for decode cells (perf iteration A1)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                if cell_supported(get_config(a), SHAPES[s])[0]:
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shp in cells:
        for mp in meshes:
            tag = f"{arch}_{shp}_{'2x16x16' if mp else '16x16'}"
            try:
                res = compile_cell(arch, shp, multi_pod=mp,
                                   quantized_kv=args.quantized_kv)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)} cells:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells) * len(meshes)} compilations succeeded")


if __name__ == "__main__":
    main()
