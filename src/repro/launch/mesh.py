"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests see the 1 real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    ``pod`` is pure data parallelism (the cross-pod gradient reduce is
    the only collective on that axis); ``data`` is DP+FSDP; ``model``
    is TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Single-device mesh with the same axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
