"""Serving launcher: quantized-offload LM serving via the engine API.

  python -m repro.launch.serve --arch deepseek-moe-16b [--policy q8_0] \
      [--slots 4] [--requests 8] [--gen 16] [--deadline-ms 500] \
      [--admission] [--replicas 3] [--cost-model-path cm.json]

Requests flow through the ``ContinuousBatcher`` engine (the same
``submit()``/``stream()``/``run()`` protocol as the diffusion engine):
a fixed slot pool over the paged KV block pool, chunked-prefill
admission mid-flight, EOS/max-length retirement freeing blocks back to
the pool.  The host loop consumes the typed event stream —
``Admitted``/``TokenDelta``/``Finished``/``Rejected`` — so it reports
time-to-first-token per request instead of waiting for a
batch-and-drain ``run()``; ``--deadline-ms`` attaches an SLO budget to
every request and the scheduler admits earliest-deadline-first.
``--admission`` additionally attaches a phase-aware ``CostModel``
(seeded by a deadline-free calibration request, refined online by the
EWMA over observed quanta): requests whose estimated service time
exceeds their budget are **rejected up front** instead of expiring in
the queue, and the launcher reports the estimated-vs-budget detail per
rejection.  ``--cost-model-path`` persists that calibration as
versioned JSON — an existing file seeds the table (skipping the
calibration micro-run's trace-poisoned first impressions) and the
refined table is written back after the run.  ``--replicas N`` fronts
N data-parallel engine replicas with a ``FleetManager`` (shared event
bus, cost-balanced dispatch, watchdog-driven health) instead of one
engine — the rest of the host loop is unchanged, which is the point.
``--asr`` (with an encoder-decoder ``--arch`` such as
``whisper-large-v3``) serves streaming transcription through the
``AsrEngine`` instead: synthetic audio-frame embeddings are ingested
in encode quanta into the paged cross-attention pool, and the same
event loop reports transcripts, audio-prefix-cache hits, and
per-phase (encode/prefill/decode) quanta.
Runs reduced configs on CPU; on TPU the same path serves full configs
with TP-only weight sharding (no FSDP — see DESIGN.md) and the Pallas
fused-dequant kernels.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg, smoke_inputs
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes, quantize_params
from repro.engine import (AsrEngine, AsrEngineConfig, CostModel,
                          EngineConfig, Finished, FleetManager,
                          LMEngineConfig, Rejected, ReplicaSpec,
                          SpecDecodeConfig, TokenDelta,
                          TranscribeRequest, calibrate)
from repro.models.frontend import synthetic_audio
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None,
                    help="default: one per slot")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO budget (EDF admission)")
    ap.add_argument("--asr", action="store_true",
                    help="serve streaming transcription through the "
                         "AsrEngine instead of LM decode (requires an "
                         "encoder-decoder --arch, e.g. "
                         "whisper-large-v3); audio embeddings are "
                         "synthetic frontend stubs, repeated across "
                         "slots so the audio prefix cache shows hits")
    ap.add_argument("--spec-draft", default=None, metavar="ARCH",
                    help="enable draft-model speculative decoding: the "
                         "named arch (reduced on CPU like --arch) "
                         "proposes tokens that the target verifies in "
                         "one fused paged-prefill launch per round; "
                         "needs a decoder-only --arch sharing the "
                         "target's vocabulary, incompatible with --asr")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative round "
                         "(default 4)")
    ap.add_argument("--admission", action="store_true",
                    help="attach a phase-aware cost model: reject "
                         "requests whose estimated service time "
                         "exceeds their deadline budget up front")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a FleetManager fronting N "
                         "data-parallel engine replicas (default 1: "
                         "a single engine, no fleet layer)")
    ap.add_argument("--cost-model-path", default=None, metavar="PATH",
                    help="persist cost-model calibration as versioned "
                         "JSON: load it if the file exists, write the "
                         "refined table back after the run (implies a "
                         "cost model even without --admission)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable the telemetry layer and write the "
                         "final metrics snapshot (benchmarks/common.py "
                         "record schema) to PATH; PATH ending in "
                         "'.prom' writes Prometheus text exposition "
                         "instead")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable per-request span tracing and write a "
                         "Chrome trace-event JSON (Perfetto-loadable) "
                         "to PATH (implies the metrics layer)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = reduce_cfg(cfg)
    policy = get_policy(args.policy or cfg.default_policy)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, policy)
    print(f"{cfg.name} [{policy.name}]: {param_bytes(qp)/1e6:.1f} MB")

    if args.asr and not cfg.is_enc_dec:
        raise SystemExit(f"--asr needs an encoder-decoder arch; "
                         f"{cfg.name} is decoder-only")
    n_requests = args.requests or args.slots
    inp = smoke_inputs(jax.random.PRNGKey(1), cfg, batch=args.slots,
                       seq=args.prompt_len)
    if args.asr:
        max_len = AsrEngine.required_len(args.prompt_len, args.gen)
        audios = [synthetic_audio(jax.random.PRNGKey(100 + i), cfg)
                  for i in range(args.slots)]
    else:
        max_len = ContinuousBatcher.required_len(n_requests, args.slots,
                                                 args.prompt_len, args.gen)
    tele = None
    if args.metrics_out or args.trace_out:
        from repro.obs import Telemetry, TraceRecorder
        tele = Telemetry(tracer=TraceRecorder() if args.trace_out
                         else None)
    cm = None
    restored = False
    if args.admission or args.cost_model_path:
        if args.cost_model_path and os.path.exists(args.cost_model_path):
            cm = CostModel.load(args.cost_model_path)
            restored = True
            print(f"cost model restored from {args.cost_model_path} "
                  f"({len(cm.snapshot())} phase entries)")
        else:
            cm = CostModel()
        cm.metrics = tele   # estimate-vs-actual error histograms

    spec_decode = None
    if args.spec_draft:
        if args.asr:
            raise SystemExit("--spec-draft is decoder-only LM serving; "
                             "it cannot combine with --asr")
        dcfg = get_config(args.spec_draft)
        if jax.default_backend() == "cpu":
            dcfg = reduce_cfg(dcfg)
        if dcfg.vocab_size != cfg.vocab_size:
            raise SystemExit(
                f"--spec-draft {dcfg.name} vocab {dcfg.vocab_size} != "
                f"target vocab {cfg.vocab_size}")
        dparams = init_lm(jax.random.PRNGKey(2), dcfg)
        print(f"speculative draft {dcfg.name}: k={args.spec_k}")
        spec_decode = SpecDecodeConfig(draft_params=dparams,
                                       draft_cfg=dcfg, k=args.spec_k)

    # One EngineConfig describes every replica: shared knobs (cost
    # model, telemetry — any replica's observed quanta refine every
    # replica's estimates) at the top level, per-engine sections below.
    econf = EngineConfig(
        cost_model=cm, metrics=tele,
        lm=LMEngineConfig(slots=args.slots, max_len=max_len,
                          enc_embeds=(None if args.asr
                                      else inp.get("enc_embeds")),
                          spec_decode=spec_decode),
        asr=AsrEngineConfig(slots=args.slots, max_len=max_len))
    kind = "asr" if args.asr else "lm"

    def make_spec(name):
        return ReplicaSpec(name, params=qp, model_cfg=cfg, engine=kind,
                           config=econf)

    if args.replicas > 1:
        engine = FleetManager([make_spec(f"replica{i}")
                               for i in range(args.replicas)],
                              metrics=tele)
        batchers = [r.engine for r in engine.replicas]
    else:
        engine = make_spec("solo").make()
        batchers = [engine]
    if tele is not None:
        # Attach AFTER fleet/engine construction: the fleet rebinds
        # replica buses onto its shared one, and subscriptions live on
        # the bus object itself.
        tele.attach(engine.bus)
    prompts = np.asarray(inp["tokens"])

    def make_req(rid, i, deadline_ms=None):
        if args.asr:
            return TranscribeRequest(
                rid=rid, audio=audios[i % args.slots],
                prompt=prompts[i % args.slots].tolist(),
                max_new=args.gen, deadline_ms=deadline_ms)
        return Request(rid=rid, prompt=prompts[i % args.slots].tolist(),
                       max_new=args.gen, deadline_ms=deadline_ms)

    if cm is not None and not restored:
        # Calibration micro-run: one deadline-free request per compiled
        # shape seeds the per-phase cost table (and pre-compiles, so
        # workload estimates don't include trace time).
        calibrate(engine, [make_req(-1 - w, 0)
                           for w in range(2 * args.replicas)])
    if cm is not None:
        if args.asr:
            ke, kp, kd = cm.asr_keys(batchers[0])
            print(f"calibrated: encode chunk "
                  f"{(cm.cost(ke) or 0) * 1e3:.1f} ms, ", end="")
        else:
            kp, kd = cm.lm_keys(batchers[0])
            print("calibrated: ", end="")
        print(f"prefill chunk {(cm.cost(kp) or 0) * 1e3:.1f} ms, "
              f"decode token {(cm.cost(kd) or 0) * 1e3:.1f} ms")
    # Counter baselines so the summary reports workload quanta only
    # (the calibration micro-run above consumed some already).
    q0p = sum(b.prefill_quanta for b in batchers)
    q0d = sum(b.decode_quanta for b in batchers)
    submit_ts = {}
    for r in range(n_requests):
        submit_ts[r] = engine.bus.clock()
        engine.submit(make_req(r, r, deadline_ms=args.deadline_ms))
    t0 = time.time()
    done, ttft, rejected = [], {}, []
    for e in engine.stream():
        if isinstance(e, TokenDelta) and e.rid in submit_ts \
                and e.rid not in ttft:
            ttft[e.rid] = e.ts - submit_ts[e.rid]
        elif isinstance(e, Finished) and e.rid >= 0:
            done.append(e.result)
        elif isinstance(e, Rejected):
            rejected.append(e)
    dt = time.time() - t0
    n_tok = sum(len(d.prompt) + len(d.out) for d in done)
    enc = (f"{sum(b.encode_quanta for b in batchers)} encode + "
           if args.asr else "")
    hits = (f", {sum(b.audio_hits for b in batchers)} audio-cache hits"
            if args.asr else "")
    print(f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({enc}{sum(b.prefill_quanta for b in batchers) - q0p} prefill"
          f" + {sum(b.decode_quanta for b in batchers) - q0d} decode "
          f"quanta{hits})")
    if spec_decode is not None:
        prop = sum(b.spec_proposed for b in batchers)
        acc = sum(b.spec_accepted for b in batchers)
        print(f"speculation: {acc}/{prop} draft tokens accepted "
              f"({acc / max(1, prop):.0%}), "
              f"{sum(b.decode_launches for b in batchers)} target decode"
              f" launches, {sum(b.draft_launches for b in batchers)} "
              "draft launches")
    if args.replicas > 1:
        for rs in engine.stats()["replicas"]:
            print(f"  {rs['name']}: {rs['state']}, {rs['steps']} quanta")
    for e in rejected:
        print(f"rejected rid {e.rid} ({e.reason}): estimated "
              f"{e.estimated_s * 1e3:.1f} ms > budget "
              f"{e.budget_s * 1e3:.1f} ms")
    if ttft:
        print(f"ttft: first {min(ttft.values()):.2f}s / "
              f"worst {max(ttft.values()):.2f}s (incl. compile)")
    if done:
        print("first request:", done[0].prompt + done[0].out)
    if cm is not None and args.cost_model_path:
        cm.save(args.cost_model_path)
        print(f"cost model saved to {args.cost_model_path} "
              f"({len(cm.snapshot())} phase entries)")
    if tele is not None:
        if args.metrics_out:
            if args.metrics_out.endswith(".prom"):
                with open(args.metrics_out, "w") as f:
                    f.write(tele.registry.to_prometheus())
            else:
                tele.registry.write_snapshot(args.metrics_out)
            print(f"metrics snapshot written to {args.metrics_out} "
                  f"({len(tele.registry.instruments())} instruments)")
        if args.trace_out and tele.tracer is not None:
            tele.tracer.export(args.trace_out)
            print(f"trace written to {args.trace_out} "
                  f"({len(tele.tracer.spans)} spans, "
                  f"{len(tele.tracer.markers)} markers — load in "
                  f"Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
