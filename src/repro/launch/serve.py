"""Serving launcher: quantized-offload LM serving with batched decode.

  python -m repro.launch.serve --arch deepseek-moe-16b [--policy q8_0] \
      [--batch 4] [--gen 16]

Runs reduced configs on CPU; on TPU the same path serves full configs
with TP-only weight sharding (no FSDP — see DESIGN.md) and the Pallas
fused-dequant kernels.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg, smoke_inputs
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes, quantize_params
from repro.models.transformer import init_lm
from repro.train.serve_step import make_cache, make_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = reduce_cfg(cfg)
    policy = get_policy(args.policy or cfg.default_policy)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, policy)
    print(f"{cfg.name} [{policy.name}]: {param_bytes(qp)/1e6:.1f} MB")

    inp = smoke_inputs(jax.random.PRNGKey(1), cfg, batch=args.batch,
                       seq=args.prompt_len)
    cache = make_cache(qp, cfg, args.batch,
                       args.prompt_len + args.gen,
                       enc_embeds=inp.get("enc_embeds"))
    decode = jax.jit(make_decode(cfg), donate_argnums=(3,))
    tok = inp["tokens"][:, :1]
    t0 = time.time()
    toks = [tok]
    for t in range(args.prompt_len + args.gen - 1):
        nxt, _, cache = decode(qp, tok, jnp.int32(t), cache)
        tok = (inp["tokens"][:, t + 1:t + 2]
               if t + 1 < args.prompt_len else nxt)
        toks.append(tok)
    out = jax.block_until_ready(jnp.concatenate(toks, 1))
    dt = time.time() - t0
    print(f"served {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s")
    print("first request:", out[0].tolist())


if __name__ == "__main__":
    main()
