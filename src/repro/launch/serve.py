"""Serving launcher: quantized-offload LM serving via the engine API.

  python -m repro.launch.serve --arch deepseek-moe-16b [--policy q8_0] \
      [--slots 4] [--requests 8] [--gen 16] [--deadline-ms 500]

Requests flow through the ``ContinuousBatcher`` engine (the same
``submit()``/``stream()``/``run()`` protocol as the diffusion engine):
a fixed slot pool over the paged KV block pool, chunked-prefill
admission mid-flight, EOS/max-length retirement freeing blocks back to
the pool.  The host loop consumes the typed event stream —
``Admitted``/``TokenDelta``/``Finished`` — so it reports
time-to-first-token per request instead of waiting for a
batch-and-drain ``run()``; ``--deadline-ms`` attaches an SLO budget to
every request and the scheduler admits earliest-deadline-first.  Runs
reduced configs on CPU; on TPU the same path serves full configs with
TP-only weight sharding (no FSDP — see DESIGN.md) and the Pallas
fused-dequant kernels.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg, smoke_inputs
from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes, quantize_params
from repro.engine import Finished, TokenDelta
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None,
                    help="default: one per slot")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO budget (EDF admission)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if jax.default_backend() == "cpu":
        cfg = reduce_cfg(cfg)
    policy = get_policy(args.policy or cfg.default_policy)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, policy)
    print(f"{cfg.name} [{policy.name}]: {param_bytes(qp)/1e6:.1f} MB")

    n_requests = args.requests or args.slots
    inp = smoke_inputs(jax.random.PRNGKey(1), cfg, batch=args.slots,
                       seq=args.prompt_len)
    max_len = ContinuousBatcher.required_len(n_requests, args.slots,
                                             args.prompt_len, args.gen)
    engine = ContinuousBatcher(qp, cfg, slots=args.slots, max_len=max_len,
                               enc_embeds=inp.get("enc_embeds"))
    prompts = np.asarray(inp["tokens"])
    submit_ts = {}
    for r in range(n_requests):
        submit_ts[r] = engine.bus.clock()
        engine.submit(Request(rid=r,
                              prompt=prompts[r % args.slots].tolist(),
                              max_new=args.gen,
                              deadline_ms=args.deadline_ms))
    t0 = time.time()
    done, ttft = [], {}
    for e in engine.stream():
        if isinstance(e, TokenDelta) and e.rid not in ttft:
            ttft[e.rid] = e.ts - submit_ts[e.rid]
        elif isinstance(e, Finished):
            done.append(e.result)
    dt = time.time() - t0
    n_tok = sum(len(d.prompt) + len(d.out) for d in done)
    print(f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({engine.prefill_quanta} prefill + {engine.decode_quanta} "
          f"decode quanta)")
    print(f"ttft: first {min(ttft.values()):.2f}s / "
          f"worst {max(ttft.values()):.2f}s (incl. compile)")
    print("first request:", done[0].prompt + done[0].out)


if __name__ == "__main__":
    main()
