"""Production training launcher.

On real hardware this runs under multi-controller JAX (one process per
host; jax.distributed.initialize from the cluster env).  On this CPU
container it runs reduced configs single-process — same code path, same
checkpoint/restart machinery (see examples/train_lm.py for the
CPU-scale driver with the full feature set).

  python -m repro.launch.train --arch granite-8b [--reduced] \
      [--steps N] [--resume auto] [--mesh 16x16|2x16x16|auto]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config, reduced as reduce_cfg
from repro.configs.base import TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.distributed import ctx as axctx
from repro.distributed import sharding
from repro.distributed.fault_tolerance import (StepTimer, Watchdog,
                                               elastic_mesh)
from repro.models.transformer import init_lm
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (default on cpu backend)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--quantized-moments", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduce_cfg(cfg)
    tcfg = TrainConfig(microbatch=args.microbatch,
                       quantized_moments=args.quantized_moments,
                       grad_compression=args.grad_compression,
                       remat="block", ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, steps=args.steps)

    mesh = elastic_mesh(model_parallel=1 if jax.device_count() == 1
                        else 16, pod_size=256)
    print(f"mesh: {dict(mesh.shape)} on {jax.device_count()} devices")

    with mesh, axctx.axis_env(mesh):
        params, opt, comp = init_train_state(
            jax.random.PRNGKey(tcfg.seed), cfg, tcfg, init_lm)
        pspec = sharding.param_specs(params, mesh)
        step_raw = make_train_step(cfg, tcfg)
        step = jax.jit(step_raw, donate_argnums=(0, 1),
                       in_shardings=(sharding.to_named(pspec, mesh),
                                     None, None, None))

        start = 0
        if args.resume == "auto":
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None:
                restored, man = ckpt.restore(
                    tcfg.ckpt_dir, last, {"params": params, "opt": opt})
                params, opt, start = (restored["params"], restored["opt"],
                                      man["step"])
                print(f"resumed at step {start}")

        pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             batch=args.batch, seed=tcfg.seed,
                             start_step=start)
        watchdog = Watchdog()
        timer = StepTimer(watchdog)
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            with timer:
                params, opt, comp, m = step(params, opt, comp, batch)
            if i % 10 == 0:
                print(f"step {i} loss {float(m['loss']):.4f}")
            if (i + 1) % tcfg.ckpt_every == 0 or i == args.steps - 1:
                ckpt.save(tcfg.ckpt_dir, i + 1,
                          {"params": params, "opt": opt},
                          meta={"seed": tcfg.seed, **pipe.state()})
                ckpt.gc_old(tcfg.ckpt_dir)
        pipe.close()
        if watchdog.suspects:
            print(f"straggler-suspect steps: {watchdog.suspects}")


if __name__ == "__main__":
    main()
