"""GQA attention with KV caching (full + sliding-window ring buffer).

Train/prefill use the flash kernel (TPU) or the XLA reference path
(CPU/dry-run).  Decode attends a single query against the cache with an
explicit validity mask; the cache for sliding-window models is a ring
buffer of ``window`` slots, which is what makes `long_500k` feasible for
h2o-danube (bounded KV).  Optional Q8_0-quantized KV storage halves the
decode memory term (beyond-paper extension of the paper's technique).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import apply_linear, init_linear
from repro.distributed import ctx
from repro.kernels import ops
from repro.models import layers


class KVCache(NamedTuple):
    """Fixed-capacity cache. k/v: (B, Hkv, C, hd) (int8 when quantized);
    scales only used for the quantized variant: (B, Hkv, C, hd//32)."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def init_kv_cache(batch: int, cfg: ModelConfig, max_len: int,
                  quantized: bool = False) -> KVCache:
    cap = max_len
    if cfg.sliding_window is not None:
        cap = min(cap, cfg.sliding_window)
    shape = (batch, cfg.num_kv_heads, cap, cfg.hd)
    if quantized:
        sshape = (batch, cfg.num_kv_heads, cap, cfg.hd // 32)
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros(sshape, jnp.float16),
                       jnp.zeros(sshape, jnp.float16))
    return KVCache(jnp.zeros(shape, jnp.bfloat16),
                   jnp.zeros(shape, jnp.bfloat16), None, None)


def init_paged_kv_cache(num_blocks: int, cfg: ModelConfig, block_size: int,
                        quantized: bool = False) -> KVCache:
    """Physical block pool for the paged serving runtime.

    k/v: (num_blocks, Hkv, block_size, hd).  Block 0 is the reserved
    null block: idle slots point their table at it, so their (discarded)
    writes never touch live data.  Logical per-request capacity and the
    slot -> block mapping live host-side in ``serving.kvcache``.
    Sliding-window configs keep full positions here (masking enforces
    the window); the ring-buffer compaction only applies to the
    contiguous layout.
    """
    shape = (num_blocks, cfg.num_kv_heads, block_size, cfg.hd)
    if quantized:
        sshape = (num_blocks, cfg.num_kv_heads, block_size, cfg.hd // 32)
        return KVCache(jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                       jnp.zeros(sshape, jnp.float16),
                       jnp.zeros(sshape, jnp.float16))
    return KVCache(jnp.zeros(shape, jnp.bfloat16),
                   jnp.zeros(shape, jnp.bfloat16), None, None)


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-32-block int8 quantization along head_dim."""
    from repro.core import quant
    t = quant.quantize_q8_0(x)
    return t.qs, t.d


def _dequantize_kv(qs: jax.Array, d: jax.Array) -> jax.Array:
    from repro.core import quant
    from repro.core.quant import Q8_0Tensor
    return quant.dequantize_q8_0(Q8_0Tensor(qs, d), jnp.bfloat16)


# ------------------------------------------------------------- params

def init_attention(key: jax.Array, cfg: ModelConfig, *,
                   cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    hd, hq, hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    p = {
        "wq": init_linear(ks[0], cfg.d_model, hq * hd, role="attn_qkv",
                          bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], cfg.d_model, hkv * hd, role="attn_qkv",
                          bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], cfg.d_model, hkv * hd, role="attn_qkv",
                          bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], hq * hd, cfg.d_model, role="attn_out"),
    }
    del cross  # same projection structure; queries/keys differ at apply
    return p


def _split_heads(x: jax.Array, nheads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, nheads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _positions_mrope(positions: jax.Array) -> jax.Array:
    """(B, S) -> (B, 3, S) text-position triplet (stub frontend)."""
    return jnp.broadcast_to(positions[:, None, :],
                            (positions.shape[0], 3, positions.shape[1]))


def _rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.mrope:
        return layers.apply_mrope(x, _positions_mrope(positions),
                                  tuple(cfg.mrope_sections), cfg.rope_theta)
    return layers.apply_rope(x, positions, cfg.rope_theta)


# -------------------------------------------------------- full-seq fwd

def attention_fwd(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, *, causal: bool = True,
                  kv_x: jax.Array | None = None,
                  rope: bool = True) -> jax.Array:
    """Training / prefill attention over a full sequence.

    ``kv_x`` switches to cross-attention (keys/values from encoder
    states, no RoPE on the cross path, non-causal).
    """
    src = kv_x if kv_x is not None else x
    q = ctx.heads_q(_split_heads(apply_linear(p["wq"], x), cfg.num_heads))
    k = ctx.heads(_split_heads(apply_linear(p["wk"], src),
                               cfg.num_kv_heads))
    v = ctx.heads(_split_heads(apply_linear(p["wv"], src),
                               cfg.num_kv_heads))
    if rope and kv_x is None:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    window = cfg.sliding_window if kv_x is None else None
    # Cost probes (scan_unroll) force the unchunked path so attention
    # FLOPs are fully visible to cost_analysis.
    q_chunk = 0 if cfg.scan_unroll else None
    out = ops.attention(q, k, v, causal=causal and kv_x is None,
                        window=window, q_chunk=q_chunk)
    return ctx.act(apply_linear(p["wo"], _merge_heads(ctx.heads_q(out))))


# ----------------------------------------------------- paged prefill

def attention_prefill_paged(p: dict, cfg: ModelConfig, x: jax.Array,
                            pos0: jax.Array, cache: KVCache,
                            block_tables: jax.Array, *,
                            rope: bool = True
                            ) -> tuple[jax.Array, KVCache]:
    """Fused multi-token prefill of one chunk against the paged pool.

    x: (1, T, d) — the chunk being admitted (batch-1 slot view);
    pos0: (1,) int32 — tokens already cached for the slot;
    block_tables: (1, MB) int32 — the slot's block-table row.

    One ``ops.paged_prefill_attention`` program per layer replaces T
    per-token decode scatter/gather rounds: the chunk's KV is written
    into its destination blocks in-kernel and every chunk query attends
    causally to history + the chunk itself.  Quantized (Q8_0) pools take
    the fused Q8 sibling kernel: the chunk's KV is requantized in-kernel
    and all four pools (quants + scales) are updated in place.

    Returns (out (1, T, d), updated cache).
    """
    b, t, _ = x.shape
    assert b == 1, "admission prefill is batch-1 (one slot)"
    quantized = cache.k_scale is not None
    positions = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    q = _split_heads(apply_linear(p["wq"], x), cfg.num_heads)
    k = _split_heads(apply_linear(p["wk"], x), cfg.num_kv_heads)
    v = _split_heads(apply_linear(p["wv"], x), cfg.num_kv_heads)
    if rope:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    g = cfg.num_heads // cfg.num_kv_heads
    # (1, Hq, T, hd) -> (T, Hkv, G, hd); query head ordering kv*G + g
    # matches the decode path's reshape.
    qt = q[0].reshape(cfg.num_kv_heads, g, t, cfg.hd).transpose(2, 0, 1, 3)
    kn = k[0].transpose(1, 0, 2)                 # (T, Hkv, hd)
    vn = v[0].transpose(1, 0, 2)
    if quantized:
        # Pass the raw (unquantized) chunk KV: the kernel requantizes
        # per-32 blocks along hd itself, matching _quantize_kv exactly.
        out, kp, vp, ksp, vsp = ops.paged_prefill_attention(
            qt, kn, vn, cache.k, cache.v, block_tables[0], pos0[0],
            window=cfg.sliding_window, scale=cfg.hd ** -0.5,
            k_scale_pool=cache.k_scale, v_scale_pool=cache.v_scale)
        new = KVCache(ctx.paged_kv(kp), ctx.paged_kv(vp),
                      ctx.paged_kv(ksp), ctx.paged_kv(vsp))
        out = out.transpose(1, 2, 0, 3)          # (Hkv, G, T, hd)
        out = out.reshape(1, cfg.num_heads, t, cfg.hd)
        return apply_linear(p["wo"],
                            _merge_heads(out).astype(x.dtype)), new
    out, kp, vp = ops.paged_prefill_attention(
        qt, kn.astype(cache.k.dtype), vn.astype(cache.v.dtype),
        cache.k, cache.v, block_tables[0], pos0[0],
        window=cfg.sliding_window, scale=cfg.hd ** -0.5)
    new = KVCache(ctx.paged_kv(kp), ctx.paged_kv(vp), None, None)
    out = out.transpose(1, 2, 0, 3)              # (Hkv, G, T, hd)
    out = out.reshape(1, cfg.num_heads, t, cfg.hd)
    return apply_linear(p["wo"], _merge_heads(out).astype(x.dtype)), new


# ------------------------------------------------------------- decode

def _update_read_contiguous(cfg: ModelConfig, cache: KVCache, k, v, pos):
    """Legacy layout: per-slot contiguous rows, one shared scalar ``pos``.

    Returns (new_cache, keys, vals, valid (B|1, C))."""
    cap = cache.capacity
    if cfg.sliding_window is not None:
        slot = pos % cap                      # ring buffer
    else:
        slot = jnp.minimum(pos, cap - 1)
    quantized = cache.k_scale is not None
    cc = ctx.kv_cache
    if quantized:
        kq, kd = _quantize_kv(k)
        vq, vd = _quantize_kv(v)
        new = KVCache(
            cc(jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, slot, 0))),
            cc(jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, slot, 0))),
            cc(jax.lax.dynamic_update_slice(cache.k_scale, kd,
                                            (0, 0, slot, 0))),
            cc(jax.lax.dynamic_update_slice(cache.v_scale, vd,
                                            (0, 0, slot, 0))))
        keys = cc(_dequantize_kv(new.k, new.k_scale))
        vals = cc(_dequantize_kv(new.v, new.v_scale))
    else:
        new = KVCache(
            cc(jax.lax.dynamic_update_slice(cache.k, k, (0, 0, slot, 0))),
            cc(jax.lax.dynamic_update_slice(cache.v, v, (0, 0, slot, 0))),
            None, None)
        keys, vals = new.k, new.v
    # Validity: slot c holds a token iff c < pos+1 (full) or within the
    # last `window` tokens (ring buffer: all filled slots are valid).
    idx = jnp.arange(cap)
    valid = idx <= jnp.minimum(pos, cap - 1) \
        if cfg.sliding_window is None else idx < jnp.minimum(pos + 1, cap)
    return new, keys, vals, valid[None, :]


def _update_read_rowwise(cfg: ModelConfig, cache: KVCache, k, v, pos_vec):
    """Contiguous layout with *per-row* positions ((B,) int32)."""
    cap = cache.capacity
    b = k.shape[0]
    rows = jnp.arange(b)
    if cfg.sliding_window is not None:
        slot = pos_vec % cap
    else:
        slot = jnp.minimum(pos_vec, cap - 1)
    quantized = cache.k_scale is not None
    cc = ctx.kv_cache

    def scatter(buf, upd):
        # upd: (B, Hkv, 1, d*) -> write row r at column slot[r].
        return cc(buf.at[rows, :, slot].set(upd[:, :, 0]))

    if quantized:
        kq, kd = _quantize_kv(k)
        vq, vd = _quantize_kv(v)
        new = KVCache(scatter(cache.k, kq), scatter(cache.v, vq),
                      scatter(cache.k_scale, kd), scatter(cache.v_scale, vd))
        keys = cc(_dequantize_kv(new.k, new.k_scale))
        vals = cc(_dequantize_kv(new.v, new.v_scale))
    else:
        new = KVCache(scatter(cache.k, k), scatter(cache.v, v), None, None)
        keys, vals = new.k, new.v
    idx = jnp.arange(cap)[None, :]
    if cfg.sliding_window is None:
        valid = idx <= jnp.minimum(pos_vec, cap - 1)[:, None]
    else:
        valid = idx < jnp.minimum(pos_vec + 1, cap)[:, None]
    return new, keys, vals, valid


def _update_read_paged(cfg: ModelConfig, cache: KVCache, k, v, pos_vec,
                       block_tables):
    """Paged layout: pool (NB, Hkv, bs, hd) + per-row block tables.

    Row r writes its token at block ``tables[r, pos // bs]`` offset
    ``pos % bs`` and attends to the gathered logical window
    (MB * bs positions) with per-row masking ``idx <= pos`` (AND the
    sliding window, if configured — paged SWA stores full positions).
    """
    b = k.shape[0]
    bs = cache.k.shape[2]
    mb = block_tables.shape[1]
    rows = jnp.arange(b)
    bid = block_tables[rows, pos_vec // bs]           # (B,) physical block
    off = pos_vec % bs
    cc = ctx.paged_kv
    quantized = cache.k_scale is not None

    def scatter(pool, upd):
        return cc(pool.at[bid, :, off].set(upd[:, :, 0]))

    def gather(pool):
        # (B, MB, Hkv, bs, d*) -> (B, Hkv, MB*bs, d*)
        g = pool[block_tables]
        g = g.transpose(0, 2, 1, 3, 4)
        return g.reshape(b, g.shape[1], mb * bs, g.shape[-1])

    if quantized:
        kq, kd = _quantize_kv(k)
        vq, vd = _quantize_kv(v)
        new = KVCache(scatter(cache.k, kq), scatter(cache.v, vq),
                      scatter(cache.k_scale, kd), scatter(cache.v_scale, vd))
        keys = _dequantize_kv(gather(new.k), gather(new.k_scale))
        vals = _dequantize_kv(gather(new.v), gather(new.v_scale))
    else:
        new = KVCache(scatter(cache.k, k), scatter(cache.v, v), None, None)
        keys, vals = gather(new.k), gather(new.v)
    idx = jnp.arange(mb * bs)[None, :]
    valid = idx <= pos_vec[:, None]
    if cfg.sliding_window is not None:
        valid &= idx > (pos_vec[:, None] - cfg.sliding_window)
    # Pool blocks are recycled, not zeroed: a masked position may hold a
    # previous occupant's bytes.  The -inf mask already zeroes its
    # probability, but 0 * NaN = NaN, so neutralize the values too —
    # masked contributions are exactly 0.0 either way.
    vals = jnp.where(valid[:, None, :, None], vals, 0)
    return new, keys, vals, valid


def attention_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                     pos: jax.Array, cache: KVCache,
                     *, rope: bool = True,
                     block_tables: jax.Array | None = None
                     ) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 (tokens so far,
    shared by all rows) or (B,) int32 per-slot positions.

    ``block_tables`` (B, MB) int32 switches the cache to the paged
    block-pool layout (see :func:`init_paged_kv_cache`); it requires
    per-slot positions.  Returns (out (B, 1, d), updated cache).
    """
    b = x.shape[0]
    per_row = jnp.ndim(pos) > 0
    pos_vec = (jnp.asarray(pos, jnp.int32) if per_row
               else jnp.full((b,), pos, jnp.int32))
    positions = pos_vec[:, None]
    q = _split_heads(apply_linear(p["wq"], x), cfg.num_heads)
    k = _split_heads(apply_linear(p["wk"], x), cfg.num_kv_heads)
    v = _split_heads(apply_linear(p["wv"], x), cfg.num_kv_heads)
    if rope:
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)

    if block_tables is not None:
        assert per_row, "paged decode requires per-slot positions"
        new, keys, vals, valid = _update_read_paged(cfg, cache, k, v,
                                                    pos_vec, block_tables)
    elif per_row:
        new, keys, vals, valid = _update_read_rowwise(cfg, cache, k, v,
                                                      pos_vec)
    else:
        new, keys, vals, valid = _update_read_contiguous(cfg, cache, k, v,
                                                         pos)

    # GQA: fold query heads into groups over kv heads.  bf16 operands
    # with f32 accumulation (no materialized f32 cache copy).
    g = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, cfg.num_kv_heads, g, cfg.hd)
    logits = ctx.decode_logits(
        jnp.einsum("bhgd,bhcd->bhgc", qg.astype(keys.dtype), keys,
                   preferred_element_type=jnp.float32)) * (cfg.hd ** -0.5)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgc,bhcd->bhgd", probs.astype(vals.dtype), vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, cfg.num_heads * cfg.hd).astype(x.dtype)
    return apply_linear(p["wo"], out), new


def _cross_attend(p: dict, cfg: ModelConfig, x: jax.Array,
                  keys: jax.Array, vals: jax.Array,
                  valid: jax.Array | None) -> jax.Array:
    """Shared cross-attention core: (B, T, d) queries against fixed
    encoder keys/vals (B, Hkv, C, hd), optional validity mask (B, C).

    Cross attention is non-causal over a *fixed* KV set, so every query
    position is independent — chunk-at-once is bit-identical in
    structure to per-token, which is what lets enc-dec prefill ride the
    fused path.  NaN bytes in masked positions (recycled paged blocks)
    are neutralized the same way as ``_update_read_paged``: -inf on the
    logits kills the probability, an explicit zero kills the value
    (0 * NaN = NaN otherwise).
    """
    b, t, _ = x.shape
    g = cfg.num_heads // cfg.num_kv_heads
    q = _split_heads(apply_linear(p["wq"], x), cfg.num_heads)
    # (B, Hq, T, hd) -> (B, Hkv, G, T, hd); head order kv*G + g matches
    # attention_decode's grouping.
    qg = q.reshape(b, cfg.num_kv_heads, g, t, cfg.hd)
    logits = jnp.einsum("bhgtd,bhcd->bhgtc", qg.astype(keys.dtype), keys,
                        preferred_element_type=jnp.float32) \
        * (cfg.hd ** -0.5)
    if valid is not None:
        vals = jnp.where(valid[:, None, :, None], vals, 0)
        logits = jnp.where(valid[:, None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgtc,bhcd->bhgtd", probs.astype(vals.dtype), vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, cfg.num_heads, t, cfg.hd)
    return apply_linear(p["wo"], _merge_heads(out).astype(x.dtype))


def cross_attention_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                           enc_k: jax.Array, enc_v: jax.Array,
                           enc_valid: jax.Array | None = None) -> jax.Array:
    """Cross attention against precomputed contiguous encoder KV.

    x: (B, T, d) — T == 1 for decode, T > 1 for fused chunk prefill.
    enc_k/enc_v: (B, Hkv, S_enc, hd); ``enc_valid`` (B, S_enc) masks a
    ragged encoder tail when present."""
    return _cross_attend(p, cfg, x, enc_k, enc_v, enc_valid)


def cross_attention_paged(p: dict, cfg: ModelConfig, x: jax.Array,
                          cross_tables: jax.Array, k_pool: jax.Array,
                          v_pool: jax.Array, *, enc_len: int) -> jax.Array:
    """Cross attention reading encoder KV from the paged cross pool.

    x: (B, T, d) queries; cross_tables: (B, MBc) int32 rows into the
    bf16 pools (NBc, Hkv, cbs, hd) written once per request by
    ``write_cross_kv``.  ``enc_len`` (static) masks the partial tail
    block — positions >= enc_len in the gathered window are recycled
    bytes, not encoder states.
    """
    b = x.shape[0]
    cbs = k_pool.shape[2]
    mb = cross_tables.shape[1]

    def gather(pool):
        g = pool[cross_tables]                   # (B, MBc, Hkv, cbs, hd)
        g = g.transpose(0, 2, 1, 3, 4)
        return g.reshape(b, g.shape[1], mb * cbs, g.shape[-1])

    valid = jnp.broadcast_to(jnp.arange(mb * cbs)[None, :] < enc_len,
                             (b, mb * cbs))
    return _cross_attend(p, cfg, x, gather(k_pool), gather(v_pool), valid)
