"""CLIP-style text encoder (SD v1.5 conditioning), reusing the LM stack."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


def clip_config(*, d_model: int = 768, layers: int = 12, heads: int = 12,
                vocab: int = 49408, max_len: int = 77) -> ModelConfig:
    return ModelConfig(
        name="clip_text", family="dense", num_layers=layers,
        d_model=d_model, num_heads=heads, num_kv_heads=heads,
        d_ff=4 * d_model, vocab_size=vocab, norm="layernorm",
        activation="gelu", pos_embed="sinusoidal")


TINY_CLIP = clip_config(d_model=64, layers=2, heads=2, vocab=512)


def init_clip(key: jax.Array, cfg: ModelConfig) -> dict:
    return T.init_lm(key, cfg)


def clip_encode(params: dict, cfg: ModelConfig,
                tokens: jax.Array) -> jax.Array:
    """tokens: (B, 77) -> hidden states (B, 77, d) (pre-unembed)."""
    b, s = tokens.shape
    x = L.apply_embedding(params["embed"], tokens)
    x = x + T._sinusoidal(s, cfg.d_model)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    x, _ = T._stack_fwd(params["layers"], cfg, x, positions, causal=True)
    return T._apply_norm(cfg, params["final_norm"], x)
