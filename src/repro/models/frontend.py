"""Modality frontend STUBS (per assignment).

``[audio]`` / ``[vlm]`` architectures specify the transformer backbone
only; the conv/patch frontends are stubs whose *outputs* (frame / patch
embeddings) are supplied by ``input_specs()``.  These helpers define the
stand-in shapes and a deterministic synthetic generator for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

VLM_PATCHES = 256  # stub: one low-res image worth of patch embeddings


def audio_frontend_shape(cfg: ModelConfig, batch: int) -> tuple:
    """Whisper conv frontend output: (B, n_frames, d_model)."""
    return (batch, cfg.encoder_seq, cfg.d_model)


def vision_frontend_shape(cfg: ModelConfig, batch: int) -> tuple:
    """Qwen2-VL patch-merger output: (B, n_patches, d_model)."""
    return (batch, VLM_PATCHES, cfg.d_model)


def synthetic_frontend(key: jax.Array, shape: tuple) -> jax.Array:
    return jax.random.normal(key, shape, jnp.bfloat16) * 0.02


def synthetic_audio(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One request's worth of synthetic audio-frame embeddings —
    ``(encoder_seq, d_model)``, the tensor a ``TranscribeRequest``
    carries (unbatched: the ASR engine streams it per slot)."""
    return synthetic_frontend(key, audio_frontend_shape(cfg, 1))[0]
