"""Shared neural-net layers: norms, RoPE / M-RoPE, MLPs, embeddings.

Everything is pure functions over pytree params — no framework
dependency.  Weight matmuls go through :mod:`repro.core.qlinear` so the
offload policy can quantize them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.qlinear import Linear, apply_linear, init_linear
from repro.core.quant import Q3KTensor, Q4_0Tensor, Q8_0Tensor
from repro.distributed import ctx


# ------------------------------------------------------------- norms

def init_rmsnorm(dim: int) -> dict:
    return {"g": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["g"]).astype(x.dtype)


def init_layernorm(dim: int) -> dict:
    return {"g": jnp.ones((dim,), jnp.float32),
            "b": jnp.zeros((dim,), jnp.float32)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"]
            + p["b"]).astype(x.dtype)


# -------------------------------------------------------------- RoPE

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)                     # (B,1,S,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array,
                sections: tuple[int, ...],
                theta: float = 10_000.0) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  positions: (B, 3, S).  With the stub frontend all three
    streams carry text positions, but the section mechanics are real.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=d // 2)
    # Select per-frequency-slot position stream: (B, D/2, S).
    pos_slot = positions.astype(jnp.float32)[:, sec_id, :]
    ang = jnp.einsum("bds,d->bsd", pos_slot, freqs)           # (B,S,D/2)
    cos = jnp.cos(ang)[:, None]                               # (B,1,S,D/2)
    sin = jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- MLP

def init_mlp(key: jax.Array, d: int, ff: int, activation: str,
             role_prefix: str = "mlp") -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], d, ff, role=f"{role_prefix}_up"),
         "down": init_linear(ks[1], ff, d, role=f"{role_prefix}_down")}
    if activation == "silu":  # swiglu
        p["gate"] = init_linear(ks[2], d, ff, role=f"{role_prefix}_gate")
    return p


def apply_mlp(p: dict, x: jax.Array, activation: str) -> jax.Array:
    up = ctx.ffn(apply_linear(p["up"], x))
    if activation == "silu":
        h = jax.nn.silu(ctx.ffn(apply_linear(p["gate"], x))) * up
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return ctx.act(apply_linear(p["down"], h))


# -------------------------------------------------------- embeddings

def init_embedding(key: jax.Array, vocab: int, d: int,
                   dtype=jnp.bfloat16) -> Linear:
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return Linear(w=w, b=None, role="embed")


def apply_embedding(emb: Linear, tokens: jax.Array) -> jax.Array:
    """Row lookup that understands quantized storage: only the gathered
    rows are dequantized (quantized bytes stay quantized in HBM)."""
    w = emb.w
    if isinstance(w, Q8_0Tensor):
        qs = jnp.take(w.qs, tokens, axis=0)         # (..., d) int8
        d = jnp.take(w.d, tokens, axis=0)           # (..., d/32) f16
        return quant.dequantize_q8_0(Q8_0Tensor(qs, d), jnp.bfloat16)
    if isinstance(w, Q4_0Tensor):
        sub = Q4_0Tensor(jnp.take(w.qs, tokens, axis=0),
                         jnp.take(w.d, tokens, axis=0))
        return quant.dequantize_q4_0(sub, jnp.bfloat16)
    if isinstance(w, Q3KTensor):
        sub = Q3KTensor(jnp.take(w.ql, tokens, axis=0),
                        jnp.take(w.qh, tokens, axis=0),
                        jnp.take(w.scales, tokens, axis=0),
                        jnp.take(w.d, tokens, axis=0),
                        scale_bits=w.scale_bits)
        return quant.dequantize_q3_k(sub, jnp.bfloat16)
    return jnp.take(w, tokens, axis=0)


def apply_unembed(head: Linear, x: jax.Array) -> jax.Array:
    """Logits = x @ W_vocab^T (shares apply_linear, so quantizable)."""
    return ctx.vocab(apply_linear(head, x).astype(jnp.float32))
