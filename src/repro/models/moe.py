"""Mixture-of-Experts FFN: group-local top-k routing + EP sharding.

Dispatch is **group-local** (groups = batch rows, which are aligned
with the `data` mesh axis): each group sorts its own tokens by expert
assignment and builds per-group capacity buffers ``(G, E, C, d)``.
Under GSPMD this keeps all routing ops (argsort / gather / scatter)
shard-local; the only cross-device traffic is the expert crossing
(combine gather), which additionally moves *quantized* bytes.  Net
measured effect vs the naive global-routing value-scatter baseline:
32x lower dominant-term time on the 16x16 mesh (EXPERIMENTS.md §Perf,
moonshot train cell, iterations B1-B4b).

Shared experts (deepseek-moe / moonshot) run densely on every token.
Expert weights are role-tagged (`expert_up/gate/down`) so the offload
policy quantizes them — per-expert quantized buffers are the largest
weight-byte win of the paper's technique on MoE models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.qlinear import Linear, apply_linear
from repro.core.quant import Q3KTensor, Q8_0Tensor
from repro.distributed import ctx
from repro.kernels import ops


def _q8_across_ep(x: jax.Array) -> jax.Array:
    """Quantize a (G, E, C, d) buffer to Q8 blocks *before* the EP cut
    and dequantize after — the expert all-to-all then moves int8 + fp16
    scales (~8.5 b/elem) instead of bf16 (the paper's
    stream-quantized-bytes insight applied to the interconnect).
    Active only under a distributed axis env; unit tests see exact
    bf16 values."""
    env = ctx.current()
    if env is None or env.moe_mode != "ep" or x.shape[-1] % 32:
        return ctx.expert_buf(x)
    t = quant.quantize_q8_0(x)
    qs = ctx.expert_buf(t.qs)
    d = ctx.expert_buf(t.d)
    return quant.dequantize_q8_0(quant.Q8_0Tensor(qs, d), jnp.bfloat16
                                 ).astype(x.dtype)


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    moe = cfg.moe
    d, ff, e = cfg.d_model, moe.expert_ff, moe.num_experts
    ks = jax.random.split(key, 6)
    std = d ** -0.5

    def ew(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * std
                ).astype(jnp.bfloat16)

    p = {
        "router": Linear(ew(ks[0], (e, d)).astype(jnp.float32),
                         role="router"),
        # Stacked expert weights: (E, ff, d) / (E, d, ff) output-major.
        "w_up": Linear(ew(ks[1], (e, ff, d)), role="expert_up"),
        "w_gate": Linear(ew(ks[2], (e, ff, d)), role="expert_gate"),
        "w_down": Linear(ew(ks[3], (e, d, ff)), role="expert_down"),
    }
    if moe.num_shared:
        sff = moe.expert_ff * moe.num_shared
        from repro.models.layers import init_mlp
        # Shared experts are dense MLPs -> standard mlp sharding rules.
        p["shared"] = init_mlp(ks[4], d, sff, "silu", role_prefix="mlp")
    return p


import functools


@functools.lru_cache(maxsize=None)
def _make_quantized_combine(n: int, dtype_name: str):
    """Gather expert outputs through a Q8 wire format (fwd compressed);
    backward is the exact gather-transpose (straight-through) so expert
    gradients are NOT routed through round() — without this, the
    quantizer's zero-derivative round would starve expert training.
    Shape/dtype are closed over (custom_vjp residuals must be arrays)."""
    dtype = jnp.dtype(dtype_name)

    @jax.custom_vjp
    def qc(out_flat: jax.Array, dst: jax.Array) -> jax.Array:
        g, _, d = out_flat.shape
        oq = quant.quantize_q8_0(out_flat)
        qs = jnp.concatenate([oq.qs, jnp.zeros((g, 1, d), jnp.int8)], 1)
        dsc = jnp.concatenate(
            [oq.d, jnp.zeros((g, 1, d // 32), jnp.float16)], 1)
        qs_g = ctx.constrain(
            jnp.take_along_axis(qs, dst[..., None], axis=1), {0: "dp"})
        dsc_g = ctx.constrain(
            jnp.take_along_axis(dsc, dst[..., None], axis=1), {0: "dp"})
        return quant.dequantize_q8_0(
            quant.Q8_0Tensor(qs_g, dsc_g), dtype)

    def fwd(out_flat, dst):
        return qc(out_flat, dst), dst

    def bwd(dst, gy):
        # The gather-transpose is a scatter-add, which SPMD replicates
        # (the B2 pathology).  But dst is injective on kept entries
        # (slot = expert*cap + position), so the transpose is a
        # permutation: scatter only int32 inverse indices, then gather
        # the cotangents (same trick as the forward dispatch).
        g, sk, d = gy.shape
        gidx = jnp.arange(g)[:, None]
        inv = jnp.full((g, n + 1), sk, jnp.int32)
        inv = inv.at[gidx, dst].set(
            jnp.broadcast_to(jnp.arange(sk)[None], (g, sk)))[:, :n]
        gypad = jnp.concatenate(
            [gy, jnp.zeros((g, 1, d), gy.dtype)], axis=1)
        out = jnp.take_along_axis(gypad, inv[..., None], axis=1)
        return ctx.constrain(out.astype(dtype),
                             {0: "dp", 1: None}), None

    qc.defvjp(fwd, bwd)
    return qc


def _quantized_combine(out_flat: jax.Array, dst: jax.Array) -> jax.Array:
    fn = _make_quantized_combine(out_flat.shape[1],
                                 jnp.dtype(out_flat.dtype).name)
    return fn(out_flat, dst)


def _expert_matmul(w: Linear, x: jax.Array) -> jax.Array:
    """x: (G, E, C, K); w.w: (E, N, K) (possibly quantized) -> (G,E,C,N)."""
    g, e, c, k = x.shape
    ww = w.w
    if isinstance(ww, (Q8_0Tensor, Q3KTensor)):
        # Batched quantized matmul: vmap the fused kernel over experts.
        xe = x.transpose(1, 0, 2, 3).reshape(e, g * c, k)
        y = jax.vmap(lambda xg, we: ops.quantized_matmul(xg, we))(xe, ww)
        return y.reshape(e, g, c, -1).transpose(1, 0, 2, 3).astype(x.dtype)
    return jnp.einsum("geck,enk->gecn", x.astype(ww.dtype), ww,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Groups = batch rows."""
    moe = cfg.moe
    g, s, d = x.shape
    e, k = moe.num_experts, moe.top_k

    logits = apply_linear(p["router"], x.astype(jnp.float32))   # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                  # (G,S,k)
    gate = gate / jnp.clip(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style), over all tokens.
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx.reshape(-1, k), e).sum(1),
                  axis=0) / k
    aux = e * jnp.sum(me * ce) * moe.router_aux_coef

    cap = max(int(moe.capacity_factor * s * k / e), 1)

    # ---- group-local sorted dispatch (all ops shard-local in G) ----
    flat_e = expert_idx.reshape(g, s * k)
    flat_gate = gate.reshape(g, s * k)
    order = jnp.argsort(flat_e, axis=-1)                        # (G,S*k)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    sgate = jnp.take_along_axis(flat_gate, order, axis=-1)
    stok = order // k                                           # token idx
    # Position within each expert's (sorted) run.
    start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)
    pos_in_e = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        start, se, axis=-1)
    keep = pos_in_e < cap
    # Dropped entries go to a trash slot (index e*cap) so they can never
    # clobber a legitimate occupant of capacity slot 0.
    dst = jnp.where(keep, se * cap + pos_in_e, e * cap)        # (G,S*k)

    # Index-scatter + gather formulation: the scatter moves only int32
    # slot->token indices (25 MB), never the d-dim vectors — the big
    # (G,E,C,d) buffer is produced by a gather, which SPMD keeps local
    # in G (a value-scatter here was replicated across the mesh: 51 GB
    # all-gathers per layer; see EXPERIMENTS.md §Perf iteration B2).
    gidx = jnp.arange(g)[:, None]
    islot = jnp.full((g, e * cap + 1), s, jnp.int32)  # sentinel = s
    islot = islot.at[gidx, dst].set(stok)[:, : e * cap]
    xpad = jnp.concatenate([x, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(xpad, islot[..., None], axis=1)
    buf = ctx.expert_buf(buf.reshape(g, e, cap, d))             # EP/DP cut

    up = _expert_matmul(p["w_up"], buf)
    gt = _expert_matmul(p["w_gate"], buf)
    h = ctx.expert_buf(jax.nn.silu(gt) * up)
    out_e = ctx.expert_buf(_expert_matmul(p["w_down"], h))      # (G,E,C,d)

    # ---- combine (gather; dropped entries hit the zero pad) ----
    # The combine gather is the EP wire crossing (expert-layout ->
    # token-layout).  When a distributed env is active we gather the
    # *quantized* expert outputs (int8 + fp16 block scales) and
    # dequantize on the token side, so the all-to-all moves ~8.5
    # bits/elem instead of bf16 — the paper's stream-quantized-bytes
    # insight applied to the interconnect.
    env = ctx.current()
    if env is not None and env.moe_mode == "ep" and d % 32 == 0:
        contrib = _quantized_combine(out_e.reshape(g, e * cap, d), dst)
    else:
        out_flat = jnp.concatenate(
            [out_e.reshape(g, e * cap, d),
             jnp.zeros((g, 1, d), x.dtype)], axis=1)
        contrib = jnp.take_along_axis(out_flat, dst[..., None], axis=1)
    contrib = contrib * (sgate * keep)[..., None].astype(x.dtype)
    y = jnp.zeros((g, s, d), x.dtype).at[gidx, stok].add(contrib)

    if "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, "silu")
    return y, aux
