"""State-space / recurrent blocks: Mamba (jamba) and xLSTM (mLSTM+sLSTM).

Training uses parallel forms (associative scan for Mamba's linear
recurrence; the decay-matrix parallel form for mLSTM, as in the xLSTM
paper); decode uses O(1) recurrent state updates — which is what makes
`long_500k` a constant-memory workload for these families.

Projections are role-tagged (`ssm_in/ssm_out/ssm_x`) for the offload
policy; the recurrences themselves stay bf16/f32 (the paper's
non-offloaded F16/F32 host share).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import apply_linear, init_linear
from repro.distributed import ctx
from repro.models.layers import init_rmsnorm, rmsnorm


# ================================================================ Mamba

class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_inner, conv_k - 1) rolling conv window
    ssm: jax.Array   # (B, d_inner, d_state) f32


def mamba_dims(cfg: ModelConfig) -> tuple[int, int]:
    return cfg.ssm_expand * cfg.d_model, cfg.ssm_state


def init_mamba(key: jax.Array, cfg: ModelConfig) -> dict:
    d_in, d_state = mamba_dims(cfg)
    dt_rank = max(cfg.d_model // 16, 1)
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": init_linear(ks[0], cfg.d_model, 2 * d_in, role="ssm_in"),
        "conv_w": (jax.random.normal(ks[1], (d_in, cfg.ssm_conv),
                                     jnp.float32) * 0.2).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((d_in,), jnp.bfloat16),
        "x_proj": init_linear(ks[2], d_in, dt_rank + 2 * d_state,
                              role="ssm_x"),
        "dt_proj": init_linear(ks[3], dt_rank, d_in, role="ssm_x", bias=True),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_linear(ks[4], d_in, cfg.d_model, role="ssm_out"),
    }
    return p


def _mamba_core(p: dict, cfg: ModelConfig, xz: jax.Array,
                conv_state: jax.Array | None):
    """Shared projection path. xz: (B, S, 2*d_in) -> (x_conv, z, dtBC)."""
    d_in, d_state = mamba_dims(cfg)
    x, z = jnp.split(xz, 2, axis=-1)                       # (B,S,d_in)
    # Depthwise causal conv along S.
    kconv = cfg.ssm_conv
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (kconv - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.transpose(0, 2, 1), x], axis=1)
    idx = jnp.arange(x.shape[1])[:, None] + jnp.arange(kconv)[None, :]
    windows = xp[:, idx, :]                                # (B,S,k,d_in)
    xc = jnp.einsum("bskd,dk->bsd", windows.astype(jnp.float32),
                    p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)
    new_conv = xp[:, -(kconv - 1):, :].transpose(0, 2, 1)   # (B,d_in,k-1)
    return xc, z, new_conv


def _selective_params(p: dict, cfg: ModelConfig, xc: jax.Array):
    d_in, d_state = mamba_dims(cfg)
    dt_rank = p["dt_proj"].w.shape[1]
    dbc = apply_linear(p["x_proj"], xc)                    # (B,S,rank+2N)
    dt, bc = jnp.split(dbc, [dt_rank], axis=-1)
    bmat, cmat = jnp.split(bc, 2, axis=-1)                 # (B,S,N) each
    dt = jax.nn.softplus(apply_linear(p["dt_proj"], dt).astype(jnp.float32))
    a = -jnp.exp(p["A_log"])                               # (d_in, N)
    da = jnp.exp(dt[..., None] * a)                        # (B,S,d_in,N)
    dbx = (dt[..., None] * bmat[:, :, None, :].astype(jnp.float32)
           * xc[..., None].astype(jnp.float32))            # (B,S,d_in,N)
    return da, dbx, cmat.astype(jnp.float32)


MAMBA_CHUNK = 256


def mamba_fwd(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Chunked-parallel training form.

    Within a chunk the linear recurrence h_t = da_t * h_{t-1} + dbx_t is
    solved with an associative scan (parallel); chunks are chained with
    a lax.scan carrying the boundary state — bounding the scan's
    intermediate footprint to (B, chunk, d_in, N) instead of the full
    sequence (the standard production trade-off for Mamba on long S).
    """
    b, s, _ = x.shape
    d_in, d_state = mamba_dims(cfg)
    xz = ctx.ffn(apply_linear(p["in_proj"], x))
    xc, z, _ = _mamba_core(p, cfg, xz, None)
    da, dbx, cmat = _selective_params(p, cfg, xc)
    chunk = min(cfg.mamba_chunk or MAMBA_CHUNK, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def reshape_c(t):  # (B,S,...) -> (nc, B, chunk, ...)
        return t.reshape(b, nc, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br

    def chunk_step(h0, inp):
        da_c, dbx_c = inp                                  # (B,chunk,d,N)
        cum_a, inner = jax.lax.associative_scan(
            combine, (da_c, dbx_c), axis=1)
        h = inner + cum_a * h0[:, None]
        return h[:, -1], h

    _, hs = jax.lax.scan(chunk_step,
                         jnp.zeros((b, d_in, d_state), jnp.float32),
                         (reshape_c(da), reshape_c(dbx)),
                         unroll=True if cfg.scan_unroll else 1)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, d_in, d_state)
    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)               # (B,S,d_in)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_linear(p["out_proj"], y)


def init_mamba_state(batch: int, cfg: ModelConfig) -> MambaState:
    d_in, d_state = mamba_dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, d_in, cfg.ssm_conv - 1), jnp.bfloat16),
        ssm=jnp.zeros((batch, d_in, d_state), jnp.float32))


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                 state: MambaState) -> tuple[jax.Array, MambaState]:
    """One-token recurrent step. x: (B, 1, d)."""
    xz = apply_linear(p["in_proj"], x)
    xc, z, new_conv = _mamba_core(p, cfg, xz, state.conv)
    da, dbx, cmat = _selective_params(p, cfg, xc)          # S = 1
    h = da[:, 0] * state.ssm + dbx[:, 0]                   # (B,d_in,N)
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return apply_linear(p["out_proj"], y), MambaState(new_conv, h)


# ================================================================ xLSTM

class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd, hd) matrix memory
    n: jax.Array  # (B, H, hd) normalizer
    m: jax.Array  # (B, H) log-stabilizer


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> dict:
    """mLSTM block (xLSTM): qkv + exponential input/forget gates."""
    h, hd, d = cfg.num_heads, cfg.hd, cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, h * hd, role="attn_qkv"),
        "wk": init_linear(ks[1], d, h * hd, role="attn_qkv"),
        "wv": init_linear(ks[2], d, h * hd, role="attn_qkv"),
        "wi": init_linear(ks[3], d, h, role="ssm_x", bias=True),
        "wf": init_linear(ks[4], d, h, role="ssm_x", bias=True),
        "wo": init_linear(ks[5], h * hd, d, role="attn_out"),
        "ogate": init_linear(jax.random.fold_in(key, 9), d, h * hd,
                             role="ssm_in"),
    }


def _mlstm_qkv(p: dict, cfg: ModelConfig, x: jax.Array):
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.hd

    def heads(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    q = ctx.heads_q(heads(apply_linear(p["wq"], x)).astype(jnp.float32)
                    * hd ** -0.5)
    k = ctx.heads(heads(apply_linear(p["wk"], x)).astype(jnp.float32)
                  * hd ** -0.5)
    v = ctx.heads(heads(apply_linear(p["wv"], x)).astype(jnp.float32))
    i = apply_linear(p["wi"], x).astype(jnp.float32).transpose(0, 2, 1)
    f = apply_linear(p["wf"], x).astype(jnp.float32).transpose(0, 2, 1)
    return q, k, v, i, f


def mlstm_fwd(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Parallel form (xLSTM paper eq. D): decay matrix + stabilization."""
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.hd
    q, k, v, i, f = _mlstm_qkv(p, cfg, x)
    logf = jax.nn.log_sigmoid(f)                           # (B,H,S)
    cum = jnp.cumsum(logf, axis=-1)
    # D[t, s'] = exp(cum[t] - cum[s'] + i[s']) for s' <= t (log-domain).
    dmat = cum[:, :, :, None] - cum[:, :, None, :] + i[:, :, None, :]
    tmask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(tmask, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)              # stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, -1, keepdims=True)),
                       jnp.exp(-m))
    out = jnp.einsum("bhts,bhsd->bhtd", scores / norm, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    o = jax.nn.sigmoid(apply_linear(p["ogate"], x).astype(jnp.float32))
    return apply_linear(p["wo"], (out * o).astype(x.dtype))


def init_mlstm_state(batch: int, cfg: ModelConfig) -> MLSTMState:
    h, hd = cfg.num_heads, cfg.hd
    return MLSTMState(c=jnp.zeros((batch, h, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, h, hd), jnp.float32),
                      m=jnp.full((batch, h), -1e30, jnp.float32))


def mlstm_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                 state: MLSTMState) -> tuple[jax.Array, MLSTMState]:
    """O(1) recurrent step. x: (B, 1, d)."""
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.hd
    q, k, v, i, f = _mlstm_qkv(p, cfg, x)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]           # (B,H,hd)
    i, f = i[:, :, 0], f[:, :, 0]                          # (B,H)
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + state.m, i)
    fg = jnp.exp(logf + state.m - m_new)[..., None]
    ig = jnp.exp(i - m_new)[..., None]
    c = fg[..., None] * state.c + (ig * v)[..., None] * k[:, :, None, :]
    n = fg * state.n + ig * k
    hnum = jnp.einsum("bhvd,bhd->bhv", c, q)
    hden = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                       jnp.exp(-m_new))[..., None]
    out = (hnum / hden).reshape(b, 1, h * hd)
    o = jax.nn.sigmoid(apply_linear(p["ogate"], x).astype(jnp.float32))
    y = apply_linear(p["wo"], (out * o).astype(x.dtype))
    return y, MLSTMState(c, n, m_new)


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)
    h: jax.Array  # (B, D)
    m: jax.Array  # (B, D)


def init_slstm(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "wz": init_linear(ks[0], d, d, role="ssm_in", bias=True),
        "wi": init_linear(ks[1], d, d, role="ssm_x", bias=True),
        "wf": init_linear(ks[2], d, d, role="ssm_x", bias=True),
        "wo_gate": init_linear(ks[3], d, d, role="ssm_x", bias=True),
        "r": (jax.random.normal(ks[4], (4, d), jnp.float32) * 0.1),
        "out": init_linear(jax.random.fold_in(key, 7), d, d,
                           role="ssm_out"),
    }


def init_slstm_state(batch: int, cfg: ModelConfig) -> SLSTMState:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return SLSTMState(z, z, z, jnp.full_like(z, -1e30))


def _slstm_step(p: dict, state: SLSTMState, gates):
    zt, it, ft, ot = gates                                 # (B,D) each f32
    rz, ri, rf, ro = p["r"]
    zt = jnp.tanh(zt + rz * state.h)
    it = it + ri * state.h
    ft = ft + rf * state.h
    ot = jax.nn.sigmoid(ot + ro * state.h)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + state.m, it)
    fg = jnp.exp(logf + state.m - m_new)
    ig = jnp.exp(it - m_new)
    c = fg * state.c + ig * zt
    n = fg * state.n + ig
    h = ot * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h, m_new), h


def slstm_fwd(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Recurrent scan over time (sLSTM is not parallelizable)."""
    b, s, d = x.shape
    xf = x
    gates = tuple(apply_linear(p[w], xf).astype(jnp.float32)
                  for w in ("wz", "wi", "wf", "wo_gate"))   # (B,S,D) x4
    state0 = init_slstm_state(b, cfg)

    def step(st, g):
        return _slstm_step(p, st, g)

    _, hs = jax.lax.scan(step, state0,
                         tuple(g.transpose(1, 0, 2) for g in gates))
    y = hs.transpose(1, 0, 2).astype(x.dtype)               # (B,S,D)
    return apply_linear(p["out"], y)


def slstm_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                 state: SLSTMState) -> tuple[jax.Array, SLSTMState]:
    gates = tuple(apply_linear(p[w], x)[:, 0].astype(jnp.float32)
                  for w in ("wz", "wi", "wf", "wo_gate"))
    state, h = _slstm_step(p, state, gates)
    return apply_linear(p["out"], h[:, None].astype(x.dtype)), state
