"""Unified LM stack covering all ten assigned architecture families.

One scan-based decoder (dense / GQA / SWA / MoE / Mamba / mLSTM / sLSTM
blocks in an arbitrary repeating pattern) plus an optional encoder
(whisper).  Layer parameters for one *period* of the block pattern are
stacked over periods and iterated with ``jax.lax.scan`` so the HLO stays
O(period), not O(num_layers) — essential for compiling 126-layer models
in the dry-run.

Forward paths:
  * :func:`lm_forward` — full-sequence (train / prefill), returns logits
    and MoE aux loss.
  * :func:`lm_decode_step` — one-token decode with a stacked cache
    (KV / SSM / xLSTM states), returns logits and the updated cache.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import Linear, apply_linear, init_linear
from repro.distributed import ctx
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache


# ----------------------------------------------------------- structure

def _period_kinds(cfg: ModelConfig) -> list[str]:
    return list(cfg.block_pattern)


def _ffn_kind(cfg: ModelConfig, j: int) -> str:
    """FFN flavour for position j within a period."""
    if cfg.moe is not None and j % cfg.moe_every == 0:
        return "moe"
    if cfg.d_ff > 0:
        return "mlp"
    return "none"


def _norm(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return L.init_layernorm, L.layernorm
    return L.init_rmsnorm, L.rmsnorm


# ----------------------------------------------------------------- init

def _init_block(key, cfg: ModelConfig, kind: str, *, cross: bool) -> dict:
    init_n, _ = _norm(cfg)
    p: dict[str, Any] = {"norm1": init_n(cfg.d_model)}
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    elif kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        p["mlstm"] = ssm_mod.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = ssm_mod.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["norm_x"] = init_n(cfg.d_model)
        p["cross"] = attn_mod.init_attention(ks[1], cfg, cross=True)
    return p


def _init_layer(key, cfg: ModelConfig, j: int, *, cross: bool) -> dict:
    kind = _period_kinds(cfg)[j]
    ks = jax.random.split(key, 2)
    p = _init_block(ks[0], cfg, kind, cross=cross)
    fk = _ffn_kind(cfg, j)
    init_n, _ = _norm(cfg)
    if fk != "none":
        p["norm2"] = init_n(cfg.d_model)
    if fk == "mlp":
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation)
    elif fk == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    return p


def _init_period(key, cfg: ModelConfig, *, cross: bool) -> list[dict]:
    kinds = _period_kinds(cfg)
    ks = jax.random.split(key, len(kinds))
    return [_init_layer(ks[j], cfg, j, cross=cross) for j in range(len(kinds))]


def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
    """Full LM parameter tree."""
    plen = len(_period_kinds(cfg))
    assert cfg.num_layers % plen == 0, (cfg.num_layers, plen)
    n_periods = cfg.num_layers // plen
    keys = jax.random.split(key, 8)
    init_n, _ = _norm(cfg)

    period_keys = jax.random.split(keys[0], n_periods)
    stacked = jax.vmap(
        functools.partial(_init_period, cfg=cfg, cross=cfg.is_enc_dec)
    )(period_keys)

    p: dict[str, Any] = {
        "embed": L.init_embedding(keys[1], cfg.vocab_size, cfg.d_model),
        "layers": stacked,
        "final_norm": init_n(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(keys[2], cfg.d_model, cfg.vocab_size,
                                   role="lm_head")
    if cfg.is_enc_dec:
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        enc_cfg = cfg  # same width; encoder blocks are plain attention
        p["encoder"] = {
            "layers": jax.vmap(
                lambda k: [_init_layer(k, enc_cfg, 0, cross=False)])(enc_keys),
            "final_norm": init_n(cfg.d_model),
        }
    return p


# ------------------------------------------------------------- forward

def _apply_norm(cfg, p, x):
    _, f = _norm(cfg)
    return f(p, x, cfg.norm_eps)


def _block_fwd(p: dict, cfg: ModelConfig, kind: str, x, positions,
               *, causal: bool, enc_out=None):
    h = _apply_norm(cfg, p["norm1"], x)
    rope = cfg.pos_embed == "rope"
    if kind == "attn":
        y = attn_mod.attention_fwd(p["attn"], cfg, h, positions,
                                   causal=causal, rope=rope)
    elif kind == "mamba":
        y = ssm_mod.mamba_fwd(p["mamba"], cfg, h)
    elif kind == "mlstm":
        y = ssm_mod.mlstm_fwd(p["mlstm"], cfg, h)
    elif kind == "slstm":
        y = ssm_mod.slstm_fwd(p["slstm"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + y
    if enc_out is not None and "cross" in p:
        h = _apply_norm(cfg, p["norm_x"], x)
        x = x + attn_mod.attention_fwd(p["cross"], cfg, h, positions,
                                       causal=False, kv_x=enc_out)
    return x


def _apply_ffn(p: dict, cfg: ModelConfig, j: int, x):
    """norm2 + MLP/MoE residual tail of layer ``j`` (position-wise, so
    it is identical for full-sequence, chunk, and one-token inputs).
    Returns (x, moe aux loss)."""
    fk = _ffn_kind(cfg, j)
    aux = jnp.zeros((), jnp.float32)
    if fk == "mlp":
        h = _apply_norm(cfg, p["norm2"], x)
        x = x + L.apply_mlp(p["mlp"], h, cfg.activation)
    elif fk == "moe":
        h = _apply_norm(cfg, p["norm2"], x)
        y, aux = moe_mod.apply_moe(p["moe"], cfg, h)
        x = x + y
    return x, aux


def _layer_fwd(p: dict, cfg: ModelConfig, j: int, x, positions,
               *, causal: bool, enc_out=None):
    kind = _period_kinds(cfg)[j]
    x = ctx.act(_block_fwd(p, cfg, kind, x, positions, causal=causal,
                           enc_out=enc_out))
    return _apply_ffn(p, cfg, j, x)


def _sinusoidal(seq: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, jnp.float32) / d))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(
        jnp.bfloat16)


def _stack_fwd(stacked, cfg: ModelConfig, x, positions, *,
               causal: bool, enc_out=None, remat: str = "none"):
    """Scan over layer periods; returns (x, total_aux)."""

    def period_body(carry, period_params):
        x, aux = carry
        for j in range(len(_period_kinds(cfg))):
            x, a = _layer_fwd(period_params[j], cfg, j, x, positions,
                              causal=causal, enc_out=enc_out)
            aux = aux + a
        return (x, aux), None

    body = period_body
    if remat in ("block", "full"):
        policy = (None if remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(period_body, policy=policy,
                              prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stacked, unroll=True if cfg.scan_unroll else 1)
    return x, aux


def encoder_forward(params: dict, cfg: ModelConfig,
                    enc_embeds: jax.Array, *, remat: str = "none"):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend supplies them). Non-causal self attention."""
    b, s, _ = enc_embeds.shape
    x = enc_embeds + _sinusoidal(s, cfg.d_model)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _stack_fwd(params["encoder"]["layers"], cfg, x, positions,
                      causal=False, remat=remat)
    return _apply_norm(cfg, params["encoder"]["final_norm"], x)


def lm_forward(params: dict, cfg: ModelConfig, tokens: jax.Array,
               *, enc_embeds: jax.Array | None = None,
               prefix_embeds: jax.Array | None = None,
               remat: str = "none",
               last_only: bool = False) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits (B, S, V) f32, moe aux loss scalar).

    ``enc_embeds``: encoder-frontend output for enc-dec models.
    ``prefix_embeds``: VLM stub — precomputed patch embeddings prepended
    to the token embeddings (qwen2-vl).
    ``last_only``: serving prefill — unembed only the final position
    (the (B,S,V) logits tensor would otherwise dominate prefill memory).
    """
    b, s = tokens.shape
    x = ctx.act(L.apply_embedding(params["embed"], tokens))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal(s, cfg.d_model)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_out = None
    if cfg.is_enc_dec:
        assert enc_embeds is not None, "enc-dec model needs encoder input"
        enc_out = encoder_forward(params, cfg, enc_embeds, remat=remat)
    x, aux = _stack_fwd(params["layers"], cfg, x, positions,
                        causal=True, enc_out=enc_out, remat=remat)
    x = _apply_norm(cfg, params["final_norm"], x)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    if last_only:
        x = x[:, -1:]
    head = params.get("lm_head") or Linear(params["embed"].w,
                                           role="lm_head")
    logits = L.apply_unembed(head, x)
    return logits, aux


# -------------------------------------------------------------- decode

class LayerCache(NamedTuple):
    """Union cache for one layer; unused fields are size-0 arrays so the
    pytree structure is uniform across kinds (scan requirement is per-
    period anyway, but uniformity keeps sharding specs simple)."""
    kv: Any
    mamba: Any
    mlstm: Any
    slstm: Any
    cross_k: Any
    cross_v: Any


def init_cache(params: dict, cfg: ModelConfig, batch: int, max_len: int,
               *, quantized_kv: bool = False,
               enc_embeds: jax.Array | None = None,
               block_size: int | None = None,
               num_blocks: int | None = None,
               cross_block_size: int | None = None,
               cross_num_blocks: int | None = None) -> Any:
    """Stacked per-period cache pytree (+ precomputed cross KV).

    With ``block_size``/``num_blocks`` set, self-attention KV uses the
    *paged* block-pool layout (one (num_blocks, Hkv, block_size, hd)
    pool per attn layer; slot -> block mapping lives host-side in
    ``serving.kvcache``).  Recurrent (SSM / xLSTM) states stay
    slot-indexed either way.

    Enc-dec cross KV has two layouts: by default it is precomputed
    *here* from ``enc_embeds`` (contiguous (B, Hkv, S_enc, hd) rows —
    the legacy/serving-scheduler path).  With ``cross_block_size`` /
    ``cross_num_blocks`` set the cross KV becomes a *paged* bf16 pool
    (cross_num_blocks, Hkv, cross_block_size, hd) per attn layer,
    initialized empty — the ASR engine encodes audio incrementally and
    scatters projections in later via :func:`write_cross_kv`, so no
    ``enc_embeds`` are consumed here.
    """
    kinds = _period_kinds(cfg)
    plen = len(kinds)
    n_periods = cfg.num_layers // plen
    if (block_size is None) != (num_blocks is None):
        raise ValueError("paged cache needs both block_size and num_blocks")
    if (cross_block_size is None) != (cross_num_blocks is None):
        raise ValueError("paged cross cache needs both cross_block_size "
                         "and cross_num_blocks")
    paged_cross = cross_block_size is not None
    if paged_cross and not cfg.is_enc_dec:
        raise ValueError("cross pool requested for a non-enc-dec config")

    enc_out = None
    if cfg.is_enc_dec and not paged_cross:
        enc_out = encoder_forward(params, cfg, enc_embeds)

    def one_layer(j: int, period: int):
        kind = kinds[j]
        kv = mamba = mlstm = slstm = ck = cv = ()
        if kind == "attn":
            if block_size is not None:
                kv = attn_mod.init_paged_kv_cache(num_blocks, cfg,
                                                  block_size,
                                                  quantized=quantized_kv)
            else:
                kv = attn_mod.init_kv_cache(batch, cfg, max_len,
                                            quantized=quantized_kv)
        elif kind == "mamba":
            mamba = ssm_mod.init_mamba_state(batch, cfg)
        elif kind == "mlstm":
            mlstm = ssm_mod.init_mlstm_state(batch, cfg)
        elif kind == "slstm":
            slstm = ssm_mod.init_slstm_state(batch, cfg)
        if cfg.is_enc_dec and paged_cross:
            cshape = (cross_num_blocks, cfg.num_kv_heads,
                      cross_block_size, cfg.hd)
            ck = jnp.zeros(cshape, jnp.bfloat16)
            cv = jnp.zeros(cshape, jnp.bfloat16)
        elif cfg.is_enc_dec:
            layer_p = jax.tree.map(lambda a: a[period],
                                   params["layers"][j]["cross"])
            src = enc_out
            k = apply_linear(layer_p["wk"], src)
            v = apply_linear(layer_p["wv"], src)
            bsz, se, _ = src.shape
            ck = k.reshape(bsz, se, cfg.num_kv_heads, cfg.hd).transpose(
                0, 2, 1, 3)
            cv = v.reshape(bsz, se, cfg.num_kv_heads, cfg.hd).transpose(
                0, 2, 1, 3)
        return LayerCache(kv, mamba, mlstm, slstm, ck, cv)

    periods = []
    for period in range(n_periods):
        periods.append([one_layer(j, period) for j in range(plen)])
    # Stack over periods.
    return jax.tree.map(lambda *xs: jnp.stack(xs), *periods)


def write_cross_kv(params: dict, cfg: ModelConfig, enc_out: jax.Array,
                   cross_table: jax.Array, cache: Any) -> Any:
    """Project finished encoder output into one slot's cross blocks.

    enc_out: (1, S_enc, d) — the encoder states for ONE request;
    cross_table: (MBc,) int32 — the slot's cross-block row.  For every
    decoder layer, K/V projections are computed once here and scattered
    into that layer's paged bf16 cross pool; the partial tail block is
    zero-padded (readers mask ``idx < enc_len``).  Runs once per
    request, at encode completion.  Returns the updated cache.
    """
    kinds = _period_kinds(cfg)
    se = enc_out.shape[1]
    cbs = cache[0].cross_k.shape[3]      # (P, NBc, Hkv, cbs, hd)
    mb = cross_table.shape[0]
    pad = mb * cbs - se

    def write_one(layer_p, ck, cv):
        # layer_p: one period's cross params; ck/cv: (NBc, Hkv, cbs, hd)
        def to_blocks(t):
            t = t[0].reshape(se, cfg.num_kv_heads, cfg.hd).transpose(1, 0, 2)
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
            t = t.reshape(cfg.num_kv_heads, mb, cbs, cfg.hd)
            return t.transpose(1, 0, 2, 3).astype(ck.dtype)
        return (ck.at[cross_table].set(to_blocks(
                    apply_linear(layer_p["wk"], enc_out))),
                cv.at[cross_table].set(to_blocks(
                    apply_linear(layer_p["wv"], enc_out))))

    new = []
    for j in range(len(kinds)):
        ck, cv = jax.vmap(write_one)(params["layers"][j]["cross"],
                                     cache[j].cross_k, cache[j].cross_v)
        new.append(cache[j]._replace(cross_k=ck, cross_v=cv))
    return new


def _block_cross(p: dict, cfg: ModelConfig, x, cache: LayerCache,
                 cross_tables):
    """Cross-attention residual shared by decode and fused prefill:
    paged pool read when ``cross_tables`` is given, contiguous
    precomputed rows otherwise."""
    h = _apply_norm(cfg, p["norm_x"], x)
    if cross_tables is not None:
        return x + attn_mod.cross_attention_paged(
            p["cross"], cfg, h, cross_tables, cache.cross_k, cache.cross_v,
            enc_len=cfg.encoder_seq)
    return x + attn_mod.cross_attention_decode(p["cross"], cfg, h,
                                               cache.cross_k, cache.cross_v)


def _block_decode(p: dict, cfg: ModelConfig, kind: str, x, pos,
                  cache: LayerCache, block_tables=None, cross_tables=None):
    h = _apply_norm(cfg, p["norm1"], x)
    rope = cfg.pos_embed == "rope"
    if kind == "attn":
        y, kv = attn_mod.attention_decode(p["attn"], cfg, h, pos, cache.kv,
                                          rope=rope,
                                          block_tables=block_tables)
        cache = cache._replace(kv=kv)
    elif kind == "mamba":
        y, st = ssm_mod.mamba_decode(p["mamba"], cfg, h, cache.mamba)
        cache = cache._replace(mamba=st)
    elif kind == "mlstm":
        y, st = ssm_mod.mlstm_decode(p["mlstm"], cfg, h, cache.mlstm)
        cache = cache._replace(mlstm=st)
    elif kind == "slstm":
        y, st = ssm_mod.slstm_decode(p["slstm"], cfg, h, cache.slstm)
        cache = cache._replace(slstm=st)
    else:
        raise ValueError(kind)
    x = x + y
    if cfg.is_enc_dec and "cross" in p:
        x = _block_cross(p, cfg, x, cache, cross_tables)
    return x, cache


def lm_decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                   pos: jax.Array, cache: Any, *,
                   block_tables: jax.Array | None = None,
                   cross_tables: jax.Array | None = None
                   ) -> tuple[jax.Array, Any]:
    """token: (B, 1) int32; pos: scalar int32 shared by all rows, or
    (B,) int32 per-slot positions -> (logits (B,1,V), cache).

    ``block_tables`` (B, MB) int32 selects the paged KV layout (see
    :func:`init_cache`); it requires per-slot positions.
    ``cross_tables`` (B, MBc) int32 likewise selects the paged cross
    pool for enc-dec models (ASR serving); without it cross KV is read
    from the cache's contiguous precomputed rows.
    """
    kinds = _period_kinds(cfg)
    x = L.apply_embedding(params["embed"], token)
    if cfg.pos_embed == "sinusoidal":
        if jnp.ndim(pos) > 0:        # per-row absolute offsets
            x = x + jax.vmap(
                lambda o: _sinusoidal(1, cfg.d_model, offset=o))(pos)
        else:
            x = x + _sinusoidal(1, cfg.d_model, offset=pos)[None]

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_caches = []
        for j, kind in enumerate(kinds):
            x, c = _block_decode(period_params[j], cfg, kind, x, pos,
                                 period_cache[j],
                                 block_tables=block_tables,
                                 cross_tables=cross_tables)
            new_caches.append(c)
            x, _ = _apply_ffn(period_params[j], cfg, j, x)
        return x, new_caches

    x, new_cache = jax.lax.scan(period_body, x,
                                (params["layers"], cache),
                                unroll=True if cfg.scan_unroll else 1)
    x = _apply_norm(cfg, params["final_norm"], x)
    head = params.get("lm_head") or Linear(params["embed"].w,
                                           role="lm_head")
    logits = L.apply_unembed(head, x)
    return logits, new_cache


def prefill_fused_eligible(cfg: ModelConfig, *,
                           quantized_kv: bool = False) -> bool:
    """True when a prompt chunk can go through a fused paged
    flash-prefill kernel instead of the decode-step scan: every layer
    must be plain self-attention (recurrent/hybrid state has no fused
    multi-token update).

    ``quantized_kv`` no longer disqualifies: Q8_0 pools dispatch the
    ``flash_prefill_paged_q8`` sibling, which requantizes the chunk's
    KV in-kernel (the kwarg is kept so callers can state the pool
    dtype; both pool dtypes are now fused-eligible).

    Enc-dec decoders no longer disqualify either: cross attention is
    non-causal over a *fixed*, fully-precomputed encoder KV set, so
    every chunk position is independent — the fused path adds one
    cross-attention read per layer (contiguous or paged) after the
    fused self-attention program, mathematically identical to the
    per-token scan (oracle-gated in tests)."""
    del quantized_kv  # Q8_0 pools take the fused q8 sibling kernel
    return set(_period_kinds(cfg)) == {"attn"}


def prefill_path(cfg: ModelConfig, *, quantized_kv: bool = False,
                 batch: int = 1, fused: bool = True) -> str:
    """Single source of truth for which prefill path a chunk executes:
    ``"fused"`` (one kernel launch per chunk) or ``"scan"`` (one decode
    step per token).  ``lm_prefill_chunk``'s dispatch and the serving
    scheduler's launch accounting / cost-model keys both derive from
    this, so estimates can never be keyed on a path that isn't taken.
    """
    if (fused and batch == 1
            and prefill_fused_eligible(cfg, quantized_kv=quantized_kv)):
        return "fused"
    return "scan"


def _lm_prefill_chunk_fused(params: dict, cfg: ModelConfig,
                            tokens: jax.Array, pos0: jax.Array, cache: Any,
                            block_tables: jax.Array,
                            cross_tables: jax.Array | None = None,
                            last_only: bool = True
                            ) -> tuple[jax.Array, Any]:
    """Fused prefill: the whole chunk runs as ONE forward over the
    paged pool per layer (``attention_prefill_paged``) instead of a
    T-step scan of :func:`lm_decode_step` — one kernel launch per
    layer per chunk.  Pure-attention decoders only (see
    :func:`prefill_fused_eligible`); FFN / MoE are position-wise, so
    the chunk-at-once result matches the scan to fp32 allclose.
    Enc-dec decoders add one chunk-at-once cross-attention read per
    layer (non-causal over fixed encoder KV, so per-position
    independent — identical to the scan's per-token reads)."""
    kinds = _period_kinds(cfg)
    t = tokens.shape[1]
    x = L.apply_embedding(params["embed"], tokens)
    if cfg.pos_embed == "sinusoidal":
        x = x + jax.vmap(
            lambda o: _sinusoidal(t, cfg.d_model, offset=o))(pos0)
    rope = cfg.pos_embed == "rope"

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_caches = []
        for j, kind in enumerate(kinds):
            assert kind == "attn", kind
            p = period_params[j]
            h = _apply_norm(cfg, p["norm1"], x)
            y, kv = attn_mod.attention_prefill_paged(
                p["attn"], cfg, h, pos0, period_cache[j].kv,
                block_tables, rope=rope)
            x = x + y
            if cfg.is_enc_dec and "cross" in p:
                x = _block_cross(p, cfg, x, period_cache[j], cross_tables)
            new_caches.append(period_cache[j]._replace(kv=kv))
            x, _ = _apply_ffn(p, cfg, j, x)
        return x, new_caches

    x, new_cache = jax.lax.scan(period_body, x,
                                (params["layers"], cache),
                                unroll=True if cfg.scan_unroll else 1)
    # Prompt prefill only needs the next-token logits; verification
    # (speculative decoding) needs the target's logits at EVERY chunk
    # position, so the unembed is the one place the two differ.
    x = _apply_norm(cfg, params["final_norm"],
                    x[:, -1:] if last_only else x)
    head = params.get("lm_head") or Linear(params["embed"].w,
                                           role="lm_head")
    return L.apply_unembed(head, x), new_cache


def lm_prefill_chunk(params: dict, cfg: ModelConfig, tokens: jax.Array,
                     pos0: jax.Array, cache: Any, *,
                     block_tables: jax.Array | None = None,
                     cross_tables: jax.Array | None = None,
                     fused: bool = True) -> tuple[jax.Array, Any]:
    """Prefill of one chunk: tokens (B, C) at positions
    ``pos0 .. pos0+C-1``; returns the logits of the *last* position and
    the updated cache.  pos0: (B,) int32.

    Two paths, one compiled program per chunk length either way:

    * **fused** (default when eligible) — the chunk runs as one fused
      attention program per layer against the paged pool
      (:func:`_lm_prefill_chunk_fused`): causal within the chunk,
      position-masked against history, KV written in-kernel.
    * **decode-step scan** (the reference oracle) — a ``lax.scan`` of
      :func:`lm_decode_step`, bit-identical to feeding the chunk
      through single-token decode; recurrent (SSM / xLSTM) states and
      batch > 1 always take this path (the fused kernel is batch-1,
      one slot per admission), and tests pin ``fused=False`` to it as
      the ground truth.  Quantized (Q8_0) KV is fused-eligible: it
      dispatches the q8 sibling kernel, which requantizes the chunk
      in-kernel; the scan remains its dequant-reference oracle at
      tolerance (see ``kernels/README.md``).  Enc-dec decoders are
      fused-eligible too — cross attention (``cross_tables`` paged, or
      contiguous precomputed rows) runs chunk-at-once per layer.
    """
    if block_tables is not None:
        quantized = any(
            isinstance(c.kv, attn_mod.KVCache) and c.kv.k_scale is not None
            for c in cache)
        if prefill_path(cfg, quantized_kv=quantized,
                        batch=tokens.shape[0], fused=fused) == "fused":
            return _lm_prefill_chunk_fused(params, cfg, tokens, pos0,
                                           cache, block_tables,
                                           cross_tables)

    def body(carry, tok_col):
        pos, cache = carry
        logits, cache = lm_decode_step(params, cfg, tok_col[:, None], pos,
                                       cache, block_tables=block_tables,
                                       cross_tables=cross_tables)
        return (pos + 1, cache), logits

    (_, cache), logits = jax.lax.scan(body, (pos0, cache), tokens.T)
    return logits[-1], cache


def lm_verify_chunk(params: dict, cfg: ModelConfig, tokens: jax.Array,
                    pos0: jax.Array, cache: Any, *,
                    block_tables: jax.Array | None = None,
                    cross_tables: jax.Array | None = None,
                    fused: bool = True) -> tuple[jax.Array, Any]:
    """Verification launch for speculative decoding: tokens (B, C) at
    positions ``pos0 .. pos0+C-1`` -> (logits (B, C, V), cache).

    Identical transformer math to :func:`lm_prefill_chunk` — same fused
    chunk-at-once path when eligible, same decode-step scan otherwise —
    but the unembed covers *every* chunk position instead of only the
    last one, because the verifier needs the target's greedy choice
    after each proposed draft token.  Position ``j``'s logits condition
    on ``tokens[:, :j+1]`` plus cached history (causal within the
    chunk), exactly what feeding the chunk token-by-token through
    :func:`lm_decode_step` produces; the scan path IS that feeding, so
    scan-verified speculation is bit-exact against plain decode by
    construction.
    """
    if block_tables is not None:
        quantized = any(
            isinstance(c.kv, attn_mod.KVCache) and c.kv.k_scale is not None
            for c in cache)
        if prefill_path(cfg, quantized_kv=quantized,
                        batch=tokens.shape[0], fused=fused) == "fused":
            return _lm_prefill_chunk_fused(params, cfg, tokens, pos0,
                                           cache, block_tables,
                                           cross_tables, last_only=False)

    def body(carry, tok_col):
        pos, cache = carry
        logits, cache = lm_decode_step(params, cfg, tok_col[:, None], pos,
                                       cache, block_tables=block_tables,
                                       cross_tables=cross_tables)
        return (pos + 1, cache), logits

    (_, cache), logits = jax.lax.scan(body, (pos0, cache), tokens.T)
    # scanned logits stack as (C, B, 1, V); callers want (B, C, V)
    return jnp.moveaxis(logits[:, :, 0], 0, 1), cache


# ---------------------------------------------------- slot cache surgery
# Host-side serving (serving.kvcache / serving.scheduler) runs chunked
# prefill at batch 1 for the slot being admitted.  These helpers carve a
# batch-1 view out of the slot-batched cache: paged KV pools are shared
# (the block table already isolates the slot), while recurrent states
# and cross KV are sliced / written back by row.

def _slot_rows(sub, fn):
    return jax.tree.map(fn, sub)


def cache_slot_view(cache: Any, slot: jax.Array, *,
                    paged_cross: bool = False) -> Any:
    """Batch-1 view of ``slot``'s rows (paged KV pools pass through).

    ``paged_cross`` passes the cross KV through unsliced too — with the
    paged cross layout it is a shared block pool, not slot rows (the
    slot's cross-table row does the isolation)."""
    def take(x):
        return jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=1)
    def take_cross(x):
        return x if paged_cross else _slot_rows(x, take)
    return [c._replace(mamba=_slot_rows(c.mamba, take),
                       mlstm=_slot_rows(c.mlstm, take),
                       slstm=_slot_rows(c.slstm, take),
                       cross_k=take_cross(c.cross_k),
                       cross_v=take_cross(c.cross_v))
            for c in cache]


def cache_slot_merge(cache: Any, local: Any, slot: jax.Array) -> Any:
    """Fold a batch-1 view back: KV pools are taken from ``local``
    (updated in place by paged writes), recurrent rows are scattered
    back at ``slot``; cross KV is read-only during decode (both
    layouts), so the full cache's copy is kept as-is."""
    def put(full, sub):
        return jax.tree.map(
            lambda f, s: jax.lax.dynamic_update_slice_in_dim(f, s, slot,
                                                             axis=1),
            full, sub)
    return [c._replace(kv=l.kv,
                       mamba=put(c.mamba, l.mamba),
                       mlstm=put(c.mlstm, l.mlstm),
                       slstm=put(c.slstm, l.slstm))
            for c, l in zip(cache, local)]


def cache_slot_reset(cache: Any, slot: jax.Array) -> Any:
    """Zero ``slot``'s recurrent (SSM / xLSTM) states — a freshly
    admitted request must not inherit the previous occupant's state.
    Paged KV needs no reset: the allocator hands out whole blocks and
    per-row masking never reads past the slot's own positions."""
    def zero(x):
        return x.at[:, slot].set(jnp.zeros_like(x[:, :1])[:, 0])
    return [c._replace(mamba=_slot_rows(c.mamba, zero),
                       mlstm=_slot_rows(c.mlstm, zero),
                       slstm=_slot_rows(c.slstm, zero))
            for c in cache]
