"""SD v1.5 / SD-Turbo U-Net in JAX.

Faithful to stable-diffusion.cpp's execution structure: **convolutions
are im2col + mul_mat** (exactly how GGML lowers them), so every conv is
a role-tagged linear and participates in the paper's dot-product
accounting.  Attention blocks are spatial transformers with cross
attention to the CLIP text states.

Full-size config matches SD v1.5 (SD-Turbo shares the architecture);
tests run a reduced config.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.qlinear import Linear, apply_linear, init_linear
from repro.kernels import ops
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    in_channels: int = 4
    out_channels: int = 4
    model_channels: int = 320
    channel_mult: tuple = (1, 2, 4, 4)
    num_res_blocks: int = 2
    attention_levels: tuple = (0, 1, 2)   # levels with spatial transformer
    num_heads: int = 8
    context_dim: int = 768                # CLIP hidden size
    time_dim_mult: int = 4
    groups: int = 32

    @property
    def time_dim(self) -> int:
        return self.model_channels * self.time_dim_mult


SD15_UNET = UNetConfig()
TINY_UNET = UNetConfig(model_channels=32, channel_mult=(1, 2),
                       num_res_blocks=1, attention_levels=(0, 1),
                       num_heads=2, context_dim=64, groups=8)


# ---------------------------------------------------------------- conv

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Conv:
    """im2col conv: a Linear over patches. Kernel size is static aux."""
    lin: Linear
    k: int = 3

    def tree_flatten(self):
        return (self.lin,), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        return cls(children[0], k)


def init_conv(key, in_ch: int, out_ch: int, k: int = 3, *,
              role: str = "conv") -> Conv:
    fan_in = in_ch * k * k
    w = (jax.random.normal(key, (out_ch, fan_in), jnp.float32)
         * fan_in ** -0.5).astype(jnp.bfloat16)
    return Conv(Linear(w, jnp.zeros((out_ch,), jnp.bfloat16), role), k)


def apply_conv(p: Conv, x: jax.Array, stride: int = 1) -> jax.Array:
    """x: (B, H, W, C) -> (B, H', W', out_ch) via im2col + mul_mat."""
    k = p.k
    pad = (k - 1) // 2
    patches = jax.lax.conv_general_dilated_patches(
        x, (k, k), (stride, stride), ((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # patches: (B, H', W', C*k*k) — the im2col buffer GGML builds.
    return apply_linear(p.lin, patches)


# ------------------------------------------------------------ groupnorm

def init_groupnorm(ch: int) -> dict:
    return {"g": jnp.ones((ch,), jnp.float32),
            "b": jnp.zeros((ch,), jnp.float32)}


def groupnorm(p: dict, x: jax.Array, groups: int, eps: float = 1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xn = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(b, h, w, c)
    return (xn * p["g"] + p["b"]).astype(x.dtype)


# ------------------------------------------------------------ res block

def init_resblock(key, in_ch: int, out_ch: int, time_dim: int,
                  groups: int) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "norm1": init_groupnorm(in_ch),
        "conv1": init_conv(ks[0], in_ch, out_ch),
        "time": init_linear(ks[1], time_dim, out_ch, role="time_embed",
                            bias=True),
        "norm2": init_groupnorm(out_ch),
        "conv2": init_conv(ks[2], out_ch, out_ch),
    }
    if in_ch != out_ch:
        p["skip"] = init_conv(ks[3], in_ch, out_ch, k=1)
    return p


def apply_resblock(p: dict, x: jax.Array, temb: jax.Array,
                   groups: int) -> jax.Array:
    h = apply_conv(p["conv1"], jax.nn.silu(groupnorm(p["norm1"], x, groups)))
    h = h + apply_linear(p["time"], jax.nn.silu(temb))[:, None, None, :]
    h = apply_conv(p["conv2"], jax.nn.silu(groupnorm(p["norm2"], h, groups)))
    skip = apply_conv(p["skip"], x) if "skip" in p else x
    return skip + h


# ------------------------------------------- spatial transformer block

def init_spatial_transformer(key, ch: int, cfg: UNetConfig) -> dict:
    ks = jax.random.split(key, 12)
    inner = ch
    return {
        "norm": init_groupnorm(ch),
        "proj_in": init_conv(ks[0], ch, inner, k=1),
        "ln1": L.init_layernorm(inner),
        "q1": init_linear(ks[1], inner, inner, role="attn_qkv"),
        "k1": init_linear(ks[2], inner, inner, role="attn_qkv"),
        "v1": init_linear(ks[3], inner, inner, role="attn_qkv"),
        "o1": init_linear(ks[4], inner, inner, role="attn_out"),
        "ln2": L.init_layernorm(inner),
        "q2": init_linear(ks[5], inner, inner, role="attn_qkv"),
        "k2": init_linear(ks[6], cfg.context_dim, inner, role="attn_qkv"),
        "v2": init_linear(ks[7], cfg.context_dim, inner, role="attn_qkv"),
        "o2": init_linear(ks[8], inner, inner, role="attn_out"),
        "ln3": L.init_layernorm(inner),
        "ff1": init_linear(ks[9], inner, inner * 8, role="mlp_up"),
        "ff2": init_linear(ks[10], inner * 4, inner, role="mlp_down"),
        "proj_out": init_conv(ks[11], inner, ch, k=1),
    }


def _mha(q_p, k_p, v_p, o_p, x, ctx, heads: int):
    b, n, c = x.shape
    hd = c // heads

    def split(t):
        return t.reshape(b, -1, heads, hd).transpose(0, 2, 1, 3)
    q = split(apply_linear(q_p, x))
    k = split(apply_linear(k_p, ctx))
    v = split(apply_linear(v_p, ctx))
    out = ops.attention(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, n, c)
    return apply_linear(o_p, out)


def apply_spatial_transformer(p: dict, x: jax.Array, ctx: jax.Array,
                              cfg: UNetConfig) -> jax.Array:
    b, h, w, c = x.shape
    res = x
    xn = groupnorm(p["norm"], x, cfg.groups)
    xn = apply_conv(p["proj_in"], xn).reshape(b, h * w, c)
    xn = xn + _mha(p["q1"], p["k1"], p["v1"], p["o1"],
                   L.layernorm(p["ln1"], xn), L.layernorm(p["ln1"], xn),
                   cfg.num_heads)
    xn = xn + _mha(p["q2"], p["k2"], p["v2"], p["o2"],
                   L.layernorm(p["ln2"], xn), ctx, cfg.num_heads)
    # GEGLU feed-forward.
    hgl = apply_linear(p["ff1"], L.layernorm(p["ln3"], xn))
    hh, gate = jnp.split(hgl, 2, axis=-1)
    xn = xn + apply_linear(p["ff2"], hh * jax.nn.gelu(gate))
    xn = apply_conv(p["proj_out"], xn.reshape(b, h, w, c))
    return res + xn


# ---------------------------------------------------------------- UNet

def timestep_embedding(t: jax.Array, dim: int) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], -1)


def init_unet(key, cfg: UNetConfig) -> dict:
    ks = iter(jax.random.split(key, 256))
    ch = cfg.model_channels
    p: dict[str, Any] = {
        "time1": init_linear(next(ks), ch, cfg.time_dim, role="time_embed",
                             bias=True),
        "time2": init_linear(next(ks), cfg.time_dim, cfg.time_dim,
                             role="time_embed", bias=True),
        "conv_in": init_conv(next(ks), cfg.in_channels, ch),
    }
    downs = []
    ch_stack = [ch]
    cur = ch
    for lvl, mult in enumerate(cfg.channel_mult):
        out_ch = ch * mult
        for _ in range(cfg.num_res_blocks):
            blk = {"res": init_resblock(next(ks), cur, out_ch,
                                        cfg.time_dim, cfg.groups)}
            if lvl in cfg.attention_levels:
                blk["attn"] = init_spatial_transformer(next(ks), out_ch, cfg)
            downs.append(blk)
            cur = out_ch
            ch_stack.append(cur)
        if lvl != len(cfg.channel_mult) - 1:
            downs.append({"down": init_conv(next(ks), cur, cur)})
            ch_stack.append(cur)
    p["downs"] = downs

    p["mid"] = {
        "res1": init_resblock(next(ks), cur, cur, cfg.time_dim, cfg.groups),
        "attn": init_spatial_transformer(next(ks), cur, cfg),
        "res2": init_resblock(next(ks), cur, cur, cfg.time_dim, cfg.groups),
    }

    ups = []
    for lvl, mult in reversed(list(enumerate(cfg.channel_mult))):
        out_ch = ch * mult
        for i in range(cfg.num_res_blocks + 1):
            skip = ch_stack.pop()
            blk = {"res": init_resblock(next(ks), cur + skip, out_ch,
                                        cfg.time_dim, cfg.groups)}
            if lvl in cfg.attention_levels:
                blk["attn"] = init_spatial_transformer(next(ks), out_ch, cfg)
            if i == cfg.num_res_blocks and lvl != 0:
                blk["up"] = init_conv(next(ks), out_ch, out_ch)
            ups.append(blk)
            cur = out_ch
    p["ups"] = ups
    p["norm_out"] = init_groupnorm(cur)
    p["conv_out"] = init_conv(next(ks), cur, cfg.out_channels)
    return p


def apply_unet(p: dict, cfg: UNetConfig, x: jax.Array, t: jax.Array,
               ctx: jax.Array) -> jax.Array:
    """x: (B, H, W, 4) latent; t: (B,) timestep; ctx: (B, 77, ctx_dim)."""
    temb = timestep_embedding(t, cfg.model_channels).astype(x.dtype)
    temb = apply_linear(p["time2"],
                        jax.nn.silu(apply_linear(p["time1"], temb)))
    h = apply_conv(p["conv_in"], x)
    skips = [h]
    for blk in p["downs"]:
        if "down" in blk:
            h = apply_conv(blk["down"], h, stride=2)
        else:
            h = apply_resblock(blk["res"], h, temb, cfg.groups)
            if "attn" in blk:
                h = apply_spatial_transformer(blk["attn"], h, ctx, cfg)
        skips.append(h)
    h = apply_resblock(p["mid"]["res1"], h, temb, cfg.groups)
    h = apply_spatial_transformer(p["mid"]["attn"], h, ctx, cfg)
    h = apply_resblock(p["mid"]["res2"], h, temb, cfg.groups)
    for blk in p["ups"]:
        h = jnp.concatenate([h, skips.pop()], axis=-1)
        h = apply_resblock(blk["res"], h, temb, cfg.groups)
        if "attn" in blk:
            h = apply_spatial_transformer(blk["attn"], h, ctx, cfg)
        if "up" in blk:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = apply_conv(blk["up"], h)
    h = jax.nn.silu(groupnorm(p["norm_out"], h, cfg.groups))
    return apply_conv(p["conv_out"], h)
