"""SD VAE decoder (latent -> image), GGML-style im2col convs."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.unet import (apply_conv, groupnorm, init_conv,
                               init_groupnorm)
from repro.core.qlinear import apply_linear, init_linear


@dataclasses.dataclass(frozen=True)
class VAEConfig:
    z_channels: int = 4
    out_channels: int = 3
    base: int = 128
    channel_mult: tuple = (1, 2, 4, 4)   # decoder runs reversed
    num_res_blocks: int = 2
    groups: int = 32
    scale_factor: float = 0.18215


SD15_VAE = VAEConfig()
TINY_VAE = VAEConfig(base=32, channel_mult=(1, 2), num_res_blocks=1,
                     groups=8)


def _init_res(key, in_ch, out_ch):
    ks = jax.random.split(key, 3)
    p = {"norm1": init_groupnorm(in_ch), "conv1": init_conv(ks[0], in_ch, out_ch),
         "norm2": init_groupnorm(out_ch), "conv2": init_conv(ks[1], out_ch, out_ch)}
    if in_ch != out_ch:
        p["skip"] = init_conv(ks[2], in_ch, out_ch, k=1)
    return p


def _apply_res(p, x, groups):
    h = apply_conv(p["conv1"], jax.nn.silu(groupnorm(p["norm1"], x, groups)))
    h = apply_conv(p["conv2"], jax.nn.silu(groupnorm(p["norm2"], h, groups)))
    return (apply_conv(p["skip"], x) if "skip" in p else x) + h


def init_vae_decoder(key, cfg: VAEConfig) -> dict:
    ks = iter(jax.random.split(key, 64))
    top = cfg.base * cfg.channel_mult[-1]
    p: dict[str, Any] = {
        "conv_in": init_conv(next(ks), cfg.z_channels, top),
        "mid_res1": _init_res(next(ks), top, top),
        "mid_qkv": init_linear(next(ks), top, 3 * top, role="attn_qkv"),
        "mid_proj": init_linear(next(ks), top, top, role="attn_out"),
        "mid_norm": init_groupnorm(top),
        "mid_res2": _init_res(next(ks), top, top),
    }
    ups = []
    cur = top
    for lvl, mult in reversed(list(enumerate(cfg.channel_mult))):
        out_ch = cfg.base * mult
        blks = [_init_res(next(ks), cur if i == 0 else out_ch, out_ch)
                for i in range(cfg.num_res_blocks + 1)]
        cur = out_ch
        up = init_conv(next(ks), cur, cur) if lvl != 0 else None
        ups.append({"res": blks, "up": up})
    p["ups"] = ups
    p["norm_out"] = init_groupnorm(cur)
    p["conv_out"] = init_conv(next(ks), cur, cfg.out_channels)
    return p


def apply_vae_decoder(p: dict, cfg: VAEConfig, z: jax.Array) -> jax.Array:
    """z: (B, h, w, 4) latent -> (B, 8h, 8w, 3) image in [-1, 1]."""
    h = apply_conv(p["conv_in"], z / cfg.scale_factor)
    h = _apply_res(p["mid_res1"], h, cfg.groups)
    # Single-head spatial self-attention at the bottleneck.
    b, hh, ww, c = h.shape
    xn = groupnorm(p["mid_norm"], h, cfg.groups).reshape(b, hh * ww, c)
    qkv = apply_linear(p["mid_qkv"], xn)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    from repro.core.qlinear import record_matmul
    record_matmul("vae_attn_scores", "activation", hh * ww, hh * ww, c,
                  count=b, act_act=True)
    record_matmul("vae_attn_pv", "activation", hh * ww, c, hh * ww,
                  count=b, act_act=True)
    att = jax.nn.softmax(
        jnp.einsum("bqc,bkc->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * c ** -0.5, -1)
    xn = jnp.einsum("bqk,bkc->bqc", att, v.astype(jnp.float32))
    h = h + apply_linear(p["mid_proj"], xn.astype(h.dtype)).reshape(
        b, hh, ww, c)
    h = _apply_res(p["mid_res2"], h, cfg.groups)
    for blk in p["ups"]:
        for r in blk["res"]:
            h = _apply_res(r, h, cfg.groups)
        if blk["up"] is not None:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = apply_conv(blk["up"], h)
    h = jax.nn.silu(groupnorm(p["norm_out"], h, cfg.groups))
    return jnp.tanh(apply_conv(p["conv_out"], h))
