"""`repro.obs` — unified telemetry: metrics registry + span tracing.

The serving stack (engines, batcher, router, fleet, KV runtime, cost
model) accepts an optional ``metrics=`` object.  With the default
``None`` every instrumentation call is skipped and behaviour is
bit-identical; pass a :class:`Telemetry` (or a bare
:class:`MetricsRegistry`) to light the layer up.

:class:`Telemetry` is the facade the wiring expects:

* bundles a :class:`MetricsRegistry` and an optional
  :class:`TraceRecorder`;
* :meth:`Telemetry.attach` subscribes to an
  :class:`~repro.engine.events.EventBus` and derives event-level
  metrics (``events_total``, ``requests_terminal_total``,
  ``queue_wait_seconds``, token/preview/preemption counters) while
  forwarding every event to the tracer;
* engines call :meth:`Telemetry.request_submitted` (submission is not
  a bus event — the bus invariant is that the first event for a rid is
  its ``Admitted``) and :meth:`Telemetry.phase` (one compute quantum,
  named after the cost-model phase key);
* delegates ``counter`` / ``gauge`` / ``histogram``, so duck-typed
  consumers (``ReplicaHealth``, ``CostModel``) work with either a
  ``Telemetry`` or a bare registry.

Attach to the FINAL bus: ``EngineRouter`` / ``FleetManager`` rebind
engine buses onto a shared one during construction, and subscriptions
live on the bus object itself.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.obs.metrics import (DEFAULT_ERROR_BUCKETS,
                               DEFAULT_TIME_BUCKETS,
                               SNAPSHOT_SCHEMA_VERSION, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.trace import Marker, Span, TraceRecorder

TERMINAL_EVENT_NAMES = ("Finished", "Cancelled", "Rejected")

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TraceRecorder", "Span", "Marker", "Telemetry",
    "DEFAULT_TIME_BUCKETS", "DEFAULT_ERROR_BUCKETS",
    "SNAPSHOT_SCHEMA_VERSION", "TERMINAL_EVENT_NAMES",
]


class Telemetry:
    """Metrics registry + optional trace recorder behind one handle."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: TraceRecorder | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.registry = registry if registry is not None \
            else MetricsRegistry(clock=clock)
        self.tracer = tracer
        # rid -> (submit ts, engine kind) — queue-wait measurement.
        self._submitted: dict[int, tuple[float, str]] = {}

    # ------------------------------------------------- registry facade
    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self.registry.counter(name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self.registry.gauge(name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=DEFAULT_TIME_BUCKETS) -> Histogram:
        return self.registry.histogram(name, help, labels, buckets)

    # ------------------------------------------------------ bus wiring
    def attach(self, bus: Any) -> "Telemetry":
        """Subscribe to the (final, post-router/fleet) event bus.  One
        subscription covers both the event-derived metrics and the
        tracer — do not additionally call ``tracer.attach``."""
        bus.subscribe(self._on_event)
        return self

    def _on_event(self, ev: Any) -> None:
        t = type(ev).__name__
        self.counter("events_total", "bus events by type",
                     labels=("type",)).inc(type=t)
        if t == "Admitted":
            mark = self._submitted.get(ev.rid)
            if mark is not None:
                self.histogram(
                    "queue_wait_seconds",
                    "submit-to-admission wait", labels=("engine",)
                ).observe(ev.ts - mark[0], engine=mark[1])
        elif t == "TokenDelta":
            self.counter("tokens_emitted_total",
                         "streamed tokens").inc()
        elif t == "PreviewLatent":
            self.counter("previews_total",
                         "progressive latent previews").inc()
        elif t == "Preempted":
            self.counter("preemptions_total",
                         "slot preemptions").inc()
        if t in TERMINAL_EVENT_NAMES:
            kind = self._submitted.get(ev.rid, (0.0, "unknown"))[1]
            self.counter(
                "requests_terminal_total",
                "retired requests by outcome",
                labels=("engine", "outcome")
            ).inc(engine=kind, outcome=t.lower())
        if self.tracer is not None:
            self.tracer.on_event(ev)

    # ------------------------------------------------- engine hooks
    def request_submitted(self, rid: int, engine: str,
                          ts: float) -> None:
        """Called by engines at ``submit()`` time (before admission
        control), so queue-wait and rejected-before-admission requests
        are both visible."""
        self._submitted[rid] = (ts, engine)
        self.counter("requests_submitted_total",
                     "submitted requests by engine",
                     labels=("engine",)).inc(engine=engine)
        if self.tracer is not None:
            self.tracer.note_submit(rid, ts, kind=engine)

    def phase(self, engine: str, phase: str, t0: float, t1: float,
              rids=(), args: dict | None = None) -> None:
        """One compute quantum: observe its duration under the
        cost-model-aligned phase name and hand the span to the
        tracer."""
        self.histogram(
            "phase_seconds", "compute quantum duration by phase "
            "(first observation per shape includes jit compile)",
            labels=("engine", "phase")
        ).observe(t1 - t0, engine=engine, phase=phase)
        if self.tracer is not None:
            self.tracer.phase(engine, phase, t0, t1, rids=rids,
                              args=args)
