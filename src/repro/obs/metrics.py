"""Labeled metrics registry: counters, gauges, fixed-bucket histograms.

The paper's entire contribution is *measurement* — per-phase kernel
breakdowns (Fig. 11), dtype time splits (Table I) — yet until now the
serving stack could only observe itself through ad-hoc benchmark
scripts and ``stats()`` dicts.  This module is the always-on half of
the observability layer (`repro.obs`): a pure-Python, zero-dependency
metrics registry every serving component can write to when telemetry
is enabled (engines take ``metrics=None`` by default and skip every
instrumentation call — the bit-identical contract).

Design points, deliberately Prometheus-shaped:

* **Three instrument kinds.**  :class:`Counter` (monotonic adds),
  :class:`Gauge` (set/inc/dec to the current value), and
  :class:`Histogram` with *fixed* upper-bound buckets chosen at
  creation — no dynamic rebucketing, so merging/diffing snapshots
  across runs is well-defined.
* **Labels.**  Every instrument declares its label names up front;
  samples are keyed by the label-value tuple.  Unknown or missing
  labels raise immediately (a typo'd label would otherwise silently
  fork a time series).
* **Injectable clock.**  The registry carries the same injectable
  clock discipline as the :class:`~repro.engine.events.EventBus`, so
  virtual-clock tests and benchmarks produce deterministic
  timestamps in snapshots.
* **Two export formats.**  :meth:`MetricsRegistry.to_prometheus`
  emits the text exposition format (``# HELP`` / ``# TYPE`` /
  cumulative ``_bucket{le=...}`` rows), and
  :meth:`MetricsRegistry.snapshot_record` /
  :meth:`MetricsRegistry.write_snapshot` emit the *same versioned
  JSON record schema* as ``benchmarks/common.py`` (schema_version 1,
  ``{bench, name, value, detail}`` entries) so metric snapshots ride
  the existing CI perf-trajectory harness (``compare.py`` diffs them
  run-over-run like any other suite).  ``benchmarks/obs_smoke.py``
  cross-validates a written snapshot against
  ``benchmarks.common.validate_record``.

Everything here is pure host Python: no jax imports, no background
threads, O(1) per instrumentation call.
"""
from __future__ import annotations

import json
import platform
import sys
import time
from typing import Callable, Iterable, Mapping

# Default histogram buckets (seconds): spans jit-compile tails down to
# sub-millisecond virtual-clock quanta.
DEFAULT_TIME_BUCKETS = (0.0001, 0.0005, 0.001, 0.005, 0.01, 0.025,
                        0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# Relative-error buckets (dimensionless): cost-model estimate-vs-actual.
DEFAULT_ERROR_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                         0.5, 1.0, 2.5)

# The JSON snapshot intentionally shares the benchmark record schema
# (benchmarks/common.py BENCH_SCHEMA_VERSION) so CI's perf-trajectory
# comparator consumes metric snapshots unchanged.
SNAPSHOT_SCHEMA_VERSION = 1


def _label_values(declared: tuple[str, ...],
                  given: Mapping[str, object]) -> tuple[str, ...]:
    if set(given) != set(declared):
        raise ValueError(
            f"labels {sorted(given)} do not match declared "
            f"{sorted(declared)}")
    return tuple(str(given[k]) for k in declared)


def _fmt(v: float) -> str:
    """Compact float formatting for exposition rows (ints stay ints)."""
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(s: str) -> str:
    return s.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _render_labels(names: tuple[str, ...], values: tuple[str, ...],
                   extra: tuple[tuple[str, str], ...] = (),
                   sep: str = ",") -> str:
    pairs = [f'{k}="{_escape(v)}"' for k, v in zip(names, values)]
    pairs += [f'{k}="{_escape(v)}"' for k, v in extra]
    return "{" + sep.join(pairs) + "}" if pairs else ""


class _Instrument:
    """Shared labeled-sample plumbing for the three instrument kinds."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._samples: dict[tuple[str, ...], float] = {}

    def _key(self, labels: Mapping[str, object]) -> tuple[str, ...]:
        return _label_values(self.labels, labels)

    def value(self, **labels) -> float:
        """Current value for one label set (0.0 if never touched)."""
        return self._samples.get(self._key(labels), 0.0)

    def samples(self) -> dict[tuple[str, ...], float]:
        """label-value tuple -> value (exposition / snapshot order)."""
        return dict(self._samples)


class Counter(_Instrument):
    """Monotonically increasing labeled counter."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc "
                             f"{amount}")
        k = self._key(labels)
        self._samples[k] = self._samples.get(k, 0.0) + amount


class Gauge(_Instrument):
    """Labeled gauge: set to the current value of something."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._samples[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = self._key(labels)
        self._samples[k] = self._samples.get(k, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)


class Histogram(_Instrument):
    """Fixed-bucket labeled histogram (cumulative on exposition).

    ``buckets`` are the finite upper bounds, strictly increasing; an
    implicit ``+Inf`` bucket tops them off.  Per label set the
    histogram keeps non-cumulative bucket counts plus ``sum`` and
    ``count`` — O(len(buckets)) memory, O(log n) per observe.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Iterable[str] = (),
                 buckets: Iterable[float] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, labels)
        bs = tuple(float(b) for b in buckets)
        if not bs or any(b2 <= b1 for b1, b2 in zip(bs, bs[1:])):
            raise ValueError(
                f"histogram {name}: buckets must be non-empty and "
                f"strictly increasing, got {bs}")
        self.bucket_bounds = bs
        # label key -> [counts per bucket incl. +Inf]
        self._buckets: dict[tuple[str, ...], list[int]] = {}
        self._sums: dict[tuple[str, ...], float] = {}

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        counts = self._buckets.get(k)
        if counts is None:
            counts = self._buckets[k] = [0] * (len(self.bucket_bounds)
                                               + 1)
            self._sums[k] = 0.0
        lo, hi = 0, len(self.bucket_bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= self.bucket_bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        counts[lo] += 1
        self._sums[k] += float(value)
        self._samples[k] = self._samples.get(k, 0.0) + 1  # count mirror

    def count(self, **labels) -> int:
        return int(self._samples.get(self._key(labels), 0))

    def sum(self, **labels) -> float:
        return self._sums.get(self._key(labels), 0.0)

    def buckets(self, **labels) -> dict[float, int]:
        """Cumulative ``upper_bound -> count`` (Prometheus semantics),
        ``+Inf`` included."""
        counts = self._buckets.get(self._key(labels),
                                   [0] * (len(self.bucket_bounds) + 1))
        out, acc = {}, 0
        for bound, c in zip(self.bucket_bounds + (float("inf"),), counts):
            acc += c
            out[bound] = acc
        return out


class MetricsRegistry:
    """Process-wide instrument registry with get-or-create semantics.

    One registry is typically shared by every engine, the KV runtime,
    the cost model, and the fleet (`repro.obs.Telemetry` bundles it
    with the optional trace recorder).  ``counter`` / ``gauge`` /
    ``histogram`` return the existing instrument when the name is
    already registered — and raise if the kind or label names
    disagree, so two call sites cannot silently fork one metric.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self._instruments: dict[str, _Instrument] = {}

    # ------------------------------------------------------ factories
    def _get(self, cls, name: str, help: str, labels: tuple[str, ...],
             **kw) -> _Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, labels, **kw)
            self._instruments[name] = inst
            return inst
        if not isinstance(inst, cls) or inst.labels != labels:
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind} "
                f"with labels {inst.labels}, requested {cls.kind} "
                f"with {labels}")
        return inst

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Iterable[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, tuple(labels),
                         buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        return self._instruments.get(name)

    def instruments(self) -> list[_Instrument]:
        return list(self._instruments.values())

    # ----------------------------------------------------- exposition
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for inst in self._instruments.values():
            if inst.help:
                lines.append(f"# HELP {inst.name} {_escape(inst.help)}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
            if isinstance(inst, Histogram):
                for key in inst._buckets:
                    labels = dict(zip(inst.labels, key))
                    for bound, c in inst.buckets(**labels).items():
                        lab = _render_labels(inst.labels, key,
                                             (("le", _fmt(bound)),))
                        lines.append(f"{inst.name}_bucket{lab} {c}")
                    lab = _render_labels(inst.labels, key)
                    lines.append(
                        f"{inst.name}_sum{lab} {_fmt(inst._sums[key])}")
                    lines.append(
                        f"{inst.name}_count{lab} "
                        f"{_fmt(inst._samples[key])}")
            else:
                for key, v in inst.samples().items():
                    lab = _render_labels(inst.labels, key)
                    lines.append(f"{inst.name}{lab} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")

    # ------------------------------------------------- JSON snapshot
    def rows(self) -> list[str]:
        """``name,value,detail`` rows — the exact printed-row format
        ``benchmarks/common.py`` parses into schema entries.  Histogram
        samples expand to ``_count`` and ``_sum`` rows (fixed buckets
        are reconstructible from the exposition format; the trajectory
        comparator only needs scalars)."""
        out: list[str] = []
        for inst in self._instruments.values():
            detail = f"{inst.kind}: {inst.help}" if inst.help \
                else inst.kind
            for key, v in inst.samples().items():
                # ';'-separated label pairs: the row's name field must
                # stay comma-free to survive parse_row's 2-split.
                lab = _render_labels(inst.labels, key, sep=";")
                if isinstance(inst, Histogram):
                    out.append(f"{inst.name}_count{lab},{_fmt(v)},"
                               f"{detail}")
                    out.append(f"{inst.name}_sum{lab},"
                               f"{_fmt(inst._sums[key])},{detail}")
                else:
                    out.append(f"{inst.name}{lab},{_fmt(v)},{detail}")
        return out

    def snapshot_record(self, suite: str = "obs",
                        bench: str = "metrics") -> dict:
        """Versioned JSON record in the ``benchmarks/common.py`` schema
        (schema_version, suite, env, ``{bench, name, value, detail}``
        entries) — what CI uploads as a ``BENCH_<suite>.json``-style
        artifact and ``compare.py`` diffs run-over-run."""
        entries = []
        for row in self.rows():
            name, value, detail = (row.split(",", 2) + [""])[:3]
            entries.append({"bench": bench, "name": name,
                            "value": value, "detail": detail})
        return {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "suite": suite,
            "env": {"python": platform.python_version(),
                    "platform": sys.platform},
            "entries": entries,
        }

    def write_snapshot(self, path: str, suite: str = "obs",
                       bench: str = "metrics") -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot_record(suite, bench), f, indent=1)
            f.write("\n")
