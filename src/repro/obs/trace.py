"""Per-request span tracing over the engine event bus.

:class:`TraceRecorder` is the second half of the observability layer:
where the :class:`~repro.obs.metrics.MetricsRegistry` aggregates, the
recorder keeps *individual* request timelines — the serving-side
analogue of the paper's Fig. 11 per-phase breakdown, reconstructed
per request instead of per benchmark run.

Span sources
------------

1. **Bus events** (:meth:`attach` subscribes to an
   :class:`~repro.engine.events.EventBus`): the recorder derives the
   lifecycle skeleton — a ``queue_wait`` span from the submit mark to
   ``Admitted``, instant markers for ``TokenDelta`` / ``Progress`` /
   ``PreviewLatent`` / ``Preempted``, and the root ``request`` span
   closed by the terminal event (``Finished`` | ``Cancelled`` |
   ``Rejected``), carrying the outcome.
2. **Engine phase marks** (:meth:`phase`, called by instrumented
   engines through ``repro.obs.Telemetry``): exact compute spans per
   scheduling quantum, named after the cost-model phase keys —
   ``clip`` / ``unet_step`` / ``vae`` / ``fused`` for diffusion,
   ``prefill`` / ``decode`` for the LM — one span on the per-engine
   track plus one per participating rid, so a request's tree shows
   exactly the quanta it rode.

Events are classified by *class name*, not ``isinstance``, so this
module stays import-light (no jax, no engine imports) and works
against any bus whose events carry ``rid`` / ``ts`` / ``seq``.

Export is Chrome trace-event JSON (``{"traceEvents": [...]}`` with
``ph: "X"`` complete spans and ``ph: "i"`` instants, microsecond
timestamps) — loadable in Perfetto / ``chrome://tracing``.  Each rid
gets its own named thread row; each engine phase stream gets a
synthetic high-numbered one.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

TERMINAL_NAMES = ("Finished", "Cancelled", "Rejected")

# Synthetic Chrome tid base for per-engine phase tracks (request rows
# use the rid itself; rids are small ints in this repo).
_ENGINE_TID_BASE = 1_000_000


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval on a request's (or an engine's) timeline."""
    name: str
    cat: str                  # engine kind ("lm"/"diffusion") or "request"
    start: float              # engine-clock seconds
    end: float
    rid: int | None           # None -> engine-track aggregate span
    args: dict | None = None

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class Marker:
    """Instant event (Chrome ``ph: "i"``)."""
    name: str
    cat: str
    ts: float
    rid: int
    args: dict | None = None


class TraceRecorder:
    """Assembles per-request span trees from bus events + phase marks.

    Pure host Python, append-only; a long-lived server would rotate
    recorders per export window (the gating smoke uses one per run).
    """

    def __init__(self):
        self.spans: list[Span] = []
        self.markers: list[Marker] = []
        # rid -> {"kind", "submit", "first", "terminal", "outcome"}
        self._req: dict[int, dict] = {}
        self._bus = None

    # ----------------------------------------------------------- wiring
    def attach(self, bus: Any) -> "TraceRecorder":
        """Subscribe to a bus (call AFTER router/fleet construction:
        those rebind engine buses onto a shared one, and a subscription
        lives on the bus object itself)."""
        bus.subscribe(self.on_event)
        self._bus = bus
        return self

    def note_submit(self, rid: int, ts: float,
                    kind: str = "request") -> None:
        """Record a submission mark — the start of the ``queue_wait``
        span and of the root ``request`` span.  Engines call this (via
        ``Telemetry.request_submitted``) because submission is not a
        bus event."""
        self._req[rid] = {"kind": kind, "submit": ts, "first": None,
                          "terminal": None, "outcome": None}

    def _state(self, rid: int) -> dict:
        return self._req.setdefault(
            rid, {"kind": "request", "submit": None, "first": None,
                  "terminal": None, "outcome": None})

    # ----------------------------------------------------------- intake
    def on_event(self, ev: Any) -> None:
        st = self._state(ev.rid)
        if st["first"] is None:
            st["first"] = ev.ts
        kind = st["kind"]
        t = type(ev).__name__
        if t == "Admitted":
            start = st["submit"] if st["submit"] is not None else ev.ts
            self.add_span("queue_wait", start, ev.ts, rid=ev.rid,
                          cat=kind, args={"slot": getattr(ev, "slot",
                                                          None)})
        elif t in TERMINAL_NAMES:
            st["terminal"], st["outcome"] = ev.ts, t.lower()
            start = (st["submit"] if st["submit"] is not None
                     else st["first"])
            self.add_span("request", start, ev.ts, rid=ev.rid, cat=kind,
                          args={"outcome": t.lower()})
        elif t == "TokenDelta":
            self.markers.append(Marker(
                "token", kind, ev.ts, ev.rid,
                {"pos": ev.pos, "token": ev.token}))
        elif t == "Progress":
            self.markers.append(Marker(
                f"progress:{ev.phase}", kind, ev.ts, ev.rid,
                {"step": ev.step, "total": ev.total}))
        elif t == "PreviewLatent":
            self.markers.append(Marker(
                "preview", kind, ev.ts, ev.rid,
                {"step": ev.step, "total": ev.total}))
        elif t == "Preempted":
            self.markers.append(Marker(
                "preempted", kind, ev.ts, ev.rid,
                {"reason": ev.reason}))

    def phase(self, cat: str, name: str, start: float, end: float,
              rids: tuple = (), args: dict | None = None) -> None:
        """One engine compute quantum: an aggregate span on the
        ``cat`` engine track plus one child span per participating
        rid (the per-request tree's phase leaves)."""
        agg = dict(args or {})
        agg["rids"] = list(rids)
        self.add_span(name, start, end, rid=None, cat=cat, args=agg)
        for rid in rids:
            self.add_span(name, start, end, rid=rid, cat=cat, args=args)
            st = self._state(rid)
            if st["kind"] == "request":
                st["kind"] = cat

    def add_span(self, name: str, start: float, end: float, *,
                 rid: int | None = None, cat: str = "engine",
                 args: dict | None = None) -> Span:
        sp = Span(name, cat, start, end, rid, args)
        self.spans.append(sp)
        return sp

    # --------------------------------------------------------- querying
    def request_spans(self, rid: int) -> list[Span]:
        return sorted((s for s in self.spans if s.rid == rid),
                      key=lambda s: (s.start, s.end))

    def request_tree(self, rid: int) -> tuple[Span | None, list[Span]]:
        """(root ``request`` span or None, children sorted by start)."""
        spans = self.request_spans(rid)
        roots = [s for s in spans if s.name == "request"]
        children = [s for s in spans if s.name != "request"]
        return (roots[0] if roots else None), children

    def rids(self) -> list[int]:
        return sorted(self._req)

    def outcome(self, rid: int) -> str | None:
        return self._req.get(rid, {}).get("outcome")

    # ----------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable)."""
        evs: list[dict] = []
        engine_tids: dict[str, int] = {}

        def tid_for(span_cat: str, rid: int | None) -> int:
            if rid is not None:
                return int(rid)
            tid = engine_tids.get(span_cat)
            if tid is None:
                tid = _ENGINE_TID_BASE + len(engine_tids)
                engine_tids[span_cat] = tid
                evs.append({"name": "thread_name", "ph": "M", "pid": 0,
                            "tid": tid,
                            "args": {"name": f"engine:{span_cat}"}})
            return tid

        for rid in self.rids():
            evs.append({"name": "thread_name", "ph": "M", "pid": 0,
                        "tid": int(rid),
                        "args": {"name": f"rid {rid} "
                                 f"({self._req[rid]['kind']})"}})
        for s in self.spans:
            evs.append({"name": s.name, "cat": s.cat, "ph": "X",
                        "ts": s.start * 1e6, "dur": s.dur * 1e6,
                        "pid": 0, "tid": tid_for(s.cat, s.rid),
                        "args": s.args or {}})
        for m in self.markers:
            evs.append({"name": m.name, "cat": m.cat, "ph": "i",
                        "s": "t", "ts": m.ts * 1e6, "pid": 0,
                        "tid": int(m.rid), "args": m.args or {}})
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
            f.write("\n")
