"""AdamW with global-norm clipping and optional Q8_0-quantized moments.

Quantized moments apply the paper's technique to the optimizer state
(beyond-paper): both Adam moments are stored as Q8_0 blocks (int8 +
fp16/32 scale per 32 values), cutting optimizer memory from 8 bytes/
param to ~2.1.  Moments are dequantized, updated, and requantized each
step.  Two guards make this stable (the naive version diverges because
a v-block's small entries quantize to exactly 0, unleashing m/eps):
the second moment is stored in sqrt-domain (halving its dynamic range,
as in 8-bit Adam practice), and the per-element update is clipped to
±10 (inactive in normal operation).

Optimizer state inherits the parameter sharding (ZeRO: with FSDP'd
params the moments are sharded identically, so no device holds a full
copy).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core import quant
from repro.core.quant import Q8_0Tensor


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def _quantizable(p) -> bool:
    """Quantize moments in the weight's own shape (blocks along the
    last axis) so they inherit the weight's sharding rules — a
    flattened layout forces resharding/replication in SPMD."""
    return p.ndim >= 1 and p.shape[-1] % 32 == 0


def _q(x: jax.Array) -> Q8_0Tensor:
    return quant.quantize_q8_0(x.astype(jnp.float32))


def _dq(t: Q8_0Tensor, shape, size) -> jax.Array:
    del shape, size
    return quant.dequantize_q8_0(t)


def _zeros_like_moment(p, quantized: bool):
    if quantized and _quantizable(p):
        return _q(jnp.zeros(p.shape, jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


def init_adam(params: Any, cfg: TrainConfig) -> AdamState:
    trainable = jax.tree.map(lambda p: p, params)
    mk = lambda p: _zeros_like_moment(p, cfg.quantized_moments)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(mk, trainable),
                     v=jax.tree.map(mk, trainable))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(grads: Any, state: AdamState, params: Any,
                cfg: TrainConfig) -> tuple[Any, AdamState]:
    step = state.step + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    qz = cfg.quantized_moments

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        tq = qz and _quantizable(p)
        if tq:
            m = _dq(m, p.shape, p.size)
            v = jnp.square(_dq(v, p.shape, p.size))  # sqrt-domain storage
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + 1e-8)
        upd_ = jnp.clip(upd_, -10.0, 10.0)
        new_p = (p.astype(jnp.float32)
                 - cfg.lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
                 ).astype(p.dtype)
        if tq:
            m, v = _q(m), _q(jnp.sqrt(v))
        return new_p, m, v

    is_q = lambda x: isinstance(x, Q8_0Tensor)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m, is_leaf=is_q)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_q)
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_p, AdamState(step=step, m=new_m, v=new_v)
