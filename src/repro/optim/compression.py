"""Int8 error-feedback gradient compression (cross-pod all-reduce).

The pod axis crosses the slowest links (inter-pod ICI/DCN), so the
gradient all-reduce there is the dominant collective at multi-pod
scale.  We compress gradients to Q8_0-style int8 blocks before the
cross-pod exchange and keep the quantization residual locally (error
feedback), adding it back into the next step's gradient — the standard
convergence-preserving scheme (1-bit Adam lineage).

Under GSPMD the all-reduce is implicit, so the compression is exposed
as a (compress -> decompress) sandwich applied to the *pod-crossing*
gradient tensor inside the train step, with the residual carried in the
optimizer loop.  ``compression_ratio`` reports the byte saving for the
collective-roofline model.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant


class CompressionState(NamedTuple):
    residual: Any  # same structure as grads, f32


def init_compression(grads_like: Any) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_decompress(g: jax.Array, r: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Quantize (g + residual) to int8 blocks; return (dequantized
    value that crosses the wire, new residual)."""
    x = g.astype(jnp.float32) + r
    flat = x.reshape(-1)
    pad = (-flat.size) % 32
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q = quant.quantize_q8_0(flat)
    deq = quant.dequantize_q8_0(q)[: x.size].reshape(x.shape)
    return deq.astype(g.dtype), x - deq


def apply_compression(grads: Any, state: CompressionState
                      ) -> tuple[Any, CompressionState]:
    pairs = jax.tree.map(compress_decompress, grads, state.residual)
    new_g = jax.tree.map(lambda pr: pr[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda pr: pr[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, CompressionState(residual=new_r)


def compression_ratio() -> float:
    """bf16 (16 bit) -> Q8_0 (8.5 bit) on the wire."""
    return 16.0 / 8.5
