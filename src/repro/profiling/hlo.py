"""HLO-text parsing: collective-communication byte accounting.

``compiled.cost_analysis()`` does not expose collective bytes, so we
parse the (post-SPMD-partitioning) HLO of the per-device executable:
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op contributes its wire bytes.

Wire-byte model (ring algorithms, per participating chip):
  * all-reduce:        2 * s * (n-1)/n      (reduce-scatter + all-gather)
  * all-gather:        s * (n-1)/n          (s = full gathered size)
  * reduce-scatter:    s * (n-1)/n          (s = full input size)
  * all-to-all:        s * (n-1)/n
  * collective-permute: s                   (point-to-point)
where n is the replica-group size parsed from the op.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _op_result_bytes(line: str) -> float:
    """Sum the byte size of the op's result (handles tuple results)."""
    lhs = line.split(" = ", 1)[1] if " = " in line else line
    # Result type(s) precede the op name; grab shapes before the first
    # opcode occurrence.
    for c in _COLLECTIVES + ("fusion", "custom-call"):
        idx = lhs.find(c + "(")
        if idx < 0:
            idx = lhs.find(c + "-start(")
        if idx >= 0:
            lhs = lhs[:idx]
            break
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(lhs))


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return total_devices


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes_per_chip: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=dict)
    op_count: int = 0


def collective_bytes(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats(by_kind=defaultdict(float))
    seen_done = set()
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        kind = None
        for c in _COLLECTIVES:
            # Match op invocations incl. async -start variants; skip
            # -done (size counted at start).
            if re.search(rf"\s{c}(-start)?\(", ls):
                kind = c
                break
        if kind is None or f" {kind}-done(" in ls:
            continue
        size = _op_result_bytes(ls)
        n = max(_group_size(ls, total_devices), 1)
        if kind == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = size
        stats.wire_bytes_per_chip += wire
        stats.by_kind[kind] += wire
        stats.op_count += 1
    stats.by_kind = dict(stats.by_kind)
    return stats


def count_op(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"\s{opcode}\(", hlo_text))
