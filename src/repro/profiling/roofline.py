"""Three-term roofline analysis from a compiled dry-run artifact.

TPU v5e per-chip constants (the TARGET hardware; this container is
CPU-only so terms are derived from compiled HLO, not measured):

  * peak bf16 compute: 197 TFLOP/s
  * HBM bandwidth:     819 GB/s
  * ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per step, per chip — the executable is the per-device
SPMD program, so its cost_analysis numbers are already per chip):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes / HBM_bw
  collective = wire_bytes_per_chip / link_bw

The bound is max(terms); roofline fraction for the report is
``useful_model_flops_per_chip / (bound_seconds * peak)`` — i.e. what
fraction of peak the chip would sustain on *useful* model FLOPs if the
step ran at the derived bound.
"""
from __future__ import annotations

import dataclasses
import json

from repro.profiling import hlo as hlo_mod

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
LINK_BW = 50e9               # bytes / s / link

TERMS = ("compute", "memory", "collective")


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    collective_ops: int
    model_flops_total: float   # 6*N*D (or 6*N_active*D) per step
    compute_s: float
    memory_s: float
    collective_s: float
    memory_analysis: dict | None = None

    @property
    def bound(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (total) — remat/redundancy waste."""
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-model-FLOP fraction of peak at the derived bound."""
        if self.bound_s <= 0:
            return 0.0
        per_chip_useful = self.model_flops_total / self.chips
        return per_chip_useful / (self.bound_s * PEAK_FLOPS)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(bound=self.bound, bound_s=self.bound_s,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops_total: float,
            memory_analysis: dict | None = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = hlo_mod.collective_bytes(hlo_text, chips)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        wire_bytes_per_chip=coll.wire_bytes_per_chip,
        collective_ops=coll.op_count,
        model_flops_total=model_flops_total,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll.wire_bytes_per_chip / LINK_BW,
        memory_analysis=memory_analysis,
    )


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """6·N·D for training; 2·N·D for inference forward."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_params_active * tokens


def save_json(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1)


# ------------------------------------------------------------------
# Kernel-substituted memory terms (§Perf iterations A2 / C3).
#
# The CPU dry-run lowers quantized matmuls and attention through plain
# XLA, which materializes (a) dequantized weight copies and (b) S^2
# attention logits in HBM.  The in-repo Pallas kernels (q8_matmul,
# q3k_matmul, flash_attention — oracle-validated in tests/) keep both
# in VMEM by construction (BlockSpec tiling), so the TPU deployment's
# memory term excludes that traffic.  These helpers compute the
# substituted terms analytically; EXPERIMENTS.md reports both numbers.

def fused_dequant_memory_s(*, packed_weight_bytes_per_chip: float,
                           kv_bytes_per_chip: float = 0.0,
                           act_bytes_per_chip: float = 0.0) -> float:
    """Ideal streaming memory term: every byte crosses HBM once,
    in packed form (the Pallas fused-dequant contract)."""
    total = (packed_weight_bytes_per_chip + kv_bytes_per_chip
             + act_bytes_per_chip)
    return total / HBM_BW


def flash_logits_bytes(*, batch: int, heads: int, sq: int, sk: int,
                       layers: int, chips: int,
                       passes: float = 6.0) -> float:
    """HBM bytes the XLA softmax-attention path spends on the (Sq,Sk)
    logits tensor (write + softmax sub/exp/div reads + P reread),
    which flash attention keeps in VMEM.  Sharded over chips."""
    return passes * batch * heads * sq * sk * 4.0 * layers / chips
