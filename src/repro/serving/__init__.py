"""LM serving: paged KV-cache runtime + continuous-batching scheduler."""
from repro.serving.kvcache import (NULL_BLOCK, BlockAllocator, PagedKVRuntime,
                                   PrefixCache)
from repro.serving.scheduler import ContinuousBatcher, Request

__all__ = [
    "NULL_BLOCK", "BlockAllocator", "PagedKVRuntime", "PrefixCache",
    "ContinuousBatcher", "Request",
]
