"""Block-paged KV-cache runtime for continuous-batching LM serving.

The paper's companion LLM study makes KV-cache movement the dominant
serving cost; the host's job (the paper's CPU role) is to keep the
accelerator's cache footprint at the *logical* working set, not a
preallocated high-water mark.  This module is that host-side runtime —
a vLLM-style paged allocator scaled to this repo:

* **Physical pool** — every self-attention layer owns a
  ``(num_blocks, Hkv, block_size, hd)`` pool (see
  ``models.attention.init_paged_kv_cache``).  Block 0 is the reserved
  *null block*: idle batch rows point their table at it so the fixed-
  shape decode step can scatter harmlessly.
* **:class:`BlockAllocator`** — a free-list with per-block refcounts;
  refcount > 1 means the block is shared read-only between slots
  and/or the prefix cache.
* **:class:`PrefixCache`** — hash-chained full prompt blocks retained
  at retirement; a later request with the same prompt prefix adopts
  the blocks (refcount bump) and skips recomputing their KV.  Entries
  are LRU-evicted under pool pressure, so retention never blocks
  admission.
* **:class:`PagedKVRuntime`** — per-slot position vectors and block
  tables, admission (``admit``), retirement (``release``), and a
  copy-on-write guard (``ensure_writable``) so a slot never mutates a
  block another holder can still read.
* **Cross-attention pool (opt-in)** — encoder-decoder serving
  (``repro.engine.asr_engine``) stores each request's precomputed
  encoder KV in a *second* refcounted block pool (``cross_len > 0``):
  per-slot ``cross_tables`` over a dedicated :class:`BlockAllocator`,
  with its own hash-chained :class:`PrefixCache` keyed on per-frame
  audio fingerprints.  Unlike prompt-prefix sharing, audio adoption is
  **all-or-nothing** (``admit_cross``): the encoder is non-causal, so
  a partial frame prefix has no reusable KV — either the whole chain
  matches (every block adopted read-only, the encode skipped entirely)
  or the slot gets fresh blocks and encodes from scratch.  Cross
  blocks are read-only after the encode (``publish_cross`` donates the
  chain to the prefix cache), so they need no CoW guard.

The runtime is pure host Python over integer state — device arrays
only appear through the ``copy_block`` callback a scheduler installs
for CoW — which keeps it unit-testable without a model.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Callable, Sequence

NULL_BLOCK = 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class BlockAllocator:
    """Free-list allocator with refcounts over ``num_blocks`` physical
    blocks.  Block 0 (the null block) is never handed out."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need at least one allocatable block")
        self.num_blocks = num_blocks
        self._free: deque[int] = deque(range(1, num_blocks))
        # Mirror of _free for O(1) membership: the free list and the
        # refcounted live set must stay disjoint (is_free / the
        # runtime's check_consistency assert on it).
        self._free_set: set[int] = set(self._free)
        self._refs: dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def refcount(self, bid: int) -> int:
        return self._refs.get(bid, 0)

    def is_free(self, bid: int) -> bool:
        """True iff ``bid`` currently sits in the free list."""
        return bid in self._free_set

    def alloc(self, n: int) -> list[int] | None:
        """Atomically allocate ``n`` blocks (refcount 1), or None."""
        if n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for bid in out:
            assert bid not in self._refs, \
                f"block {bid} was simultaneously free and refcounted"
            self._free_set.discard(bid)
            self._refs[bid] = 1
        return out

    def share(self, bid: int) -> None:
        """Add a reader to an allocated block."""
        if bid == NULL_BLOCK:
            return
        if bid not in self._refs:
            raise ValueError(f"share of unallocated block {bid}")
        assert not self.is_free(bid), \
            f"share of block {bid} that is on the free list"
        self._refs[bid] += 1

    def release(self, bid: int) -> bool:
        """Drop one reference; True when the block returned to the
        free list."""
        if bid == NULL_BLOCK:
            return False
        n = self._refs.get(bid)
        if n is None:
            raise ValueError(f"release of unallocated block {bid}")
        if n > 1:
            self._refs[bid] = n - 1
            return False
        del self._refs[bid]
        assert bid not in self._free_set, f"double-free of block {bid}"
        self._free.append(bid)
        self._free_set.add(bid)
        return True


class PrefixCache:
    """Hash-chained prompt prefix -> physical block index.

    Keys chain the parent hash with the block's token tuple, so a hit
    for block *i* implies blocks ``0..i-1`` matched too.  The cache
    holds one reference per entry; ``evict_lru`` drops the
    least-recently-used entry to relieve pool pressure."""

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.alloc = allocator
        self.block_size = block_size
        self._entries: OrderedDict[int, int] = OrderedDict()  # key -> bid
        self.hits = 0          # blocks adopted by admissions
        self.insertions = 0

    @staticmethod
    def _chain(parent: int, toks: tuple) -> int:
        return hash((parent, toks))

    def _keys(self, prompt: Sequence[int], n_blocks: int) -> list[int]:
        keys, parent = [], 0
        for i in range(n_blocks):
            toks = tuple(prompt[i * self.block_size:
                                (i + 1) * self.block_size])
            parent = self._chain(parent, toks)
            keys.append(parent)
        return keys

    def match(self, prompt: Sequence[int], max_blocks: int) -> list[int]:
        """Longest chain of cached full blocks (<= max_blocks); bumps
        each matched block's refcount (caller owns the references)."""
        out = []
        for key in self._keys(prompt, max_blocks):
            bid = self._entries.get(key)
            if bid is None:
                break
            self._entries.move_to_end(key)
            self.alloc.share(bid)
            out.append(bid)
        self.hits += len(out)
        return out

    def insert(self, prompt: Sequence[int], table: Sequence[int]) -> None:
        """Retain the prompt's *full* blocks (immutable after prefill:
        decode writes land strictly beyond them)."""
        n_full = len(prompt) // self.block_size
        for key, bid in zip(self._keys(prompt, n_full), table):
            if key in self._entries:
                self._entries.move_to_end(key)
                continue
            self.alloc.share(bid)
            self._entries[key] = bid
            self.insertions += 1

    def evict_lru(self) -> bool:
        if not self._entries:
            return False
        _, bid = self._entries.popitem(last=False)
        self.alloc.release(bid)
        return True

    def __len__(self) -> int:
        return len(self._entries)


class PagedKVRuntime:
    """Per-slot positions + block tables over a shared physical pool.

    ``max_len`` is the *per-request* logical capacity (positions
    ``0..max_len-1``); the pool defaults to exactly one block span per
    slot plus the null block, with ``extra_blocks`` headroom for
    prefix retention.  All state is host-side; the device cache pytree
    is built separately with matching ``(num_blocks, block_size)``.
    """

    def __init__(self, slots: int, max_len: int, block_size: int = 16, *,
                 num_blocks: int | None = None, extra_blocks: int = 0,
                 prefix_share: bool = False,
                 cross_len: int = 0, cross_block_size: int | None = None,
                 cross_extra_blocks: int = 0,
                 cross_prefix_share: bool = False,
                 copy_block: Callable[[int, int], None] | None = None,
                 metrics=None):
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.blocks_per_slot = cdiv(max_len, block_size)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else slots * self.blocks_per_slot + 1
                           + extra_blocks)
        self.alloc = BlockAllocator(self.num_blocks)
        self.prefix: PrefixCache | None = (
            PrefixCache(self.alloc, block_size) if prefix_share else None)
        self.copy_block = copy_block      # device CoW hook (src, dst)
        self.pos = [0] * slots            # tokens cached per slot
        self.tables = [[NULL_BLOCK] * self.blocks_per_slot
                       for _ in range(slots)]
        self._owned = [0] * slots         # blocks in use (incl. shared)
        self.cow_copies = 0
        # Optional cross-attention pool: one fixed-length span of
        # encoder KV per slot, refcounted + prefix-shareable like the
        # self-attention pool but adopted all-or-nothing.
        self.cross_len = cross_len
        self.cross_block_size = cross_block_size or block_size
        self.cross_blocks_per_slot = (
            cdiv(cross_len, self.cross_block_size) if cross_len else 0)
        self.cross_num_blocks = (
            slots * self.cross_blocks_per_slot + 1 + cross_extra_blocks
            if cross_len else 0)
        self.cross_alloc: BlockAllocator | None = (
            BlockAllocator(self.cross_num_blocks) if cross_len else None)
        self.cross_prefix: PrefixCache | None = (
            PrefixCache(self.cross_alloc, self.cross_block_size)
            if cross_len and cross_prefix_share else None)
        self.cross_tables = [[NULL_BLOCK] * self.cross_blocks_per_slot
                             for _ in range(slots)]
        self._cross_owned = [0] * slots
        # True while the slot's cross blocks were adopted from the
        # prefix cache (read-only: the engine must not encode into
        # them).
        self.cross_adopted = [False] * slots
        self.metrics = metrics            # None -> no instrumentation
        self._obs_pool()

    # ---------------------------------------------------- observability
    def _obs_pool(self) -> None:
        """Refresh pool gauges (allocated/free blocks, CoW copies,
        prefix-cache size and hits) after any state change; the gauges
        mirror the host-side counters exactly, so snapshot values and
        ``stats()``-style asserts never diverge."""
        m = self.metrics
        if m is None:
            return
        g = m.gauge("kv_pool_blocks", "physical KV blocks by state "
                    "(null block excluded)", labels=("state",))
        g.set(self.allocated_blocks, state="allocated")
        g.set(self.alloc.num_free, state="free")
        m.gauge("kv_cow_copies",
                "cumulative copy-on-write block copies").set(
            self.cow_copies)
        if self.prefix is not None:
            m.gauge("kv_prefix_entries",
                    "retained prefix-cache blocks").set(len(self.prefix))
            m.gauge("kv_prefix_hits",
                    "cumulative prefix blocks adopted").set(
                self.prefix.hits)
        if self.cross_alloc is not None:
            gc = m.gauge("kv_cross_pool_blocks",
                         "cross-attention (encoder KV) blocks by state "
                         "(null block excluded)", labels=("state",))
            gc.set(self.allocated_cross_blocks, state="allocated")
            gc.set(self.cross_alloc.num_free, state="free")
            if self.cross_prefix is not None:
                m.gauge("kv_cross_prefix_entries",
                        "retained audio-prefix blocks").set(
                    len(self.cross_prefix))
                m.gauge("kv_cross_prefix_hits",
                        "cumulative audio blocks adopted").set(
                    self.cross_prefix.hits)

    # ------------------------------------------------------- invariants
    def check_consistency(self) -> None:
        """Assert the free list and the live block tables are disjoint:
        a block must never be simultaneously free and reachable from a
        slot's table (the refcount/free ordering bug class).  Checking
        every live table entry against ``is_free`` proves the
        disjointness in one direction, which is the whole property.
        Called after every admit/CoW/release; cheap at serving scale
        (O(slots * blocks_per_slot))."""
        for slot in range(self.slots):
            for bid in self.tables[slot][:self._owned[slot]]:
                assert bid != NULL_BLOCK, \
                    f"slot {slot} owns the null block"
                assert not self.alloc.is_free(bid), \
                    f"block {bid} is in slot {slot}'s table AND free"
                assert self.alloc.refcount(bid) >= 1, \
                    f"block {bid} is in slot {slot}'s table unrefcounted"
            for bid in self.cross_tables[slot][:self._cross_owned[slot]]:
                assert bid != NULL_BLOCK, \
                    f"slot {slot} owns the null cross block"
                assert not self.cross_alloc.is_free(bid), \
                    f"cross block {bid} is in slot {slot}'s table AND free"
                assert self.cross_alloc.refcount(bid) >= 1, \
                    f"cross block {bid} in slot {slot}'s table unrefcounted"

    # -------------------------------------------------------- admission
    def _alloc_with_eviction(self, n: int) -> list[int] | None:
        while self.alloc.num_free < n:
            if self.prefix is None or not self.prefix.evict_lru():
                return None
        return self.alloc.alloc(n)

    def admit(self, slot: int, prompt: Sequence[int],
              max_new: int) -> int | None:
        """Reserve blocks for ``prompt`` + ``max_new`` generated tokens
        and return the number of prompt tokens whose KV was adopted
        from the prefix cache (0 without a hit).  None if the pool
        cannot cover the request right now (caller requeues)."""
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already admitted")
        total = min(len(prompt) + max_new - 1, self.max_len)
        need = cdiv(total, self.block_size)
        shared: list[int] = []
        if self.prefix is not None:
            # Full blocks only, and never the whole prompt: the last
            # prompt token must be recomputed to produce first logits.
            max_shared = min(need, (len(prompt) - 1) // self.block_size)
            shared = self.prefix.match(prompt, max_shared)
        fresh = self._alloc_with_eviction(need - len(shared))
        if fresh is None:
            for bid in shared:
                self.alloc.release(bid)
            if self.prefix is not None:  # adoption didn't happen: keep
                self.prefix.hits -= len(shared)   # the stat honest
            return None
        table = shared + fresh
        self.tables[slot] = (table
                             + [NULL_BLOCK] * (self.blocks_per_slot
                                               - len(table)))
        self._owned[slot] = len(table)
        n_reused = len(shared) * self.block_size
        self.pos[slot] = n_reused
        self.check_consistency()
        self._obs_pool()
        return n_reused

    # ------------------------------------------------------ write guard
    def ensure_writable(self, slot: int, pos: int) -> int:
        """Copy-on-write guard: the block holding ``pos`` must have
        refcount 1 before the device step scatters into it.  Under
        full-block-only sharing this never triggers (shared blocks sit
        strictly below every write position) but the runtime stays
        correct under any future sharing policy.  Returns the physical
        block id the write will land in."""
        bi = pos // self.block_size
        bid = self.tables[slot][bi]
        if self.alloc.refcount(bid) <= 1:
            return bid
        fresh = self._alloc_with_eviction(1)
        if fresh is None:
            raise RuntimeError("pool exhausted during copy-on-write")
        if self.copy_block is not None:
            self.copy_block(bid, fresh[0])
        self.alloc.release(bid)
        self.tables[slot][bi] = fresh[0]
        self.cow_copies += 1
        self.check_consistency()
        self._obs_pool()
        return fresh[0]

    # --------------------------------------------------------- rollback
    def truncate(self, slot: int, new_pos: int) -> None:
        """Roll the slot back to ``new_pos`` cached positions.

        This is the whole of speculative-decoding rollback: a rejected
        proposal tail is discarded by rewinding the position watermark —
        no block frees, no device copies.  Blocks were reserved for the
        request's full horizon at :meth:`admit`, positions at or beyond
        ``pos`` are unreachable (attention masks against the per-slot
        position), and the next accepted token simply overwrites the
        stale rows.  The one safety property worth asserting is that the
        discarded positions only ever lived in exclusively-owned blocks:
        the verify launch's write window must have gone through
        :meth:`ensure_writable` first, so a CoW-shared prefix block can
        never have been dirtied by a speculation that then failed."""
        pos = self.pos[slot]
        if not 0 <= new_pos <= pos:
            raise ValueError(
                f"truncate(slot={slot}) to {new_pos} outside [0, {pos}]")
        if new_pos < pos:
            for bi in range(new_pos // self.block_size,
                            cdiv(pos, self.block_size)):
                bid = self.tables[slot][bi]
                assert self.alloc.refcount(bid) == 1, \
                    (f"slot {slot} rolling back positions in shared "
                     f"block {bid} (refcount "
                     f"{self.alloc.refcount(bid)}) — a speculative "
                     "write skipped ensure_writable")
        self.pos[slot] = new_pos
        self.check_consistency()

    # ------------------------------------------------------- retirement
    def release(self, slot: int, prompt: Sequence[int] | None = None
                ) -> None:
        """Free the slot's blocks.  With prefix sharing on and the
        retiring request's ``prompt`` given, its full prompt blocks are
        retained in the prefix cache before the slot drops them."""
        n = self._owned[slot]
        table = self.tables[slot][:n]
        if self.prefix is not None and prompt is not None:
            self.prefix.insert(prompt, table)
        for bid in table:
            self.alloc.release(bid)
        self.tables[slot] = [NULL_BLOCK] * self.blocks_per_slot
        self._owned[slot] = 0
        self.pos[slot] = 0
        self.check_consistency()
        self._obs_pool()

    # ---------------------------------------------- cross-attention pool
    def _require_cross(self) -> BlockAllocator:
        if self.cross_alloc is None:
            raise RuntimeError("runtime built without a cross pool "
                               "(pass cross_len > 0)")
        return self.cross_alloc

    def _cross_padded(self, keys: Sequence[int]) -> list[int]:
        """Pad the per-frame fingerprint chain to whole blocks with a
        fixed sentinel, so match/insert/publish all hash identical
        chains even when ``cross_len % cross_block_size != 0``."""
        want = self.cross_blocks_per_slot * self.cross_block_size
        return list(keys) + [0] * (want - len(keys))

    def _alloc_cross_with_eviction(self, n: int) -> list[int] | None:
        alloc = self._require_cross()
        while alloc.num_free < n:
            if self.cross_prefix is None or not self.cross_prefix.evict_lru():
                return None
        return alloc.alloc(n)

    def admit_cross(self, slot: int, keys: Sequence[int]) -> bool | None:
        """Reserve the slot's encoder-KV span.  ``keys`` are per-frame
        content fingerprints (len == ``cross_len``).  Adoption is
        all-or-nothing — the encoder is non-causal, so a partial frame
        prefix has no reusable KV:

        * ``True`` — the *whole* chain was in the audio prefix cache;
          every block adopted read-only, the caller skips the encode.
        * ``False`` — fresh blocks allocated; the caller must encode.
        * ``None`` — pool pressure (caller requeues; nothing held).
        """
        alloc = self._require_cross()
        if self._cross_owned[slot]:
            raise RuntimeError(f"slot {slot} already holds cross blocks")
        if len(keys) != self.cross_len:
            raise ValueError(f"need {self.cross_len} frame keys, "
                             f"got {len(keys)}")
        need = self.cross_blocks_per_slot
        padded = self._cross_padded(keys)
        if self.cross_prefix is not None:
            shared = self.cross_prefix.match(padded, need)
            if len(shared) == need:          # full chain: adopt as-is
                self.cross_tables[slot] = list(shared)
                self._cross_owned[slot] = need
                self.cross_adopted[slot] = True
                self.check_consistency()
                self._obs_pool()
                return True
            for bid in shared:               # partial: useless, roll back
                alloc.release(bid)
            self.cross_prefix.hits -= len(shared)
        fresh = self._alloc_cross_with_eviction(need)
        if fresh is None:
            return None
        self.cross_tables[slot] = list(fresh)
        self._cross_owned[slot] = need
        self.cross_adopted[slot] = False
        self.check_consistency()
        self._obs_pool()
        return False

    def publish_cross(self, slot: int, keys: Sequence[int]) -> None:
        """Donate the slot's (fully encoded) cross chain to the audio
        prefix cache so later requests with the same audio adopt it.
        No-op without sharing or for an adopted (already published)
        chain; blocks stay read-only from here on."""
        if self.cross_prefix is None or self.cross_adopted[slot]:
            return
        table = self.cross_tables[slot][:self._cross_owned[slot]]
        self.cross_prefix.insert(self._cross_padded(keys), table)
        self._obs_pool()

    def release_cross(self, slot: int) -> None:
        """Drop the slot's cross-block references (published chains
        survive in the prefix cache, which holds its own reference)."""
        alloc = self._require_cross()
        for bid in self.cross_tables[slot][:self._cross_owned[slot]]:
            alloc.release(bid)
        self.cross_tables[slot] = [NULL_BLOCK] * self.cross_blocks_per_slot
        self._cross_owned[slot] = 0
        self.cross_adopted[slot] = False
        self.check_consistency()
        self._obs_pool()

    # ------------------------------------------------------------ stats
    @property
    def allocated_blocks(self) -> int:
        return self.num_blocks - 1 - self.alloc.num_free

    @property
    def allocated_cross_blocks(self) -> int:
        if self.cross_alloc is None:
            return 0
        return self.cross_num_blocks - 1 - self.cross_alloc.num_free

    def free_block_ids(self) -> list[int]:
        """Snapshot of currently free physical blocks (tests poison
        these to prove no stale reads)."""
        return list(self.alloc._free)

    def free_cross_block_ids(self) -> list[int]:
        """Free cross-pool blocks (same poisoning contract as
        :meth:`free_block_ids`, for the encoder-KV pool)."""
        return list(self._require_cross()._free)
