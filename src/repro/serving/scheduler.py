"""Continuous-batching LM serving scheduler (slot-based, vLLM-lite).

Implements the shared :class:`repro.engine.api.Engine` protocol
(``submit()`` / ``step()`` / ``run()``) — the LM counterpart of
``repro.engine.DiffusionEngine``, so one host loop can drive either
workload.

Production serving keeps the decode batch full: finished requests leave
their slot, queued requests are admitted into free slots mid-flight,
and the jitted decode step always runs at the fixed batch shape (no
recompilation).  Mechanics:

* a fixed pool of B slots over a shared fixed-capacity cache (the
  decode cache is batched, so per-slot state is just the row index);
* one shared scalar position (the cache high-water mark) for all
  slots — per-slot position vectors are a ROADMAP open item;
* admission copies the prompt in teacher-forced decode steps (simple;
  real deployments chunk-prefill — noted);
* EOS / max-length retirement frees the slot.

Known simplification: the cache position is a *shared* high-water
mark, so a request admitted into a freed slot mid-flight attends to
the previous occupant's stale KV prefix (and recurrent states are not
reset).  First-wave requests are exact; later waves are a throughput
demo, not bit-exact decoding.  Per-slot position vectors / cache
offsets are a ROADMAP open item.

This module is deliberately jit-boundary-clean: the scheduler is Python
(host-side request plumbing — the paper's "host" role), the step is one
compiled function.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import init_cache, lm_decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Prompt feed cursor, owned by the scheduler.  A declared field
    # (not injected at admission) so copied/replayed requests have it.
    _cursor: int = dataclasses.field(default=0, repr=False)


def make_batched_decode(cfg: ModelConfig):
    """Greedy decode step at the fixed slot-batch shape.

    All slots share one scalar position (the cache high-water mark):
    the cache is written at that position for every row, and rows
    whose slot is empty decode garbage that is never emitted.  This is
    the CPU-scale simplification — requests admitted into a freed slot
    attend to the previous occupant's prefix (see the module
    docstring); true per-slot position vectors are future work.
    """
    def step(params, tokens, pos, cache):
        logits, cache = lm_decode_step(params, cfg, tokens, pos, cache)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache
    return jax.jit(step, donate_argnums=(3,))


class ContinuousBatcher:
    def __init__(self, params: Any, cfg: ModelConfig, *, slots: int,
                 max_len: int, enc_embeds=None,
                 decode_fn: Callable | None = None,
                 quantized_kv: bool = False):
        self.params = params
        self.cfg = cfg
        self.slots: list[Request | None] = [None] * slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.cache = init_cache(params, cfg, slots, max_len,
                                quantized_kv=quantized_kv,
                                enc_embeds=enc_embeds)
        self.step_fn = decode_fn or make_batched_decode(cfg)
        self.pos = 0                    # shared high-water position
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.finished: list[Request] = []

    # ------------------------------------------------------------ API
    @staticmethod
    def required_len(n_requests: int, slots: int, prompt_len: int,
                     max_new: int) -> int:
        """Cache length covering every admission wave.

        The cache position is a shared high-water mark, so requests
        beyond the slot count are served in waves and the cache must
        cover all of them — an undersized ``max_len`` silently retires
        late requests with truncated (possibly empty) output.
        """
        waves = -(-n_requests // slots)
        return waves * (prompt_len + max_new) + 1

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.popleft()
                req._cursor = 0          # reset on (re-)admission
                self.slots[i] = req
                self.tokens = self.tokens.at[i, 0].set(req.prompt[0])

    def step(self) -> int:
        """One decode step across all slots; returns #active slots."""
        self._admit()
        active = sum(s is not None for s in self.slots)
        if active == 0:
            return 0
        nxt, self.cache = self.step_fn(self.params, self.tokens,
                                       jnp.int32(self.pos), self.cache)
        self.pos += 1
        nxt_host = jax.device_get(nxt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req._cursor += 1
            if req._cursor < len(req.prompt):
                tok = req.prompt[req._cursor]       # teacher-forced
            else:
                tok = int(nxt_host[i])
                req.out.append(tok)
            self.tokens = self.tokens.at[i, 0].set(tok)
            over = len(req.out) >= req.max_new
            hit_eos = req.eos is not None and req.out \
                and req.out[-1] == req.eos
            if over or hit_eos or self.pos >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None     # slot freed -> next admit fills
        return active

    def run(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return list(self.finished)    # snapshot: later runs keep appending
