"""Continuous-batching LM serving scheduler over the paged KV runtime.

Implements the shared :class:`repro.engine.api.Engine` protocol
(``submit()`` / ``step()`` / ``run()``) — the LM counterpart of
``repro.engine.DiffusionEngine``, so one host loop can drive either
workload.

The scheduler is the paper's "host" role: Python request plumbing
around two compiled programs, with all cache bookkeeping delegated to
:class:`repro.serving.kvcache.PagedKVRuntime`:

* **Paged cache, per-slot state** — every slot carries its own
  position vector entry and block-table row over a shared physical
  block pool; a recycled slot starts at position 0 in freshly
  allocated blocks, so *every* wave is bit-exact (the old shared
  high-water mark, where second-wave requests attended to the previous
  occupant's stale KV, is gone).
* **Chunked prefill** — admission feeds the prompt in fixed-size
  chunks at batch 1 (``models.transformer.lm_prefill_chunk``), writing
  straight into the slot's blocks.  By default
  (``fused_prefill=True``) each chunk is ONE fused paged
  flash-prefill program per layer (``kernels/flash_prefill.py``:
  causal within the chunk, position-masked against history, KV
  written in-kernel) — admission costs one kernel launch per chunk
  instead of one decode-step launch per token
  (``prefill_launches``).  Quantized-KV pools are fused too: the Q8_0
  sibling kernel requantizes the chunk in-kernel and updates the
  quant + scale pools in place.  Recurrent/hybrid and enc-dec models
  automatically fall back to the jitted ``lax.scan`` of the decode
  step, which stays bit-identical to solo decode and serves as the
  fused path's test oracle (at dequant-reference tolerance for
  quantized pools).  Either way,
  prompt ingestion costs *prefill quanta*, not decode steps at the
  full slot batch; the final chunk's logits emit the first generated
  token.
* **Decode quanta** — one jitted step at the fixed slot-batch shape
  (no recompilation); idle rows point their block-table entry at the
  null block and are never emitted.
* **Prefix reuse (optional)** — with ``prefix_share=True`` (pure
  attention decoders only), retiring requests donate their full prompt
  blocks to a hash-chained prefix cache; a later request with the same
  prefix adopts the blocks read-only and skips their prefill chunks.
* **Feasibility admission control (opt-in)** — with a
  :class:`repro.engine.costmodel.CostModel` attached
  (``cost_model=...``), ``submit()`` rejects a request whose estimated
  service time (prefill chunks + decode tokens, per-phase EWMA costs
  keyed on model dims / fused-vs-scan prefill / quantized KV) exceeds
  its ``deadline_ms`` budget — terminal
  :class:`~repro.engine.events.Rejected`, no slot or KV block ever
  allocated — and each ``step()`` sweeps queued requests whose
  deadline expired or became infeasible while they waited.  The
  scheduler feeds the model online: every prefill/decode quantum's
  duration (measured on the event clock; the first quantum of each
  compiled shape is skipped — it pays jit tracing) refines the EWMA.
  ``preempt_over_budget`` then evicts decodes *predicted* to overrun
  (now + remaining tokens x decode cost past the deadline) instead of
  waiting for the overrun.  With ``cost_model=None`` (the default)
  every path is bit-identical to the model-free scheduler.
* **Fairness + SLO-aware admission** — the wait queue admits
  round-robin across request ``group`` ids instead of strict FIFO, so
  one chatty tenant cannot head-of-line-block the rest; *within* a
  group the pop is earliest-deadline-first (``deadline_ms``, ties by
  ``priority`` then arrival — with no deadlines this is exactly the
  old FIFO).  Requests whose deadline has already expired sort behind
  every still-feasible request: the scheduler serves whom it can
  still help.
* **Streaming lifecycle** — ``submit()`` returns a
  :class:`repro.engine.events.RequestHandle`; the scheduler emits
  ``Admitted`` at slot assignment, ``Progress(phase="prefill")`` per
  prompt chunk, ``TokenDelta`` per generated token (``pos`` strictly
  increasing), and ``Finished`` at retirement on its
  :class:`~repro.engine.events.EventBus`.
* **Cancellation** — ``cancel(rid)`` removes a queued request or
  evicts a running one mid-prefill/mid-decode, releasing every KV
  block back to the pool (``check_consistency()`` guards the
  free-list/table disjointness) and emitting a terminal
  ``Cancelled``.
* **Preemption** — ``preempt(rid)`` (or, with
  ``preempt_over_budget=True``, automatic eviction of decodes that
  outlived their deadline while feasible requests wait) releases the
  slot's blocks and requeues the request; on re-admission its prompt
  *plus generated tokens* are re-ingested through chunked prefill —
  bit-exact on the decode-step-scan path (``fused_prefill=False``),
  agreement-gated on the fused path — and emission resumes where it
  left off (``Progress(phase="resume")``, never a second
  ``Admitted``).

``step()`` runs exactly one scheduling quantum — prefill-prioritized:
pending prompt chunks first, otherwise one batched decode step — and
records it in ``last_quantum`` / the ``prefill_quanta`` /
``decode_quanta`` counters; per-request counts land on
``Request.prefill_steps`` / ``Request.decode_steps``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine import events as ev
from repro.engine.config import EngineConfig, UNSET, resolve
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.models.transformer import (cache_slot_merge, cache_slot_reset,
                                      cache_slot_view, init_cache,
                                      lm_decode_step, lm_prefill_chunk,
                                      lm_verify_chunk, prefill_path)
from repro.serving.kvcache import PagedKVRuntime, cdiv

DEFAULT_BLOCK = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    eos: int | None = None
    group: int = 0                # fairness class (tenant / priority bin)
    deadline_ms: float | None = None  # SLO budget from submission (EDF)
    priority: int = 0             # higher wins EDF ties within a group
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    prefill_steps: int = 0        # prefill quanta this request consumed
    decode_steps: int = 0        # decode quanta that emitted for it
    # Speculative-decoding accounting (0 unless the engine runs with a
    # SpecDecodeConfig): draft tokens offered to the verifier vs. draft
    # tokens the target accepted.  Declared fields so replayed /
    # preempted copies keep their history.
    proposed: int = 0
    accepted: int = 0
    # Prompt tokens cached so far (prefix reuse + prefill chunks).
    # Observability/compat only — the scheduler's _pending list owns
    # the feed.  A declared field (not injected at admission) so
    # copied/replayed requests have it.
    _cursor: int = dataclasses.field(default=0, repr=False)
    # Scheduler-internal SLO/resume state (declared fields so replayed
    # or preempted copies survive dataclasses.replace):
    _seq: int = dataclasses.field(default=0, repr=False)    # arrival
    _deadline: float = dataclasses.field(default=float("inf"),
                                         repr=False)        # abs clock
    # Tokens to (re-)ingest at admission: the prompt for a fresh
    # request, prompt + generated-so-far after a preemption.
    _feed: list[int] = dataclasses.field(default_factory=list,
                                         repr=False)


def make_paged_decode(cfg: ModelConfig):
    """Greedy decode step at the fixed slot-batch shape: per-slot
    positions + block tables, paged KV scatter/gather."""
    def step(params, tokens, positions, block_tables, cache):
        logits, cache = lm_decode_step(params, cfg, tokens, positions,
                                       cache, block_tables=block_tables)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache
    return jax.jit(step, donate_argnums=(4,))


def make_prefill_chunk(cfg: ModelConfig, *, fused: bool = True):
    """Batch-1 chunked prefill for one slot: carve the slot's recurrent
    rows out of the batched cache, run the chunk (paged KV writes land
    via the slot's block-table row), and fold the rows back.  With
    ``fused=True`` (and an eligible model) the chunk is ONE fused
    paged flash-prefill program per layer; otherwise it is the
    reference decode-step scan.  Compiled once per distinct chunk
    length."""
    def prefill(params, tokens, pos0, slot, block_row, cache):
        local = cache_slot_view(cache, slot)
        logits, local = lm_prefill_chunk(params, cfg, tokens, pos0, local,
                                         block_tables=block_row,
                                         fused=fused)
        cache = cache_slot_merge(cache, local, slot)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache
    return jax.jit(prefill, donate_argnums=(5,))


def make_verify_chunk(cfg: ModelConfig, *, fused: bool = True):
    """Batch-1 verification launch for speculative decoding: run the
    whole ``[pending token, draft proposal...]`` chunk through one
    prefill-path program (fused when eligible, decode-step scan
    otherwise — the same dispatch as :func:`make_prefill_chunk`) and
    return the target's greedy token at EVERY chunk position ``(1, C)``
    plus the updated cache.  The chunk's KV lands in the slot's blocks
    exactly like prefill; a rejected tail is rolled back afterwards by
    ``PagedKVRuntime.truncate`` (position rewind, no device work).
    Compiled once per distinct proposal length."""
    def verify(params, tokens, pos0, slot, block_row, cache):
        local = cache_slot_view(cache, slot)
        logits, local = lm_verify_chunk(params, cfg, tokens, pos0, local,
                                        block_tables=block_row,
                                        fused=fused)
        cache = cache_slot_merge(cache, local, slot)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache
    return jax.jit(verify, donate_argnums=(5,))


def _make_slot_reset():
    return jax.jit(cache_slot_reset, donate_argnums=(0,))


def _make_copy_block():
    """Device hook for the runtime's copy-on-write guard."""
    def copy(cache, src, dst):
        def cp(x):
            return x.at[:, dst].set(x[:, src])
        return [c._replace(kv=jax.tree.map(cp, c.kv)) for c in cache]
    return jax.jit(copy, donate_argnums=(0,))


class ContinuousBatcher(ev.EventStreamMixin):
    """``max_len`` is the *per-request* logical capacity (size it with
    :meth:`required_len`); ``decode_fn`` overrides the compiled decode
    quantum and must follow :func:`make_paged_decode`'s signature —
    ``(params, tokens (S,1), positions (S,), block_tables (S,MB),
    cache) -> (next_tokens (S,), cache)`` (the paged runtime changed
    this from the old ``(params, tokens, pos, cache)`` contract).

    ``edf=False`` disables the within-group earliest-deadline-first
    pop (pure arrival order — the FIFO baseline the serving benchmark
    compares deadline hit-rates against).  ``preempt_over_budget=True``
    lets admission evict a decoding request that has outlived its
    deadline when feasible requests are waiting.  ``clock`` is the
    SLO/event timebase (injectable for deterministic tests and
    virtual-time benchmarks).

    Construction is config-first since PR 10: pass
    ``config=EngineConfig(lm=LMEngineConfig(...))`` — the loose kwargs
    remain accepted as a deprecation shim (explicit kwargs win over the
    matching config field, gated bit-identical in tests) but new knobs
    such as ``config.lm.spec_decode`` exist only on the config."""

    def __init__(self, params: Any, cfg: ModelConfig, *,
                 config: EngineConfig | None = None,
                 slots: int = UNSET, max_len: int = UNSET,
                 enc_embeds=UNSET,
                 decode_fn: Callable | None = UNSET,
                 quantized_kv: bool = UNSET,
                 weight_quant: str | None = UNSET,
                 block_size: int = UNSET,
                 prefill_chunk: int = UNSET,
                 prefix_share: bool = UNSET,
                 extra_blocks: int = UNSET,
                 fused_prefill: bool = UNSET,
                 bus: ev.EventBus | None = UNSET,
                 clock: Callable[[], float] = UNSET,
                 edf: bool = UNSET,
                 preempt_over_budget: bool = UNSET,
                 cost_model=UNSET, metrics=UNSET):
        self.config, lmc = resolve(config, "lm", dict(
            slots=slots, max_len=max_len, enc_embeds=enc_embeds,
            decode_fn=decode_fn, quantized_kv=quantized_kv,
            weight_quant=weight_quant, block_size=block_size,
            prefill_chunk=prefill_chunk, prefix_share=prefix_share,
            extra_blocks=extra_blocks, fused_prefill=fused_prefill,
            bus=bus, clock=clock, edf=edf,
            preempt_over_budget=preempt_over_budget,
            cost_model=cost_model, metrics=metrics))
        if lmc.max_len is None:
            raise ValueError("max_len is required (pass max_len= or "
                             "config.lm.max_len; size it with "
                             "required_len())")
        (slots, max_len, enc_embeds, decode_fn, quantized_kv,
         block_size, prefill_chunk, prefix_share, extra_blocks,
         fused_prefill, preempt_over_budget) = (
            lmc.slots, lmc.max_len, lmc.enc_embeds, lmc.decode_fn,
            lmc.quantized_kv, lmc.block_size, lmc.prefill_chunk,
            lmc.prefix_share, lmc.extra_blocks, lmc.fused_prefill,
            lmc.preempt_over_budget)
        weight_quant = self.config.weight_quant
        bus, clock, edf = (self.config.bus, self.config.clock,
                           self.config.edf)
        cost_model, metrics = self.config.cost_model, self.config.metrics
        if prefix_share and (set(cfg.block_pattern) != {"attn"}
                             or cfg.is_enc_dec):
            raise ValueError(
                "prefix_share needs a pure-attention decoder: recurrent "
                "states and encoder KV cannot be adopted from a cache")
        if weight_quant is not None:
            # Opt-in quantized-weight decode: linear weights move to
            # blocked storage (Q8_0/Q4_0/Q3_K per the policy) and every
            # matmul routes through core.qlinear onto the quantized
            # kernels (Pallas on TPU, dequant reference on CPU).
            params = quantize_params(params, get_policy(weight_quant))
        self.weight_quant = weight_quant
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.metrics = metrics          # None -> no instrumentation
        self.runtime = PagedKVRuntime(
            slots, max_len, block_size, prefix_share=prefix_share,
            extra_blocks=extra_blocks
            + (slots * cdiv(max_len, block_size) if prefix_share else 0),
            metrics=metrics)
        self.runtime.copy_block = self._copy_block
        self.cache = init_cache(params, cfg, slots, max_len,
                                quantized_kv=quantized_kv,
                                enc_embeds=enc_embeds,
                                block_size=block_size,
                                num_blocks=self.runtime.num_blocks)
        self.step_fn = decode_fn or make_paged_decode(cfg)
        # Fused prefill quietly downgrades to the decode-step scan when
        # the model cannot take it (recurrent/hybrid, enc-dec).  The
        # same prefill_path() call backs lm_prefill_chunk's dispatch,
        # so launch accounting and cost-model keys always describe the
        # path actually executed.
        self.fused_prefill = prefill_path(
            cfg, quantized_kv=quantized_kv,
            fused=fused_prefill) == "fused"
        self._prefill_raw = make_prefill_chunk(cfg,
                                               fused=self.fused_prefill)
        self._reset_fn = _make_slot_reset()
        self._copy_fn = _make_copy_block()
        self.slots: list[Request | None] = [None] * slots
        self._pending: list[list[int]] = [[] for _ in range(slots)]
        self._next_tok = np.zeros(slots, np.int32)
        self.finished: list[Request] = []
        # Wait queue: one list per fairness group, admitted round-robin
        # across groups, EDF-popped within a group.
        self._groups: "OrderedDict[int, list[Request]]" = OrderedDict()
        self._rr: deque[int] = deque()
        self.bus = bus if bus is not None else ev.EventBus(clock)
        self.edf = edf
        self.preempt_over_budget = preempt_over_budget
        self.quantized_kv = quantized_kv
        self.cost_model = cost_model    # None -> no admission control
        self.rejections = 0
        # Compiled shapes whose first (trace-paying) quantum already
        # ran — cost-model observations skip that first quantum.
        self._cm_warm: set = set()
        self.preemptions = 0
        self._subseq = 0
        self.prefill_quanta = 0
        self.decode_quanta = 0
        # Admission cost in per-token kernel launches: the decode-step
        # scan runs one step program per prompt token, the fused path
        # one program per chunk (the acceptance metric for fused
        # admission is strictly fewer launches on the same workload).
        self.prefill_launches = 0
        self.last_quantum: tuple[str, int] | None = None
        # Decode cost in *target-model* launches: +1 per batched decode
        # quantum, +1 per fused verification launch (or +chunk-length on
        # the scan path).  Speculation's acceptance metric is strictly
        # fewer target launches than 1-launch-per-token on the same
        # workload; draft launches are accounted separately.
        self.decode_launches = 0
        self.draft_launches = 0
        self.spec_rounds = 0        # spec quanta executed
        self.spec_verifies = 0      # per-slot verification launches
        self.spec_proposed = 0      # draft tokens offered to the target
        self.spec_accepted = 0      # draft tokens the target accepted
        self.spec = lmc.spec_decode
        self._draft_pending: list[list[int]] = [[] for _ in range(slots)]
        if self.spec is not None:
            self._init_spec(slots, max_len, block_size)

    def _init_spec(self, slots: int, max_len: int,
                   block_size: int) -> None:
        """Build the draft model's private serving state: its own paged
        runtime + block pool (draft KV never cohabits the target pool,
        so rollback can never dirty a CoW-shared prefix block) and its
        own compiled decode/prefill programs at the slot-batch shape."""
        sp = self.spec
        dcfg = sp.draft_cfg
        if set(self.cfg.block_pattern) != {"attn"} or self.cfg.is_enc_dec:
            raise ValueError(
                "spec_decode needs a pure-attention decoder-only target:"
                " rollback is a position truncation, which recurrent or"
                " encoder-fed state cannot honour")
        if set(dcfg.block_pattern) != {"attn"} or dcfg.is_enc_dec:
            raise ValueError(
                "spec_decode draft must be a pure-attention decoder-only"
                " model (draft KV rolls back by position truncation too)")
        if dcfg.vocab_size != self.cfg.vocab_size:
            raise ValueError(
                f"draft vocab {dcfg.vocab_size} != target vocab "
                f"{self.cfg.vocab_size}: proposals would not be token-"
                "compatible")
        if sp.k < 1:
            raise ValueError(f"spec_decode.k must be >= 1, got {sp.k}")
        self.draft_params = sp.draft_params
        self.draft_runtime = PagedKVRuntime(slots, max_len, block_size)
        self.draft_cache = init_cache(sp.draft_params, dcfg, slots,
                                      max_len, block_size=block_size,
                                      num_blocks=self.draft_runtime
                                      .num_blocks)
        self._draft_step = sp.draft_step_fn or make_paged_decode(dcfg)
        self._draft_fused = prefill_path(
            dcfg, fused=sp.draft_fused_prefill) == "fused"
        self._draft_prefill_raw = make_prefill_chunk(
            dcfg, fused=self._draft_fused)
        self._verify_raw = make_verify_chunk(self.cfg,
                                             fused=self.fused_prefill)

    # ------------------------------------------------------------ sizing
    @staticmethod
    def required_len(n_requests: int, slots: int, prompt_len: int,
                     max_new: int) -> int:
        """Exact per-request logical capacity.

        Positions are per-slot and blocks are recycled through the
        pool, so capacity no longer scales with admission waves: a
        request writes positions ``0 .. prompt_len + max_new - 2``
        (the final token is emitted, never cached).  ``n_requests`` /
        ``slots`` only exist for signature compatibility with the old
        shared high-water sizing, which multiplied by the wave count.
        """
        del n_requests, slots
        return prompt_len + max_new - 1

    # --------------------------------------------------------------- API
    def submit(self, req: Request) -> ev.RequestHandle:
        need = len(req.prompt) + req.max_new - 1
        if need > self.max_len:
            # Reject instead of silently truncating: sizing is exact
            # now, so an over-budget request is a misconfiguration
            # (required_len gives the capacity for this request).
            raise ValueError(
                f"prompt {len(req.prompt)} + max_new {req.max_new} needs "
                f"capacity {need} > per-request max_len={self.max_len}")
        # Fail fast on rid reuse (same check as DiffusionEngine / the
        # router): a duplicate would otherwise crash later inside
        # step() against the bus lifecycle invariants, after the slot
        # and blocks were already taken.
        if (self.bus.terminal(req.rid) is not None
                or self.bus.admitted(req.rid)
                or any(r.rid == req.rid
                       for q in self._groups.values() for r in q)):
            raise ValueError(f"duplicate rid {req.rid}")
        req._seq = self._subseq
        self._subseq += 1
        req._deadline = (float("inf") if req.deadline_ms is None
                         else self.bus.clock() + req.deadline_ms / 1e3)
        if not req._feed:
            req._feed = list(req.prompt)
        if self.metrics is not None:
            # Before admission control: rejected-at-submit requests are
            # telemetry-visible too (submission is not a bus event).
            self.metrics.request_submitted(req.rid, "lm",
                                           self.bus.clock())
        if self.cost_model is not None and req.deadline_ms is not None:
            est = self.cost_model.estimate_lm(self, req)
            if est is not None:
                # Queueing-delay-aware admission: charge the expected
                # wait behind already-queued work, so a feasible-in-
                # isolation request behind a deep queue is rejected up
                # front instead of expiring in the sweep later.
                est += self.cost_model.queue_wait(self)
            budget = req.deadline_ms / 1e3
            if est is not None and est > budget:
                self.rejections += 1
                self.bus.emit(ev.Rejected, req.rid, estimated_s=est,
                              budget_s=budget, reason="infeasible")
                return self.handle(req.rid)
        self._enqueue(req)
        return self.handle(req.rid)

    def _enqueue(self, req: Request) -> None:
        if req.group not in self._groups:
            self._groups[req.group] = []
            self._rr.append(req.group)
        self._groups[req.group].append(req)

    @property
    def queue_len(self) -> int:
        return sum(len(q) for q in self._groups.values())

    def has_work(self) -> bool:
        return bool(self.queue_len) or any(s is not None
                                           for s in self.slots)

    def next_deadline(self) -> float:
        """Earliest SLO deadline over queued + running requests (+inf
        if none declare one) — the router's multiplex key."""
        cands = [r._deadline for q in self._groups.values() for r in q]
        cands += [r._deadline for r in self.slots if r is not None]
        return min(cands, default=float("inf"))

    def next_slack(self) -> float:
        """Minimum estimated *slack* — deadline minus now minus the
        estimated (remaining) service time — over queued + running
        requests; +inf when none declares a deadline.  The router's
        multiplex key when cost models are attached; requests the
        model cannot price yet fall back to raw deadline ordering
        (estimate 0)."""
        cm = self.cost_model
        now = self.bus.clock()
        best = float("inf")
        for q in self._groups.values():
            for r in q:
                if r._deadline == float("inf"):
                    continue
                est = cm.estimate_lm(self, r) if cm else None
                best = min(best, r._deadline - now - (est or 0.0))
        for i, r in enumerate(self.slots):
            if r is None or r._deadline == float("inf"):
                continue
            est = cm.remaining_lm(self, i) if cm else None
            best = min(best, r._deadline - now - (est or 0.0))
        return best

    # ------------------------------------------- feasibility admission
    def _infeasible(self, req: Request, now: float) -> tuple[bool, Any]:
        """(hopeless, estimate): the deadline already expired, or the
        cost model predicts the request cannot finish in time even if
        served immediately.  Only called with a cost model attached."""
        if req._deadline == float("inf"):
            return False, None
        est = self.cost_model.estimate_lm(self, req)
        if req._deadline < now:
            return True, est
        return (est is not None and now + est > req._deadline), est

    def _reject(self, req: Request, est, now: float) -> None:
        self.rejections += 1
        self.bus.emit(ev.Rejected, req.rid, estimated_s=est or 0.0,
                      budget_s=req._deadline - now,
                      reason="expired" if req._deadline < now
                      else "infeasible")

    def _sweep_infeasible(self) -> None:
        """Cost-model housekeeping, once per ``step()``: queued
        requests whose deadline expired — or can provably no longer be
        met — go straight to terminal ``Rejected`` instead of sorting
        behind feasible work while occupying queue memory forever."""
        now = self.bus.clock()
        for q in self._groups.values():
            keep = []
            for r in q:
                hopeless, est = self._infeasible(r, now)
                if hopeless:
                    self._reject(r, est, now)
                else:
                    keep.append(r)
            q[:] = keep

    def _edf_key(self, req: Request) -> tuple:
        """EDF pop order within a fairness group.  Requests whose
        deadline already expired sort *behind* every still-feasible
        request (serve whom you can still help — and keep a preempted
        over-budget request from instantly reclaiming its slot);
        within a feasibility class: deadline, then priority (higher
        first), then arrival."""
        if not self.edf:
            return (req._seq,)
        expired = req._deadline < self.bus.clock()
        return (expired, req._deadline, -req.priority, req._seq)

    def _pop_round_robin(self) -> Request | None:
        while self._rr:
            gid = self._rr[0]
            if not self._groups[gid]:
                self._rr.popleft()      # drop drained groups: state
                del self._groups[gid]   # stays O(live groups), not
                continue                # O(groups ever seen)
            self._rr.rotate(-1)
            q = self._groups[gid]
            best = min(range(len(q)), key=lambda i: self._edf_key(q[i]))
            return q.pop(best)
        return None

    def _requeue_front(self, req: Request) -> None:
        self._groups[req.group].insert(0, req)
        # Undo the rotation so the group keeps its turn.
        self._rr.rotate(1)

    def _copy_block(self, src: int, dst: int) -> None:
        self.cache = self._copy_fn(self.cache, jnp.int32(src),
                                   jnp.int32(dst))

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is not None or not self.queue_len:
                continue
            while True:
                req = self._pop_round_robin()
                if req is None or self.cost_model is None:
                    break
                # Pop-time feasibility guard: a request that became
                # hopeless after the step's sweep (e.g. a preempted
                # over-budget decode requeued this quantum) must not
                # reclaim a slot it can no longer use.
                now = self.bus.clock()
                hopeless, est = self._infeasible(req, now)
                if not hopeless:
                    break
                self._reject(req, est, now)
            if req is None:
                break
            remaining = req.max_new - len(req.out)
            reused = self.runtime.admit(i, req._feed, remaining)
            if reused is None:          # pool pressure: try again later
                self._requeue_front(req)
                break
            self.slots[i] = req
            req._cursor = reused        # feed tokens already cached
            self._pending[i] = list(req._feed[reused:])
            self.cache = self._reset_fn(self.cache, jnp.int32(i))
            if self.spec is not None:
                # Draft pool mirrors the slot's horizon; sized to cover
                # every slot fully and shares with nobody (no prefix
                # cache), so admission can never fail here.
                dre = self.draft_runtime.admit(i, req._feed, remaining)
                assert dre == 0, "draft pool has no prefix cache"
                self._draft_pending[i] = list(req._feed)
            if self.bus.admitted(req.rid):   # back from preemption
                self.bus.emit(ev.Progress, req.rid, phase="resume",
                              step=len(req.out), total=req.max_new)
            else:
                self.bus.emit(ev.Admitted, req.rid, slot=i)

    def _maybe_preempt(self) -> None:
        """With ``preempt_over_budget``: if feasible requests wait and
        no slot is free, evict the most-over-budget *decoding* request
        back to the queue.  Without a cost model the victim test is
        after-the-fact (its deadline already expired); with one it is
        *predictive* — now + remaining tokens x decode cost lands past
        the deadline — so the slot is reclaimed before the doomed
        decode burns the rest of its budget (the victim is then
        rejected at its next pop rather than thrashing the slot).
        At most one eviction per quantum bounds churn.  Requires EDF
        admission: under the pure-FIFO pop the evicted victim
        (earliest arrival) would win the very next pop and reclaim its
        slot, starving the feasible waiter while re-prefilling its
        whole feed each cycle."""
        if not self.preempt_over_budget or not self.edf \
                or not self.queue_len:
            return
        if any(s is None for s in self.slots):
            return
        now = self.bus.clock()
        if self.cost_model is None:
            feasible_waiter = any(r._deadline >= now
                                  for q in self._groups.values()
                                  for r in q)
        else:
            feasible_waiter = any(not self._infeasible(r, now)[0]
                                  for q in self._groups.values()
                                  for r in q)
        if not feasible_waiter:
            return
        victims = []
        for i, r in enumerate(self.slots):
            if r is None or self._pending[i] or self._draft_pending[i] \
                    or r._deadline == float("inf"):
                continue
            est = (self.cost_model.remaining_lm(self, i)
                   if self.cost_model is not None else None)
            # Predicted miss margin; falls back to the after-the-fact
            # overrun when the model cannot price the decode yet.
            miss = now + (est or 0.0) - r._deadline
            if miss > 0:
                victims.append((miss, i))
        if victims:
            _, i = max(victims)
            self._preempt_slot(i, "deadline-overrun")

    def _preempt_slot(self, i: int, reason: str) -> None:
        req = self.slots[i]
        cached = req._feed[:self.runtime.pos[i]]
        self.runtime.release(
            i, cached if self.runtime.prefix is not None else None)
        self.slots[i] = None
        self._pending[i] = []
        self._release_draft(i)
        # Resume by re-ingesting prompt + everything generated so far:
        # the chunked-prefill path is bit-identical to decode, so the
        # continuation matches an uninterrupted run.
        req._feed = list(req.prompt) + list(req.out)
        self.preemptions += 1
        self.bus.emit(ev.Preempted, req.rid, reason=reason)
        self._enqueue(req)

    def preempt(self, rid: int, reason: str = "explicit") -> bool:
        """Evict a running request back to the wait queue (blocks
        released, resume via prefill); True if ``rid`` held a slot."""
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self._preempt_slot(i, reason)
                return True
        return False

    # ------------------------------------------- fleet migration hooks
    def evacuate(self, reason: str = "evacuate") -> list[Request]:
        """Drain hook for fleet migration: preempt every running
        request (KV blocks released, ``Preempted`` emitted, feed reset
        to prompt + generated-so-far) and pop every queued one; returns
        them in arrival order with no terminal events, so a surviving
        replica can ``adopt()`` them.  Resume via chunked re-prefill of
        the feed is bit-exact on the decode-step-scan path and
        agreement-gated on the fused path — exactly the PR 4 preemption
        contract, now across engine instances."""
        for i, r in enumerate(self.slots):
            if r is not None:
                self._preempt_slot(i, reason)
        out = [r for q in self._groups.values() for r in q]
        self._groups.clear()
        self._rr.clear()
        out.sort(key=lambda r: r._seq)
        return out

    def adopt(self, req: Request) -> ev.RequestHandle:
        """Admit a request evacuated from another engine on the same
        shared bus.  Unlike ``submit()`` this skips the duplicate-rid
        guard (the rid's prior admission legitimately lives on the
        bus) and submit-time feasibility rejection (the request was
        already admitted once; the per-step queue sweep still
        applies), and it keeps the original absolute deadline
        (``req._deadline``) instead of restarting the budget.  The
        feed is reset to prompt + generated-so-far, so admission
        re-prefills exactly the state the dead replica held; an
        already-admitted rid re-enters via ``Progress(phase="resume")``
        (the normal ``_admit`` path checks the shared bus), never a
        second ``Admitted``."""
        need = len(req.prompt) + req.max_new - 1
        if need > self.max_len:
            raise ValueError(
                f"adopted rid {req.rid} needs capacity {need} > "
                f"per-request max_len={self.max_len}")
        req._feed = list(req.prompt) + list(req.out)
        req._seq = self._subseq
        self._subseq += 1
        self._enqueue(req)
        return self.handle(req.rid)

    def cancel(self, rid: int) -> bool:
        """Abort a request wherever it is — wait queue, mid-prefill,
        or mid-decode.  A running request's slot and every KV block it
        holds return to the pool immediately (the next quantum's
        admission can reuse them); emits terminal ``Cancelled``."""
        for gid, q in self._groups.items():
            for r in q:
                if r.rid == rid:
                    q.remove(r)
                    self.bus.emit(ev.Cancelled, rid)
                    return True
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                self.runtime.release(i)   # no prefix donation: blocks
                self.slots[i] = None      # may be half-written
                self._pending[i] = []
                self._release_draft(i)
                self.runtime.check_consistency()
                self.bus.emit(ev.Cancelled, rid)
                return True
        return False

    def _release_draft(self, i: int) -> None:
        """Return the slot's draft-pool blocks (speculation only)."""
        if self.spec is not None:
            self.draft_runtime.release(i)
            self._draft_pending[i] = []

    # ------------------------------------------------------- scheduling
    def step(self) -> int:
        """One scheduling quantum (prefill-prioritized); returns the
        number of requests progressed."""
        if self.cost_model is not None and self.queue_len:
            self._sweep_infeasible()
        self._maybe_preempt()
        self._admit()
        self._obs_sched()
        for i, req in enumerate(self.slots):
            if req is not None and (self._pending[i]
                                    or self._draft_pending[i]):
                return self._prefill_quantum(i)
        if self.spec is not None:
            return self._spec_quantum()
        return self._decode_quantum()

    def _obs_quantum(self, kind: str, t0: float, out, rids: list,
                     args: dict | None = None) -> None:
        """Phase telemetry mark (histogram + trace span).  Unlike the
        cost-model ``_observe_quantum`` this never skips first-trace
        quanta — phase counts must reconcile exactly with the
        ``prefill_quanta``/``decode_quanta`` step counters, so first
        observations simply include compile time."""
        if self.metrics is None:
            return
        jax.block_until_ready(out)
        self.metrics.phase("lm", kind, t0, self.bus.clock(),
                           rids=rids, args=args)

    def _obs_sched(self) -> None:
        if self.metrics is None:
            return
        self.metrics.gauge(
            "engine_queue_depth", "queued requests by engine",
            labels=("engine",)).set(self.queue_len, engine="lm")
        self.metrics.gauge(
            "lm_slots_active", "occupied decode slots").set(
            sum(1 for s in self.slots if s is not None))

    def _observe_quantum(self, key: tuple, shape: tuple,
                         t0: float, out) -> None:
        """Feed one measured quantum duration into the cost model.
        The first quantum of each compiled ``shape`` is skipped (it
        pays jit tracing, which would poison the steady-state EWMA);
        ``out`` is blocked on so async dispatch cannot under-report."""
        if self.cost_model is None:
            return
        if shape not in self._cm_warm:
            self._cm_warm.add(shape)
            return
        jax.block_until_ready(out)
        self.cost_model.observe(key, self.bus.clock() - t0)

    def _draft_ingest(self, i: int):
        """One draft-model prefill chunk (speculation only).  The draft
        keeps a full private copy of the slot's feed — prefix reuse
        never skips draft chunks, its pool has no prefix cache — so it
        rides the slot's prefill quanta until caught up."""
        chunk = self._draft_pending[i][:self.prefill_chunk]
        del self._draft_pending[i][:len(chunk)]
        dpos = self.draft_runtime.pos[i]
        nxt, self.draft_cache = self._draft_prefill_raw(
            self.draft_params,
            jnp.asarray([chunk], jnp.int32),
            jnp.full((1,), dpos, jnp.int32),
            jnp.int32(i),
            jnp.asarray([self.draft_runtime.tables[i]], jnp.int32),
            self.draft_cache)
        self.draft_runtime.pos[i] = dpos + len(chunk)
        self.draft_launches += 1 if self._draft_fused else len(chunk)
        return nxt

    def _prefill_quantum(self, i: int) -> int:
        if not self._pending[i]:
            # Target feed done but the draft is still catching up (a
            # prefix hit skipped target chunks the draft must ingest).
            t0 = self.bus.clock()
            req = self.slots[i]
            out = self._draft_ingest(i)
            self.prefill_quanta += 1
            self.last_quantum = ("draft-prefill", 1)
            self._obs_quantum("draft-prefill", t0, out, [req.rid],
                              args={"slot": i})
            return 1
        t0 = self.bus.clock()
        req = self.slots[i]
        chunk = self._pending[i][:self.prefill_chunk]
        del self._pending[i][:len(chunk)]
        pos = self.runtime.pos[i]
        bs = self.runtime.block_size
        for bi in range(pos // bs, cdiv(pos + len(chunk), bs)):
            self.runtime.ensure_writable(i, bi * bs)
        nxt, self.cache = self._prefill_raw(
            self.params,
            jnp.asarray([chunk], jnp.int32),
            jnp.full((1,), pos, jnp.int32),
            jnp.int32(i),
            jnp.asarray([self.runtime.tables[i]], jnp.int32),
            self.cache)
        self.runtime.pos[i] = pos + len(chunk)
        req._cursor += len(chunk)
        req.prefill_steps += 1
        self.prefill_quanta += 1
        self.prefill_launches += 1 if self.fused_prefill else len(chunk)
        self.last_quantum = ("prefill", 1)
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.lm_keys(self)[0],
                                  ("prefill", len(chunk)), t0, nxt)
        self._obs_quantum("prefill", t0, nxt, [req.rid],
                          args={"tokens": len(chunk), "slot": i,
                                "fused": self.fused_prefill,
                                "quantized_kv": self.quantized_kv,
                                "weight_quant": self.weight_quant})
        self.bus.emit(ev.Progress, req.rid, phase="prefill",
                      step=req._cursor, total=len(req._feed))
        if self.spec is not None and self.slots[i] is not None \
                and self._draft_pending[i]:
            self._draft_ingest(i)       # ride the same quantum
        if not self._pending[i]:        # feed done: next token is out
            tok = int(jax.device_get(nxt)[0])
            req.out.append(tok)
            self.bus.emit(ev.TokenDelta, req.rid, token=tok,
                          pos=len(req.out) - 1)
            self._next_tok[i] = tok
            self._maybe_retire(i)
        return 1

    def _decode_quantum(self) -> int:
        t0 = self.bus.clock()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            self.last_quantum = None
            return 0
        for i in active:
            self.runtime.ensure_writable(i, self.runtime.pos[i])
        positions = np.asarray(self.runtime.pos, np.int32)
        tables = np.asarray(self.runtime.tables, np.int32)
        nxt, self.cache = self.step_fn(
            self.params, jnp.asarray(self._next_tok[:, None]),
            jnp.asarray(positions), jnp.asarray(tables), self.cache)
        self.decode_quanta += 1
        self.decode_launches += 1
        self.last_quantum = ("decode", len(active))
        nxt_host = jax.device_get(nxt)
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.lm_keys(self)[1],
                                  ("decode",), t0, nxt)
        self._obs_quantum("decode", t0, nxt,
                          [self.slots[i].rid for i in active],
                          args={"batch": len(active),
                                "quantized_kv": self.quantized_kv,
                                "weight_quant": self.weight_quant})
        for i in active:
            req = self.slots[i]
            self.runtime.pos[i] += 1    # the fed token is now cached
            tok = int(nxt_host[i])
            req.out.append(tok)
            req.decode_steps += 1
            self.bus.emit(ev.TokenDelta, req.rid, token=tok,
                          pos=len(req.out) - 1)
            self._next_tok[i] = tok
            self._maybe_retire(i)
        return len(active)

    # ------------------------------------------- speculative decoding
    def _slot_cap(self, req: Request) -> int:
        """Cacheable positions for this request (the admit-time block
        reservation): the final token is emitted, never cached."""
        return min(len(req.prompt) + req.max_new - 1, self.max_len)

    def spec_tokens_per_round(self) -> float:
        """Observed tokens emitted per verification launch (accepted
        draft tokens + the bonus token); 1.0 before any speculation has
        run.  Feeds the ``decode-spec`` cost-model estimate."""
        if not self.spec_verifies:
            return 1.0
        return self.spec_accepted / self.spec_verifies + 1.0

    def _spec_quantum(self) -> int:
        """One speculative decode quantum.

        Three phases per round:

        1. **Draft proposal** — batched draft decode steps at the slot
           shape propose up to ``k`` tokens per slot greedily.  Slots
           whose proposal finished early swing their block-table row to
           all-null for the remaining steps, so stray writes land in
           the null block (the established idle-row idiom).
        2. **Verification** — per slot, the pending token plus the
           proposal run through ONE fused paged-prefill launch
           (``make_verify_chunk``); the target's greedy argmax at every
           chunk position decides the longest accepted prefix, and the
           position after the last accepted token yields a free
           "bonus" token.  Greedy acceptance makes the emitted stream
           token-identical to plain decode by construction.
        3. **Commit / rollback** — accepted positions keep their KV;
           the rejected tail rolls back via
           ``PagedKVRuntime.truncate`` (pure position rewind — the
           write window was CoW-guarded up front, so a refcount-shared
           prefix block is never dirtied).  The draft pool rolls back
           the same way and re-feeds any gap next round.

        Near the request horizon the proposal budget shrinks to the
        tokens that still fit; when no slot can propose at all the
        quantum degenerates to one batched baseline decode step.
        """
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            self.last_quantum = None
            return 0
        k: dict[int, int] = {}
        for i in active:
            r = self.slots[i]
            k[i] = max(0, min(self.spec.k, r.max_new - len(r.out) - 1,
                              self._slot_cap(r) - 1 - self.runtime.pos[i]))
        if all(k[i] == 0 for i in active):
            return self._decode_quantum()
        t0 = self.bus.clock()
        S = len(self.slots)
        mb = self.draft_runtime.blocks_per_slot
        # ---- phase 1: draft proposals (batched across slots) --------
        stream = {i: list(self.slots[i].prompt) + list(self.slots[i].out)
                  for i in active}
        base, feeds, steps, props = {}, {}, {}, {}
        for i in active:
            base[i] = self.draft_runtime.pos[i]
            # catch-up gap (tokens committed since the draft last saw
            # this slot) + the pending token; ends by feeding the
            # pending token, whose output is the first proposal.
            feeds[i] = stream[i][base[i]:self.runtime.pos[i] + 1]
            steps[i] = len(feeds[i]) + max(k[i] - 1, 0)
            props[i] = []
        rounds = max(steps.values())
        for t in range(rounds):
            toks = np.zeros(S, np.int32)
            poss = np.zeros(S, np.int32)
            tab = np.zeros((S, mb), np.int32)
            for i in active:
                if t >= steps[i]:
                    continue            # null row: writes are harmless
                tab[i] = self.draft_runtime.tables[i]
                poss[i] = base[i] + t
                toks[i] = (feeds[i][t] if t < len(feeds[i])
                           else props[i][-1])
            nxt, self.draft_cache = self._draft_step(
                self.draft_params, jnp.asarray(toks[:, None]),
                jnp.asarray(poss), jnp.asarray(tab), self.draft_cache)
            self.draft_launches += 1
            nxt_host = jax.device_get(nxt)
            for i in active:
                if (t < steps[i] and t >= len(feeds[i]) - 1
                        and len(props[i]) < k[i]):
                    props[i].append(int(nxt_host[i]))
        # ---- phases 2+3: verify, commit, roll back (per slot) -------
        bs = self.runtime.block_size
        total_prop = total_acc = 0
        rids = [self.slots[i].rid for i in active]
        out = None
        for i in active:
            req = self.slots[i]
            pos = self.runtime.pos[i]
            chunk = [int(self._next_tok[i])] + props[i]
            length = len(chunk)
            for bi in range(pos // bs, cdiv(pos + length, bs)):
                self.runtime.ensure_writable(i, bi * bs)
            g, self.cache = self._verify_raw(
                self.params,
                jnp.asarray([chunk], jnp.int32),
                jnp.full((1,), pos, jnp.int32),
                jnp.int32(i),
                jnp.asarray([self.runtime.tables[i]], jnp.int32),
                self.cache)
            out = g
            greedy = jax.device_get(g)[0]
            self.decode_launches += 1 if self.fused_prefill else length
            self.spec_verifies += 1
            m = 0
            while m < k[i] and props[i][m] == int(greedy[m]):
                m += 1
            emitted = props[i][:m] + [int(greedy[m])]
            req.proposed += k[i]
            req.accepted += m
            total_prop += k[i]
            total_acc += m
            if req.eos is not None and req.eos in emitted:
                emitted = emitted[:emitted.index(req.eos) + 1]
            n = len(emitted)
            # the verify launch cached all `length` fed positions; keep
            # the pending token + accepted prefix, rewind the rest
            self.runtime.pos[i] = pos + length
            self.runtime.truncate(i, pos + n)
            # draft validity: it was fed the pending token plus
            # props[:k-1]; of those, positions beyond the accepted
            # prefix describe a stream that no longer exists
            self.draft_runtime.pos[i] = min(pos + 1 + m,
                                            pos + max(k[i], 1))
            for tok in emitted:
                req.out.append(tok)
                self.bus.emit(ev.TokenDelta, req.rid, token=tok,
                              pos=len(req.out) - 1)
            req.decode_steps += 1
            self._next_tok[i] = emitted[-1]
            self._maybe_retire(i)
        self.decode_quanta += 1
        self.spec_rounds += 1
        self.spec_proposed += total_prop
        self.spec_accepted += total_acc
        self.last_quantum = ("decode-spec", len(active))
        if self.cost_model is not None:
            self._observe_quantum(self.cost_model.lm_spec_key(self),
                                  ("decode-spec",), t0, out)
        self._obs_quantum("decode-spec", t0, out, rids,
                          args={"batch": len(active),
                                "proposed": total_prop,
                                "accepted": total_acc})
        if self.metrics is not None:
            self.metrics.counter(
                "lm_spec_proposed_total",
                "draft tokens offered to the verifier").inc(total_prop)
            self.metrics.counter(
                "lm_spec_accepted_total",
                "draft tokens the target accepted").inc(total_acc)
            if total_prop:
                self.metrics.histogram(
                    "lm_spec_acceptance", "per-quantum draft "
                    "acceptance rate (accepted / proposed)",
                    buckets=(0.125, 0.25, 0.375, 0.5, 0.625, 0.75,
                             0.875, 1.0)).observe(total_acc / total_prop)
        return len(active)

    def _maybe_retire(self, i: int) -> None:
        req = self.slots[i]
        over = len(req.out) >= req.max_new
        hit_eos = req.eos is not None and req.out \
            and req.out[-1] == req.eos
        trunc = self.runtime.pos[i] >= self.max_len
        if over or hit_eos or trunc:
            req.done = True
            self.finished.append(req)
            # Donating req.prompt stays valid after a resume: the feed
            # starts with the prompt, so the table's leading full
            # blocks hold exactly the prompt's KV either way.
            self.runtime.release(i, req.prompt)
            self.slots[i] = None        # slot freed -> next admit fills
            self._pending[i] = []
            self._release_draft(i)
            self.bus.emit(ev.Finished, req.rid, result=req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.has_work():
                break
            self.step()
        return list(self.finished)    # snapshot: later runs keep appending
