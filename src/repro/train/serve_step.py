"""Serving-step factories: prefill and single-token decode.

These are the functions the dry-run lowers for the ``prefill_32k``,
``decode_32k`` and ``long_500k`` cells.  Weights may be quantized
(Q8_0 / Q3_K via the offload policy) — the decode memory roofline then
reads quantized bytes, which is the paper's core win.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import init_cache, lm_decode_step, lm_forward


def make_prefill(cfg: ModelConfig):
    def prefill(params, batch: dict[str, Any]):
        # last_only: the unembed runs on one position — the (B,S,V)
        # logits tensor would otherwise dominate prefill memory.
        logits, _ = lm_forward(params, cfg, batch["tokens"],
                               enc_embeds=batch.get("enc_embeds"),
                               prefix_embeds=batch.get("prefix_embeds"),
                               remat="block", last_only=True)
        return logits[:, -1]
    return prefill


def make_decode(cfg: ModelConfig):
    def decode(params, token: jax.Array, pos: jax.Array, cache):
        logits, cache = lm_decode_step(params, cfg, token, pos, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token[:, None], logits, cache
    return decode


def make_cache(params, cfg: ModelConfig, batch: int, max_len: int, *,
               quantized_kv: bool = False,
               enc_embeds: jax.Array | None = None):
    return init_cache(params, cfg, batch, max_len,
                      quantized_kv=quantized_kv, enc_embeds=enc_embeds)


def greedy_generate(params, cfg: ModelConfig, prompt: jax.Array,
                    steps: int, *, max_len: int = 0,
                    enc_embeds: jax.Array | None = None) -> jax.Array:
    """Reference generation loop (prefill via repeated decode)."""
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    cache = make_cache(params, cfg, b, max_len, enc_embeds=enc_embeds)
    decode = make_decode(cfg)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(s + steps - 1):
        nxt, _, cache = decode(params, tok, jnp.int32(t), cache)
        tok = prompt[:, t + 1:t + 2] if t + 1 < s else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)
