"""Training-step factory: loss, remat, microbatch accumulation,
gradient compression, optimizer update — one jit-able function.

The returned ``train_step(params, opt_state, comp_state, batch)`` is
pure and shardable with pjit; GSPMD inserts the gradient reduce over
(pod, data).  Microbatch accumulation overlaps the pod-axis reduction
with compute by construction (the scan's per-microbatch grads feed the
final reduce; XLA schedules the cross-pod collective of microbatch i
concurrently with microbatch i+1's backward).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.transformer import lm_forward
from repro.optim import adamw, compression


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits: (B, S, V) f32; labels: (B, S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        logits, aux = lm_forward(
            params, cfg, batch["tokens"],
            enc_embeds=batch.get("enc_embeds"),
            prefix_embeds=batch.get("prefix_embeds"),
            remat=tcfg.remat)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"loss": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: adamw.AdamState,
                   comp_state, batch: dict[str, Any]):
        if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
            b = batch["tokens"].shape[0]
            mb = tcfg.microbatch
            nm = b // mb

            def reshape(x):
                return x.reshape(nm, mb, *x.shape[1:])
            scanned = jax.tree.map(reshape, batch)

            def acc_step(carry, mbatch):
                gacc, lacc = carry
                (_, metrics), grads = grad_fn(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / nm,
                    gacc, grads)
                return (gacc, lacc + metrics["loss"] / nm), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_step, (zeros, jnp.zeros((), jnp.float32)), scanned,
                unroll=True if tcfg.scan_unroll else 1)
            metrics = {"loss": loss, "aux": jnp.zeros(())}
        else:
            (_, metrics), grads = grad_fn(params, batch)

        if tcfg.grad_compression:
            grads, comp_state = compression.apply_compression(
                grads, comp_state)

        new_params, new_opt = adamw.adam_update(grads, opt_state, params,
                                                tcfg)
        metrics = dict(metrics,
                       grad_norm=adamw.global_norm(grads))
        return new_params, new_opt, comp_state, metrics

    return train_step


def init_train_state(key: jax.Array, cfg: ModelConfig, tcfg: TrainConfig,
                     init_fn) -> tuple[Any, adamw.AdamState, Any]:
    params = init_fn(key, cfg)
    opt_state = adamw.init_adam(params, tcfg)
    comp_state = None
    if tcfg.grad_compression:
        comp_state = compression.init_compression(params)
    return params, opt_state, comp_state
