import os
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(ROOT, "src"), ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

# Tier-1 is split into two CI matrix jobs by suite mark.  Modules on
# the serving hot path (paged KV runtime, fused prefill) declare
# ``pytestmark = pytest.mark.serving``; everything else defaults to
# ``unit`` here so new test files are always in exactly one job.
SUITE_MARKS = ("unit", "serving")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "unit: model/kernel/engine unit tests "
                   "(tier-1 `unit` matrix job)")
    config.addinivalue_line(
        "markers", "serving: paged-KV serving runtime and fused-prefill "
                   "tests (tier-1 `serving` matrix job)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        if not any(item.get_closest_marker(m) for m in SUITE_MARKS):
            item.add_marker(pytest.mark.unit)
