"""ASR engine oracles: the cross-attention pool and enc-dec serving.

Five pool/path invariants from the PR 9 issue, plus router/SLO
integration:

* chunked streaming encode == one-shot encode (bit-equal transcripts);
* a second request with identical audio adopts the published cross
  chain (no re-encode), reads it **read-only**, and decodes
  bit-identically;
* NaN-poisoned recycled cross blocks never leak into a fresh request
  (table-driven reads only touch owned blocks);
* fused enc-dec decoder prefill is bit-exact vs the retained
  decode-step scan, with strictly fewer launches;
* cancel/preempt mid-transcribe frees BOTH pools (decoder self-KV and
  encoder cross-KV).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.whisper_large_v3 import config as WHISPER
from repro.engine import (Admitted, AsrEngine, Cancelled, CostModel,
                          EngineRouter, Finished, Preempted, Progress,
                          Rejected, TokenDelta, TranscribeRequest)
from repro.models.frontend import synthetic_audio
from repro.models.transformer import init_lm, prefill_path
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = reduced(WHISPER, d_model=64, head_dim=16, d_ff=128,
              vocab_size=96, encoder_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _audio(seed):
    return synthetic_audio(jax.random.PRNGKey(seed), CFG)


def _req(rid, seed=1, prompt=(1, 2, 3, 4, 5), max_new=6, **kw):
    return TranscribeRequest(rid=rid, audio=_audio(seed),
                             prompt=list(prompt), max_new=max_new, **kw)


def _mk(params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("audio_chunk", 16)
    kw.setdefault("prefill_chunk", 4)
    return AsrEngine(params, CFG, **kw)


def _solo(params, req_seed, prompt=(1, 2, 3, 4, 5), max_new=6):
    """Reference transcript: fresh single-slot engine, scan prefill,
    no sharing."""
    eng = AsrEngine(params, CFG, slots=1, max_len=32, audio_chunk=32,
                    prefill_chunk=4, audio_share=False,
                    fused_prefill=False)
    r = _req(0, seed=req_seed, prompt=prompt, max_new=max_new)
    eng.submit(r)
    eng.run()
    return r.out


class TestEncodeOracles:
    def test_chunked_encode_matches_one_shot(self, params):
        """Streaming ingestion in 8-frame chunks must leave exactly the
        one-shot encoder KV: bit-equal transcripts."""
        outs = []
        for chunk in (8, 32):
            eng = _mk(params, slots=1, audio_chunk=chunk,
                      audio_share=False)
            r = _req(0)
            eng.submit(r)
            eng.run()
            outs.append(list(r.out))
            assert r.encode_steps == -(-CFG.encoder_seq // chunk)
        assert outs[0] == outs[1]

    def test_audio_adoption_skips_encode(self, params):
        """Identical audio published by a finished encode is adopted
        whole: no encode quanta, prefix-cache hits, bit-equal
        transcript, and no extra cross blocks allocated."""
        eng = _mk(params, slots=1)
        r0 = _req(0)
        eng.submit(r0)
        eng.run()
        enc_q = eng.encode_quanta
        cross_after_first = eng.runtime.allocated_cross_blocks
        r1 = _req(1)
        eng.submit(r1)
        eng.run()
        assert eng.audio_hits == 1
        assert eng.encode_quanta == enc_q          # no re-encode
        assert r1.encode_steps == 0
        assert eng.runtime.cross_prefix.hits > 0
        assert r1.out == r0.out
        # The adopted run borrowed the cached chain; retirement returns
        # the pool to exactly the cache-retained baseline.
        assert eng.runtime.allocated_cross_blocks == cross_after_first

    def test_adopted_audio_blocks_read_only(self, params):
        """An adopting request must never write the shared cross
        blocks: the pool bytes holding the published chain are
        bit-identical before and after the adopted run."""
        eng = _mk(params, slots=1)
        eng.submit(_req(0))
        eng.run()
        snap = [(np.asarray(c.cross_k), np.asarray(c.cross_v))
                for c in eng.cache]
        r1 = _req(1)
        eng.submit(r1)
        eng.run()
        assert eng.audio_hits == 1
        for c, (k0, v0) in zip(eng.cache, snap):
            np.testing.assert_array_equal(np.asarray(c.cross_k), k0)
            np.testing.assert_array_equal(np.asarray(c.cross_v), v0)
        assert r1.out == _solo(params, 1)

    def test_nan_poisoned_recycled_cross_blocks(self, params):
        """A fresh request re-using recycled cross blocks never reads
        its predecessor's bytes: poison every free cross block with
        NaN after wave 1; wave 2 (different audio) must still match
        its solo reference."""
        eng = _mk(params, slots=1, audio_share=False)
        eng.submit(_req(0, seed=1))
        eng.run()
        free = eng.runtime.free_cross_block_ids()
        assert free                       # wave 1's blocks came back
        idx = jnp.asarray(free, jnp.int32)
        eng.cache = [c._replace(
            cross_k=c.cross_k.at[:, idx].set(jnp.nan),
            cross_v=c.cross_v.at[:, idx].set(jnp.nan))
            for c in eng.cache]
        r1 = _req(1, seed=2)
        eng.submit(r1)
        eng.run()
        assert r1.out == _solo(params, 2)
        assert not any(np.isnan(np.asarray(t)).all()
                       for t in [r1.out])  # sanity: tokens are ints


class TestFusedEncDecPrefill:
    def test_enc_dec_attn_only_is_fused_eligible(self):
        """PR 9 eligibility change: a pure-attention enc-dec decoder
        takes the fused paged prefill path (cross attention is
        non-causal over fixed encoder KV, so chunk-at-once equals
        per-token)."""
        assert prefill_path(CFG) == "fused"
        assert prefill_path(CFG, quantized_kv=True) == "fused"
        assert prefill_path(CFG, fused=False) == "scan"
        assert prefill_path(CFG, batch=2) == "scan"

    def test_fused_matches_scan_fewer_launches(self, params):
        """The fused enc-dec prefill path must emit bit-identical
        tokens to the retained decode-step scan, at strictly fewer
        kernel launches per admission."""
        outs, launches = [], []
        for fused in (True, False):
            eng = _mk(params, slots=1, audio_share=False,
                      fused_prefill=fused)
            assert eng.fused_prefill is fused
            r = _req(0, prompt=(1, 2, 3, 4, 5, 6, 7), max_new=5)
            eng.submit(r)
            eng.run()
            outs.append(list(r.out))
            launches.append(eng.prefill_launches)
        assert outs[0] == outs[1]
        assert launches[0] < launches[1]


class TestLifecycle:
    def test_cancel_mid_transcribe_frees_both_pools(self, params):
        """Cancel during the encode phase AND during decode: both the
        decoder self-KV pool and the cross pool drop to zero allocated
        blocks (no sharing: nothing should be retained)."""
        for steps_before_cancel in (2, 8):
            eng = _mk(params, slots=1, audio_share=False)
            eng.submit(_req(0))
            for _ in range(steps_before_cancel):
                eng.step()
            assert eng.runtime.allocated_blocks > 0
            assert eng.runtime.allocated_cross_blocks > 0
            assert eng.cancel(0)
            assert eng.runtime.allocated_blocks == 0
            assert eng.runtime.allocated_cross_blocks == 0
            evs = [e for e in eng.bus.log if e.rid == 0]
            assert isinstance(evs[-1], Cancelled)

    def test_preempt_resume_bit_exact_reuses_published_audio(self, params):
        """A preempted transcription resumes bit-exactly; because its
        encode already published, re-admission re-adopts the chain and
        skips the re-encode."""
        eng = _mk(params, slots=1)
        r = _req(0, max_new=8)
        eng.submit(r)
        while len(r.out) < 2:             # into decode
            eng.step()
        enc_q = eng.encode_quanta
        assert eng.preempt(0)
        assert eng.runtime.allocated_blocks == 0
        eng.run()
        assert r.out == _solo(params, 1, max_new=8)
        assert eng.encode_quanta == enc_q     # resumed via adoption
        assert eng.audio_hits == 1
        evs = [e for e in eng.bus.log if e.rid == 0]
        assert sum(isinstance(e, Admitted) for e in evs) == 1
        assert any(isinstance(e, Preempted) for e in evs)
        assert any(isinstance(e, Progress) and e.phase == "resume"
                   for e in evs)

    def test_progress_phases_and_token_stream(self, params):
        """Events: encode Progress up to encoder_seq, prefill Progress,
        one TokenDelta per output token, terminal Finished carrying the
        request."""
        eng = _mk(params, slots=1, audio_share=False)
        r = _req(0)
        eng.submit(r)
        eng.run()
        evs = [e for e in eng.bus.log if e.rid == 0]
        enc = [e for e in evs
               if isinstance(e, Progress) and e.phase == "encode"]
        assert [e.step for e in enc] == [16, 32]
        toks = [e.token for e in evs if isinstance(e, TokenDelta)]
        assert toks == r.out
        assert isinstance(evs[-1], Finished)
        assert evs[-1].result is r


class TestAdmission:
    def test_queue_wait_rejects_behind_deep_queue(self, params):
        """Satellite: a request feasible in isolation but behind a deep
        queue is Rejected at submit once the expected queue wait is
        charged."""
        cm = CostModel()
        eng = _mk(params, slots=1, cost_model=cm, audio_share=False)
        ke, kp, kd = cm.asr_keys(eng)
        cm.seed(ke, 0.05)
        cm.seed(kp, 0.05)
        cm.seed(kd, 0.05)
        est_one = cm.estimate_asr(eng, _req(99))
        # Occupy the slot + stack a queue without stepping.
        for rid in range(3):
            eng.submit(_req(rid, deadline_ms=60_000))
        assert eng.rejections == 0
        # Feasible alone (budget > single estimate) but not behind the
        # queue (budget < estimate + queue wait).
        budget_s = est_one * 1.5
        h = eng.submit(_req(50, deadline_ms=budget_s * 1e3))
        assert h.state == "REJECTED"
        ev = eng.bus.terminal(50)
        assert isinstance(ev, Rejected) and ev.reason == "infeasible"
        assert ev.estimated_s > est_one      # wait was charged

    def test_capacity_and_shape_validation(self, params):
        eng = _mk(params, slots=1)
        with pytest.raises(ValueError, match="non-empty decoder prompt"):
            eng.submit(TranscribeRequest(rid=0, audio=_audio(1)))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(_req(1, max_new=64))
        with pytest.raises(ValueError, match="audio shape"):
            eng.submit(TranscribeRequest(
                rid=2, audio=np.zeros((4, 4)), prompt=[1]))
        eng.submit(_req(3))
        with pytest.raises(ValueError, match="duplicate"):
            eng.submit(_req(3))


class TestRouterIntegration:
    def test_three_way_dispatch_and_shared_bus(self, params):
        """TranscribeRequest routes to the ASR engine, LM Requests to
        the batcher, on one shared bus with intact per-rid lifecycle
        invariants."""
        lm_cfg = reduced(WHISPER, d_model=64, head_dim=16, d_ff=128,
                         vocab_size=96, encoder_layers=0,
                         encoder_seq=0)
        lm_params = init_lm(jax.random.PRNGKey(3), lm_cfg)
        lm = ContinuousBatcher(lm_params, lm_cfg, slots=2, max_len=16)
        asr = _mk(params, slots=1)
        router = EngineRouter(lm=lm, asr=asr)
        router.submit(_req(0))
        router.submit(Request(rid=1, prompt=[3, 1, 4, 1, 5], max_new=4))
        done = {e.rid: e.result for e in router.stream()
                if isinstance(e, Finished)}
        assert set(done) == {0, 1}
        assert isinstance(done[0], TranscribeRequest)
        assert done[0].out == _solo(params, 1)
        assert router.asr is asr and lm.bus is asr.bus
