"""Perf-trajectory schema contract (`benchmarks/common.py`).

CI persists every benchmark's rows as ``BENCH_<suite>.json`` artifacts;
this suite pins the record shape those artifacts (and any trajectory
consumer diffing them run-over-run) rely on, and the merge semantics
that let several benchmarks of one CI job share a file.
"""
import json

import pytest

from benchmarks.common import (BENCH_SCHEMA_VERSION, bench_record,
                               parse_row, validate_record,
                               write_bench_json)
from benchmarks.compare import (_leading_number, _override_limit,
                                classify, compare_records,
                                load_overrides)

ROWS = [
    "engine_throughput/steady,12.41 req/s,0.97s for 12 reqs "
    "(max_batch=4),traces +0",
    "serving_cache/bytes,paged 34.8 KB,naive high-water 66.6 KB "
    "(1.9x, 4 waves)",
    "streaming_smoke/slo,edf hit-rate 100%,fifo hit-rate 75%",
]


class TestParseRow:
    def test_name_value_detail_split(self):
        e = parse_row("a/b,1.5 req/s,extra, commas, kept", bench="x")
        assert e == {"bench": "x", "name": "a/b", "value": "1.5 req/s",
                     "detail": "extra, commas, kept"}

    def test_detail_optional(self):
        assert parse_row("a,1")["detail"] == ""

    def test_representative_benchmark_rows(self):
        for row in ROWS:
            e = parse_row(row, bench="b")
            assert e["name"].count("/") == 1 and e["value"]

    @pytest.mark.parametrize("bad", ["", "loner", ",noname"])
    def test_malformed_rows_rejected(self, bad):
        with pytest.raises(ValueError, match="malformed"):
            parse_row(bad)


class TestRecord:
    def test_roundtrip_validates(self):
        rec = bench_record("unit", [parse_row(r, bench="b") for r in ROWS])
        validate_record(rec)
        assert rec["schema_version"] == BENCH_SCHEMA_VERSION
        assert rec["suite"] == "unit"
        assert {"python", "jax", "backend", "platform"} <= set(rec["env"])
        # survives JSON serialization (the artifact is a file)
        validate_record(json.loads(json.dumps(rec)))

    @pytest.mark.parametrize("mutate,match", [
        (lambda r: r.update(schema_version=99), "schema_version"),
        (lambda r: r.update(suite=""), "suite"),
        (lambda r: r.update(env=None), "env"),
        (lambda r: r.update(entries={"not": "a list"}), "entries"),
        (lambda r: r["entries"].append({"bench": "b"}), "field"),
        (lambda r: r["entries"].append(
            {"bench": "b", "name": "", "value": "v", "detail": ""}),
         "non-empty"),
    ])
    def test_bad_records_rejected(self, mutate, match):
        rec = bench_record("unit", [parse_row(ROWS[0], bench="b")])
        mutate(rec)
        with pytest.raises(ValueError, match=match):
            validate_record(rec)


class TestWriteMerge:
    def test_create_then_merge(self, tmp_path):
        path = str(tmp_path / "BENCH_serving.json")
        write_bench_json(path, "serving", ROWS[:1], bench="a")
        write_bench_json(path, "serving", ROWS[1:], bench="b")
        with open(path) as f:
            rec = json.load(f)
        validate_record(rec)
        assert [e["bench"] for e in rec["entries"]] == ["a", "b", "b"]
        assert rec["suite"] == "serving"

    def test_rerun_replaces_same_bench_entries(self, tmp_path):
        """Re-running a benchmark against a stale file must replace
        its old entries, not accumulate two runs' numbers."""
        path = str(tmp_path / "BENCH_serving.json")
        write_bench_json(path, "serving", ROWS[:1], bench="a")
        write_bench_json(path, "serving", ROWS[1:], bench="b")
        write_bench_json(path, "serving", [ROWS[2]], bench="a")  # re-run
        with open(path) as f:
            rec = json.load(f)
        assert [e["bench"] for e in rec["entries"]] == ["b", "b", "a"]
        assert sum(e["bench"] == "a" for e in rec["entries"]) == 1

    def test_suite_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "BENCH_unit.json")
        write_bench_json(path, "unit", ROWS[:1], bench="a")
        with pytest.raises(ValueError, match="suite mismatch"):
            write_bench_json(path, "serving", ROWS[1:], bench="b")

    def test_corrupt_existing_file_rejected(self, tmp_path):
        path = str(tmp_path / "BENCH_unit.json")
        with open(path, "w") as f:
            f.write('{"schema_version": 0, "suite": "unit"}')
        with pytest.raises(ValueError, match="schema_version"):
            write_bench_json(path, "unit", ROWS[:1], bench="a")


def _rec(*rows_by_bench):
    """Build a minimal valid record from (bench, row) pairs."""
    return bench_record("serving",
                        [parse_row(r, bench=b) for b, r in rows_by_bench])


class TestCompare:
    """`benchmarks/compare.py`: the trajectory diff CI runs between a
    run's BENCH_<suite>.json and the previous artifact."""

    def test_leading_number_and_classify(self):
        assert _leading_number("12.41 req/s") == 12.41
        assert _leading_number("ttft p50 0.123s") == 0.123
        assert _leading_number("bit-exact across kill") is None
        assert classify("engine_throughput/stream", "12.4 req/s") == \
            ("higher", "time")
        assert classify("engine_throughput/latency", "p50 0.1s") == \
            ("lower", "time")
        assert classify("serving_cache/quanta", "prefill 4 + decode 9") \
            == ("lower", "count")
        assert classify("serving_cache/bytes", "paged 34.8 KB")[0] == \
            "lower"
        assert classify("fleet_smoke/scaling", "2.99x speedup")[0] == \
            "higher"

    def test_improvement_and_within_threshold_pass(self):
        base = _rec(("a", "x/tput,10.0 req/s"), ("a", "x/quanta,20 quanta"))
        cur = _rec(("a", "x/tput,12.0 req/s"), ("a", "x/quanta,20 quanta"))
        report, regressions = compare_records(base, cur, 0.5, 0.05)
        assert not regressions
        assert any("ok" in line for line in report)

    def test_counter_regression_gates_tight(self):
        base = _rec(("a", "x/quanta,20 quanta"))
        cur = _rec(("a", "x/quanta,23 quanta"))   # +15% > 5%
        _, regressions = compare_records(base, cur, 0.5, 0.05)
        assert len(regressions) == 1 and "REGRESS" in regressions[0]

    def test_time_metric_tolerates_runner_noise(self):
        base = _rec(("a", "x/tput,10.0 req/s"))
        cur = _rec(("a", "x/tput,8.0 req/s"))     # -20% < 50%
        report, regressions = compare_records(base, cur, 0.5, 0.05)
        assert not regressions and any("~" in line for line in report)
        cur = _rec(("a", "x/tput,3.0 req/s"))     # -70% > 50%
        _, regressions = compare_records(base, cur, 0.5, 0.05)
        assert len(regressions) == 1

    def test_new_gone_and_text_metrics_never_gate(self):
        base = _rec(("a", "x/old,5 quanta"), ("a", "x/note,all good"))
        cur = _rec(("a", "x/new,7 quanta"), ("a", "x/note,still good"))
        report, regressions = compare_records(base, cur, 0.5, 0.05)
        assert not regressions
        joined = "\n".join(report)
        assert "NEW" in joined and "GONE" in joined and "text" in joined

    def test_zero_baseline_handled(self):
        base = _rec(("a", "x/launches,0 launches"))
        cur = _rec(("a", "x/launches,2 launches"))
        _, regressions = compare_records(base, cur, 0.5, 0.05)
        assert len(regressions) == 1   # 0 -> nonzero is inf regression


class TestCompareOverrides:
    """Per-metric threshold overrides (`--config`): globs against
    ``bench/name`` then the bare name; first match wins; defaults
    apply when absent or unmatched."""

    def test_load_overrides_validation(self):
        ovs = load_overrides({"overrides": [
            {"pattern": "a/*", "threshold": 0.2},
            {"pattern": "*quanta*", "threshold": 0},
        ]})
        assert ovs == [("a/*", 0.2), ("*quanta*", 0.0)]
        assert load_overrides({}) == []
        with pytest.raises(ValueError, match="pattern"):
            load_overrides({"overrides": [{"threshold": 0.1}]})
        with pytest.raises(ValueError, match=">= 0"):
            load_overrides({"overrides": [
                {"pattern": "x", "threshold": -0.1}]})

    def test_override_matching_order(self):
        ovs = [("a/x*", 0.1), ("x/*", 0.2)]
        assert _override_limit(ovs, "a", "x/quanta") == 0.1
        # second pattern matches the bare name, not bench/name
        assert _override_limit(ovs, "b", "x/quanta") == 0.2
        assert _override_limit(ovs, "b", "y/quanta") is None

    def test_override_loosens_tight_counter_gate(self):
        base = _rec(("a", "x/quanta,20 quanta"))
        cur = _rec(("a", "x/quanta,23 quanta"))   # +15% > default 5%
        _, regress = compare_records(base, cur, 0.5, 0.05,
                                     overrides=[("a/x/quanta", 0.2)])
        assert not regress
        report, _ = compare_records(base, cur, 0.5, 0.05,
                                    overrides=[("a/x/quanta", 0.2)])
        assert any("override" in line for line in report)

    def test_override_tightens_loose_time_gate(self):
        base = _rec(("a", "x/tput,10.0 req/s"))
        cur = _rec(("a", "x/tput,9.0 req/s"))     # -10% < default 50%
        _, regress = compare_records(base, cur, 0.5, 0.05,
                                     overrides=[("*tput*", 0.0)])
        assert len(regress) == 1 and "override" in regress[0]

    def test_unmatched_pattern_keeps_defaults(self):
        base = _rec(("a", "x/quanta,20 quanta"))
        cur = _rec(("a", "x/quanta,23 quanta"))
        _, regress = compare_records(base, cur, 0.5, 0.05,
                                     overrides=[("elsewhere/*", 0.9)])
        assert len(regress) == 1 and "count threshold" in regress[0]
