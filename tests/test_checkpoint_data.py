"""Checkpoint atomicity/roundtrip + data-pipeline determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import quantize_params, Q3_K_POLICY
from repro.data.pipeline import TokenPipeline
from repro.models.transformer import init_lm
from repro.configs.base import ModelConfig

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
                  head_dim=16)


@pytest.fixture
def tmpdir_():
    d = "/tmp/repro_test_ckpt"
    shutil.rmtree(d, ignore_errors=True)
    yield d
    shutil.rmtree(d, ignore_errors=True)


def test_roundtrip_quantized(tmpdir_):
    params = quantize_params(init_lm(jax.random.PRNGKey(0), CFG),
                             Q3_K_POLICY)
    ckpt.save(tmpdir_, 3, {"params": params}, meta={"seed": 1})
    out, man = ckpt.restore(tmpdir_, 3, {"params": params})
    assert man["seed"] == 1
    for a, b in zip(jax.tree.leaves(out["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmpdir_):
    params = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmpdir_, s, {"p": params})
    assert ckpt.latest_step(tmpdir_) == 5
    ckpt.gc_old(tmpdir_, keep=2)
    assert sorted(int(d.split("_")[1]) for d in os.listdir(tmpdir_)
                  if d.startswith("step_")) == [4, 5]


def test_tmp_dirs_ignored(tmpdir_):
    """A crashed (un-renamed) write must be invisible to latest_step."""
    params = {"w": jnp.ones((4,))}
    ckpt.save(tmpdir_, 1, {"p": params})
    os.makedirs(os.path.join(tmpdir_, "step_00000009.tmp"))
    assert ckpt.latest_step(tmpdir_) == 1


def test_pipeline_determinism_and_restart():
    a = TokenPipeline(vocab_size=100, seq_len=16, batch=2, seed=7)
    batches_a = [next(a) for _ in range(4)]
    a.close()
    # Restart from step 2 must reproduce batches 2,3 exactly.
    b = TokenPipeline(vocab_size=100, seq_len=16, batch=2, seed=7,
                      start_step=2)
    batches_b = [next(b) for _ in range(2)]
    b.close()
    for x, y in zip(batches_a[2:], batches_b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["labels"], y["labels"])


def test_pipeline_labels_shifted():
    p = TokenPipeline(vocab_size=100, seq_len=16, batch=1, seed=0)
    b = p.make_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    p.close()
