"""Diffusion stack tests: schedules, pipeline, quantized offload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.core.qlinear import param_bytes
from repro.diffusion import schedule as S
from repro.diffusion.pipeline import (TINY_SD, generate, init_pipeline,
                                      quantize_pipeline)


def test_schedule_monotone():
    ac = S.NoiseSchedule().alphas_cumprod()
    assert ac.shape == (1000,)
    assert bool(jnp.all(jnp.diff(ac) <= 0))
    assert 0 < float(ac[-1]) < float(ac[0]) <= 1


def test_ddim_timesteps():
    ts = S.ddim_timesteps(4)
    assert len(ts) == 4 and int(ts[0]) == 999


def test_ddim_timesteps_clamped_above_num_train():
    """num_steps > num_train used to make the stride 0 and crash."""
    ts = np.asarray(S.ddim_timesteps(2000, 1000))
    assert len(ts) == 1000 and len(np.unique(ts)) == 1000
    assert ts[0] == 999 and ts[-1] == 0
    for n in (1, 7, 999, 1000):
        tsn = np.asarray(S.ddim_timesteps(n, 1000))
        assert len(tsn) == n and len(np.unique(tsn)) == n
        assert (np.diff(tsn) < 0).all() and tsn[0] == 999


@pytest.mark.parametrize("policy", ["none", "q8_0", "q3_k", "q3_k_imax"])
def test_generate_finite_all_policies(policy):
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    qp = quantize_pipeline(params, get_policy(policy))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 77), 0, 512)
    img = generate(qp, TINY_SD, toks, jax.random.PRNGKey(2))
    assert img.shape == (1, 16, 16, 3)
    assert bool(jnp.isfinite(img.astype(jnp.float32)).all())
    assert float(jnp.abs(img).max()) <= 1.0  # tanh output


def test_quantization_shrinks_pipeline():
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    b0 = param_bytes(params)
    b8 = param_bytes(quantize_pipeline(params, get_policy("q8_0")))
    b3 = param_bytes(quantize_pipeline(params, get_policy("q3_k")))
    # TINY_SD dims are below the Q3_K super-block (256), so q3_k falls
    # back to unquantized there (GGML does the same); q8 must shrink.
    assert b8 < b0 and b3 <= b0


def test_q3k_shrinks_at_real_widths():
    """At SD/LM widths (K % 256 == 0) Q3_K < Q8_0 < bf16."""
    from repro.core.qlinear import init_linear, quantize_params
    lin = {"l": init_linear(jax.random.PRNGKey(0), 1024, 512,
                            role="mlp_up")}
    b0 = param_bytes(lin)
    b8 = param_bytes(quantize_params(lin, get_policy("q8_0")))
    b3 = param_bytes(quantize_params(lin, get_policy("q3_k")))
    assert b3 < b8 < b0


def test_multistep_ddim_runs():
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 77), 0, 512)
    img = generate(params, TINY_SD, toks, jax.random.PRNGKey(2), steps=3)
    assert bool(jnp.isfinite(img.astype(jnp.float32)).all())


def test_quantized_vs_dense_output_close():
    """Q8_0 pipeline must stay close to the bf16 pipeline (the paper's
    premise that quantized offload preserves output quality)."""
    params = init_pipeline(jax.random.PRNGKey(0), TINY_SD)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 77), 0, 512)
    key = jax.random.PRNGKey(2)
    img0 = generate(params, TINY_SD, toks, key).astype(jnp.float32)
    img8 = generate(quantize_pipeline(params, get_policy("q8_0")),
                    TINY_SD, toks, key).astype(jnp.float32)
    corr = np.corrcoef(np.asarray(img0).ravel(),
                       np.asarray(img8).ravel())[0, 1]
    assert corr > 0.95, corr
