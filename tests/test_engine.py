"""Engine API tests: protocol conformance, sampler registry, CFG,
co-batch determinism, compile-cache / trace-count behavior, streaming
previews, per-request latent sizes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.diffusion import schedule as S
from repro.engine import (TINY_SD, Admitted, Cancelled, DiffusionEngine,
                          Engine, Finished, GenerateRequest, PreviewLatent,
                          Progress, build_denoise, get_sampler,
                          init_pipeline, list_samplers, steps_bucket)
from repro.models.transformer import init_lm
from repro.serving.scheduler import ContinuousBatcher
from repro.serving.scheduler import Request as LMRequest

LM_CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                     head_dim=16)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 77), 0, 512)


def f32(x):
    return np.asarray(jnp.asarray(x, jnp.float32))


# ----------------------------------------------------------- protocol
def test_both_engines_satisfy_protocol(sd_params):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    assert isinstance(eng, Engine)
    lm = ContinuousBatcher(init_lm(jax.random.PRNGKey(0), LM_CFG), LM_CFG,
                           slots=1, max_len=8)
    assert isinstance(lm, Engine)


def test_lm_request_cursor_is_declared_field():
    """_cursor is a real dataclass field: copies/replays keep it."""
    r = LMRequest(rid=0, prompt=[1, 2, 3])
    assert r._cursor == 0
    assert dataclasses.replace(r)._cursor == 0
    assert "_cursor" in {f.name for f in dataclasses.fields(LMRequest)}


# ----------------------------------------------------------- registry
def test_registry_has_all_paper_samplers():
    assert {"ddim", "euler", "turbo"} <= set(list_samplers())


def test_unknown_sampler_fails_fast(sd_params):
    with pytest.raises(KeyError, match="unknown sampler"):
        get_sampler("dpm++")
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    with pytest.raises(KeyError):
        eng.submit(GenerateRequest(rid=0, tokens=[0] * 77, sampler="nope"))


def test_steps_bucket_pow2():
    assert [steps_bucket(s) for s in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_euler_one_step_matches_turbo_x0(sd_params, toks):
    """The orphaned euler_sigmas/euler_step path, wired through the
    registry, must reproduce turbo_step's x0 estimate in one step."""
    sched = S.NoiseSchedule()
    noise = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 8, 4),
                              jnp.float32)
    g = jnp.ones((1,), jnp.float32)
    neg = jnp.zeros_like(toks[:1])
    x0 = {}
    for name in ("turbo", "euler"):
        fn = build_denoise(TINY_SD, name, False, decode=False)
        plan = get_sampler(name).plan(sched, 1, 1)
        x0[name] = f32(fn(sd_params, toks[:1], neg, g, noise, plan))
    np.testing.assert_allclose(x0["euler"], x0["turbo"],
                               atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------- engine
def test_engine_retires_all_requests_across_buckets(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    mix = [("turbo", 1), ("ddim", 2), ("ddim", 2), ("euler", 2),
           ("ddim", 2)]
    for i, (sampler, steps) in enumerate(mix):
        eng.submit(GenerateRequest(rid=i, tokens=toks[i % 2],
                                   sampler=sampler, steps=steps, seed=i))
    res = eng.run()
    assert sorted(r.rid for r in res) == list(range(5))
    for r in res:
        assert r.image.shape == (16, 16, 3)
        assert bool(jnp.isfinite(r.image.astype(jnp.float32)).all())
        assert r.decode_steps == r.steps and r.prefill_steps == 0
    assert eng.step() == 0          # queue drained


def test_same_seed_bit_identical_alone_vs_cobatched(sd_params, toks):
    req = GenerateRequest(rid=0, tokens=toks[0], sampler="ddim", steps=2,
                          seed=123)
    e1 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e1.submit(req)
    solo = e1.run()[0].image
    e2 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e2.submit(dataclasses.replace(req, rid=5))
    e2.submit(GenerateRequest(rid=6, tokens=toks[1], sampler="ddim",
                              steps=2, seed=999))
    cob = next(r.image for r in e2.run() if r.rid == 5)
    np.testing.assert_array_equal(f32(solo), f32(cob))


def test_compile_cache_no_retrace(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=3, seed=1))
    eng.run()
    assert eng.traces == 1          # the whole 3-step loop is one trace
    # Same (sampler, steps, shape): cache hit, no retrace.
    eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="ddim",
                               steps=3, seed=2))
    eng.run()
    assert eng.traces == 1
    # steps=4 shares the pow2 steps-bucket of 3: still no retrace.
    eng.submit(GenerateRequest(rid=2, tokens=toks[0], sampler="ddim",
                               steps=4, seed=3))
    eng.run()
    assert eng.traces == 1
    # A different sampler compiles exactly once more.
    eng.submit(GenerateRequest(rid=3, tokens=toks[0], sampler="euler",
                               steps=4, seed=4))
    eng.run()
    assert eng.traces == 2


def test_turbo_normalizes_steps(sd_params, toks):
    """Turbo declares fixed_steps=1: a steps=8 turbo request reuses the
    1-step program (no extra compile, no padded UNet evals) and the
    result reports the steps actually run."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="turbo",
                               steps=1, seed=1))
    eng.run()
    eng.submit(GenerateRequest(rid=1, tokens=toks[0], sampler="turbo",
                               steps=8, seed=1))
    res = eng.run()
    assert eng.traces == 1
    assert res[-1].steps == 1
    np.testing.assert_array_equal(f32(res[0].image), f32(res[1].image))


def test_per_request_guidance_scale_applies(sd_params, toks):
    """Two co-batched CFG requests differing only in guidance scale
    must produce different images (per-request scale vector works)."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    neg = jnp.zeros((77,), jnp.int32)
    for rid, g in ((0, 1.5), (1, 7.5)):
        eng.submit(GenerateRequest(rid=rid, tokens=toks[0], neg_tokens=neg,
                                   guidance_scale=g, sampler="turbo",
                                   steps=1, seed=42))
    res = eng.run()
    assert eng.traces == 1          # one CFG program, scales batched
    imgs = {r.rid: f32(r.image) for r in res}
    assert np.isfinite(imgs[0]).all() and np.isfinite(imgs[1]).all()
    assert np.abs(imgs[0] - imgs[1]).max() > 1e-4


def test_preview_stream_matches_fused_scan(sd_params, toks):
    """The segmented (preview-streaming) program path must reproduce
    the fused single-scan result, and stream Progress + PreviewLatent
    events at the requested cadence."""
    for sampler, steps in (("ddim", 3), ("euler", 2)):
        e1 = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
        e1.submit(GenerateRequest(rid=0, tokens=toks[0], sampler=sampler,
                                  steps=steps, seed=5))
        ref = np.asarray(e1.run()[0].image, np.float32)
        e2 = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
        h = e2.submit(GenerateRequest(rid=0, tokens=toks[0],
                                      sampler=sampler, steps=steps,
                                      seed=5, preview_every=1))
        evs = list(h.events())
        np.testing.assert_allclose(
            np.asarray(h.result().image, np.float32), ref,
            atol=1e-5, rtol=1e-5)
        previews = [e for e in evs if isinstance(e, PreviewLatent)]
        assert [p.step for p in previews] == list(range(1, steps + 1))
        assert all(p.latent.shape == (8, 8, 4) for p in previews)
        prog = [e for e in evs if isinstance(e, Progress)]
        assert [p.step for p in prog] == list(range(1, steps + 1))


def test_preview_cadence_and_final_step_always_previewed(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    h = eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                                   steps=5, seed=1, preview_every=2))
    steps = [e.step for e in h.events() if isinstance(e, PreviewLatent)]
    assert steps == [2, 4, 5]       # every 2nd + the final step


def test_preview_requests_never_cobatch_with_plain(sd_params, toks):
    """preview_every is part of the group key: a plain request and a
    preview request with otherwise identical settings run as separate
    batches, and the plain one keeps its fused-scan program."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=2, seed=1))
    eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="ddim",
                               steps=2, seed=2, preview_every=1))
    n1 = eng.step()                 # plain batch runs alone
    assert n1 == 1
    assert not any(isinstance(e, PreviewLatent) for e in eng.bus.log)
    res = eng.run()
    assert sorted(r.rid for r in res) == [0, 1]
    assert any(isinstance(e, PreviewLatent) for e in eng.bus.log)


def test_diffusion_cancel_queued_and_mid_denoise(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=3, seed=1, preview_every=1))
    h1 = eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="ddim",
                                    steps=3, seed=2))
    assert h1.cancel()              # still queued: leaves the queue
    assert h1.state == "CANCELLED"
    eng.step()                      # admit rid 0, first segment
    assert eng.cancel(0)            # mid-denoise: segmented path
    res = eng.run()
    assert res == []                # nobody finished
    assert not eng.has_work()
    for rid in (0, 1):
        evs = [e for e in eng.bus.log if e.rid == rid]
        assert isinstance(evs[-1], Cancelled)
    assert not eng.cancel(7)        # unknown rid


def test_handle_survives_zero_progress_quantum(sd_params, toks):
    """A quantum that progresses 0 requests and emits nothing (here:
    clearing a fully-cancelled segmented batch) must not trip the
    handle's idle guard while queued work remains."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=3, seed=1, preview_every=1))
    eng.step()                      # segmented batch in flight
    assert eng.cancel(0)
    h = eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="turbo",
                                   steps=1, seed=2))
    assert h.result().outcome == "finished"   # pumps through the dead batch
    assert h.state == "FINISHED"


def test_bus_compaction_drops_terminal_history(sd_params, toks):
    """compact() frees finished requests' event payloads (previews)
    without skewing later stream consumers."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=3, seed=1, preview_every=1))
    eng.run()
    n = len(eng.bus.log)
    assert eng.bus.compact() == n and not eng.bus.log
    assert isinstance(eng.bus.terminal(0), Finished)    # verdict kept
    h = eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="ddim",
                                   steps=2, seed=2, preview_every=1))
    evs = list(h.events())          # cursors are seq-based: no skew
    assert isinstance(evs[0], Admitted)
    assert isinstance(evs[-1], Finished)
    assert all(e.rid == 1 for e in evs)
    # A handle whose terminal was consumed elsewhere (run) and then
    # compacted must terminate cleanly: no events, result intact.
    eng.bus.compact()
    assert list(h.events()) == []
    assert h.result().finished and h.state == "FINISHED"


def test_duplicate_rid_rejected(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], steps=1, seed=1))
    with pytest.raises(ValueError, match="duplicate rid"):
        eng.submit(GenerateRequest(rid=0, tokens=toks[1], steps=1,
                                   seed=2))


# --------------------------------------------------- per-request sizes
def test_latent_hw_mixed_sizes_never_cobatch(sd_params, toks):
    """Per-request latent sizes ride the group/compile key as shape
    buckets: a 4- and a 16-latent request run as separate programs
    with correctly sized outputs."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="turbo",
                               steps=1, seed=1, latent_hw=4))
    eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="turbo",
                               steps=1, seed=2, latent_hw=16))
    assert eng.step() == 1          # sizes must not share a batch
    res = {r.rid: r for r in eng.run()}
    assert res[0].image.shape == (8, 8, 3)      # 2x VAE upsample
    assert res[1].image.shape == (32, 32, 3)
    assert eng.traces == 2          # one program per shape bucket
    admits = {e.rid: e for e in eng.bus.log if isinstance(e, Admitted)}
    assert admits[0].slot == 0 and admits[1].slot == 0


def test_latent_hw_solo_vs_cobatched_bit_identical(sd_params, toks):
    req = GenerateRequest(rid=0, tokens=toks[0], sampler="ddim", steps=2,
                          seed=77, latent_hw=16)
    e1 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e1.submit(req)
    solo = e1.run()[0].image
    e2 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e2.submit(dataclasses.replace(req, rid=5))
    e2.submit(GenerateRequest(rid=6, tokens=toks[1], sampler="ddim",
                              steps=2, seed=99, latent_hw=16))
    cob = next(r.image for r in e2.run() if r.rid == 5)
    np.testing.assert_array_equal(f32(solo), f32(cob))


def test_latent_hw_validated_at_submit(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    for hw in (5, -4, 0):           # 0 is invalid, not "use default"
        with pytest.raises(ValueError, match="latent_hw"):
            eng.submit(GenerateRequest(rid=hw, tokens=toks[0],
                                       latent_hw=hw))


def test_run_emits_finished_events_for_plain_requests(sd_params, toks):
    """run() compatibility: the drain wrapper still produces the full
    event lifecycle (Admitted then Finished, nothing after)."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    for i in range(3):
        eng.submit(GenerateRequest(rid=i, tokens=toks[i % 2],
                                   sampler="turbo", steps=1, seed=i))
    res = eng.run()
    assert len(res) == 3
    for i in range(3):
        kinds = [type(e).__name__ for e in eng.bus.log if e.rid == i]
        assert kinds == ["Admitted", "Finished"]


def test_guided_and_unguided_programs_agree_at_scale_one(sd_params, toks):
    """gscale=1 reduces CFG to the conditional branch: the guided
    program must match the plain one up to fp reassociation."""
    sched = S.NoiseSchedule()
    noise = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 8, 4),
                              jnp.float32)
    g = jnp.ones((1,), jnp.float32)
    neg = jnp.zeros_like(toks[:1])
    plan = get_sampler("ddim").plan(sched, 2, 2)
    out = [f32(build_denoise(TINY_SD, "ddim", use_cfg, decode=False)(
        sd_params, toks[:1], neg, g, noise, plan))
        for use_cfg in (False, True)]
    np.testing.assert_allclose(out[0], out[1], atol=5e-2, rtol=5e-2)
