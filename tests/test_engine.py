"""Engine API tests: protocol conformance, sampler registry, CFG,
co-batch determinism, compile-cache / trace-count behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.diffusion import schedule as S
from repro.engine import (TINY_SD, DiffusionEngine, Engine, GenerateRequest,
                          build_denoise, get_sampler, init_pipeline,
                          list_samplers, steps_bucket)
from repro.models.transformer import init_lm
from repro.serving.scheduler import ContinuousBatcher
from repro.serving.scheduler import Request as LMRequest

LM_CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                     head_dim=16)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (2, 77), 0, 512)


def f32(x):
    return np.asarray(jnp.asarray(x, jnp.float32))


# ----------------------------------------------------------- protocol
def test_both_engines_satisfy_protocol(sd_params):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    assert isinstance(eng, Engine)
    lm = ContinuousBatcher(init_lm(jax.random.PRNGKey(0), LM_CFG), LM_CFG,
                           slots=1, max_len=8)
    assert isinstance(lm, Engine)


def test_lm_request_cursor_is_declared_field():
    """_cursor is a real dataclass field: copies/replays keep it."""
    r = LMRequest(rid=0, prompt=[1, 2, 3])
    assert r._cursor == 0
    assert dataclasses.replace(r)._cursor == 0
    assert "_cursor" in {f.name for f in dataclasses.fields(LMRequest)}


# ----------------------------------------------------------- registry
def test_registry_has_all_paper_samplers():
    assert {"ddim", "euler", "turbo"} <= set(list_samplers())


def test_unknown_sampler_fails_fast(sd_params):
    with pytest.raises(KeyError, match="unknown sampler"):
        get_sampler("dpm++")
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    with pytest.raises(KeyError):
        eng.submit(GenerateRequest(rid=0, tokens=[0] * 77, sampler="nope"))


def test_steps_bucket_pow2():
    assert [steps_bucket(s) for s in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_euler_one_step_matches_turbo_x0(sd_params, toks):
    """The orphaned euler_sigmas/euler_step path, wired through the
    registry, must reproduce turbo_step's x0 estimate in one step."""
    sched = S.NoiseSchedule()
    noise = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 8, 4),
                              jnp.float32)
    g = jnp.ones((1,), jnp.float32)
    neg = jnp.zeros_like(toks[:1])
    x0 = {}
    for name in ("turbo", "euler"):
        fn = build_denoise(TINY_SD, name, False, decode=False)
        plan = get_sampler(name).plan(sched, 1, 1)
        x0[name] = f32(fn(sd_params, toks[:1], neg, g, noise, plan))
    np.testing.assert_allclose(x0["euler"], x0["turbo"],
                               atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------- engine
def test_engine_retires_all_requests_across_buckets(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    mix = [("turbo", 1), ("ddim", 2), ("ddim", 2), ("euler", 2),
           ("ddim", 2)]
    for i, (sampler, steps) in enumerate(mix):
        eng.submit(GenerateRequest(rid=i, tokens=toks[i % 2],
                                   sampler=sampler, steps=steps, seed=i))
    res = eng.run()
    assert sorted(r.rid for r in res) == list(range(5))
    for r in res:
        assert r.image.shape == (16, 16, 3)
        assert bool(jnp.isfinite(r.image.astype(jnp.float32)).all())
        assert r.decode_steps == r.steps and r.prefill_steps == 0
    assert eng.step() == 0          # queue drained


def test_same_seed_bit_identical_alone_vs_cobatched(sd_params, toks):
    req = GenerateRequest(rid=0, tokens=toks[0], sampler="ddim", steps=2,
                          seed=123)
    e1 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e1.submit(req)
    solo = e1.run()[0].image
    e2 = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    e2.submit(dataclasses.replace(req, rid=5))
    e2.submit(GenerateRequest(rid=6, tokens=toks[1], sampler="ddim",
                              steps=2, seed=999))
    cob = next(r.image for r in e2.run() if r.rid == 5)
    np.testing.assert_array_equal(f32(solo), f32(cob))


def test_compile_cache_no_retrace(sd_params, toks):
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="ddim",
                               steps=3, seed=1))
    eng.run()
    assert eng.traces == 1          # the whole 3-step loop is one trace
    # Same (sampler, steps, shape): cache hit, no retrace.
    eng.submit(GenerateRequest(rid=1, tokens=toks[1], sampler="ddim",
                               steps=3, seed=2))
    eng.run()
    assert eng.traces == 1
    # steps=4 shares the pow2 steps-bucket of 3: still no retrace.
    eng.submit(GenerateRequest(rid=2, tokens=toks[0], sampler="ddim",
                               steps=4, seed=3))
    eng.run()
    assert eng.traces == 1
    # A different sampler compiles exactly once more.
    eng.submit(GenerateRequest(rid=3, tokens=toks[0], sampler="euler",
                               steps=4, seed=4))
    eng.run()
    assert eng.traces == 2


def test_turbo_normalizes_steps(sd_params, toks):
    """Turbo declares fixed_steps=1: a steps=8 turbo request reuses the
    1-step program (no extra compile, no padded UNet evals) and the
    result reports the steps actually run."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
    eng.submit(GenerateRequest(rid=0, tokens=toks[0], sampler="turbo",
                               steps=1, seed=1))
    eng.run()
    eng.submit(GenerateRequest(rid=1, tokens=toks[0], sampler="turbo",
                               steps=8, seed=1))
    res = eng.run()
    assert eng.traces == 1
    assert res[-1].steps == 1
    np.testing.assert_array_equal(f32(res[0].image), f32(res[1].image))


def test_per_request_guidance_scale_applies(sd_params, toks):
    """Two co-batched CFG requests differing only in guidance scale
    must produce different images (per-request scale vector works)."""
    eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
    neg = jnp.zeros((77,), jnp.int32)
    for rid, g in ((0, 1.5), (1, 7.5)):
        eng.submit(GenerateRequest(rid=rid, tokens=toks[0], neg_tokens=neg,
                                   guidance_scale=g, sampler="turbo",
                                   steps=1, seed=42))
    res = eng.run()
    assert eng.traces == 1          # one CFG program, scales batched
    imgs = {r.rid: f32(r.image) for r in res}
    assert np.isfinite(imgs[0]).all() and np.isfinite(imgs[1]).all()
    assert np.abs(imgs[0] - imgs[1]).max() > 1e-4


def test_guided_and_unguided_programs_agree_at_scale_one(sd_params, toks):
    """gscale=1 reduces CFG to the conditional branch: the guided
    program must match the plain one up to fp reassociation."""
    sched = S.NoiseSchedule()
    noise = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 8, 4),
                              jnp.float32)
    g = jnp.ones((1,), jnp.float32)
    neg = jnp.zeros_like(toks[:1])
    plan = get_sampler("ddim").plan(sched, 2, 2)
    out = [f32(build_denoise(TINY_SD, "ddim", use_cfg, decode=False)(
        sd_params, toks[:1], neg, g, noise, plan))
        for use_cfg in (False, True)]
    np.testing.assert_allclose(out[0], out[1], atol=5e-2, rtol=5e-2)
