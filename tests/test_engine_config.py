"""EngineConfig surface (PR 10): one config object for all three
engines, with every pre-existing kwarg kept as a deprecation shim.

Gates:

* old-kwarg construction and ``config=`` construction are bit-identical
  for the LM batcher, the ASR engine, and the diffusion engine;
* explicit kwargs win over the config (the shim's migration contract);
* unknown kwargs still raise ``TypeError`` (the shim must not silently
  swallow typos);
* ``max_len`` stays required for the KV-backed engines;
* ``build_engine`` dispatches on kind and rejects unknown kinds.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig, reduced
from repro.configs.whisper_large_v3 import config as WHISPER
from repro.engine import (TINY_SD, AsrEngine, AsrEngineConfig, CostModel,
                          DiffusionEngine, DiffusionEngineConfig,
                          EngineConfig, EventBus, GenerateRequest,
                          LMEngineConfig, TranscribeRequest, build_engine,
                          init_pipeline)
from repro.models.frontend import synthetic_audio
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)
ASR_CFG = reduced(WHISPER, d_model=64, head_dim=16, d_ff=128,
                  vocab_size=96, encoder_seq=32)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def asr_params():
    return init_lm(jax.random.PRNGKey(0), ASR_CFG)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _lm_tokens(cb):
    reqs = [Request(rid=i, prompt=_prompt(i, 5), max_new=4)
            for i in range(3)]
    for r in reqs:
        cb.submit(r)
    cb.run()
    return [r.out for r in reqs]


# ---------------------------------------------------- bit-identical shim
class TestKwargConfigEquivalence:
    def test_lm_old_kwargs_vs_config(self, params):
        old = ContinuousBatcher(params, CFG, slots=2, max_len=32,
                                block_size=8, prefill_chunk=4,
                                fused_prefill=False)
        new = ContinuousBatcher(
            params, CFG,
            config=EngineConfig(lm=LMEngineConfig(
                slots=2, max_len=32, block_size=8, prefill_chunk=4,
                fused_prefill=False)))
        assert _lm_tokens(old) == _lm_tokens(new)

    def test_asr_old_kwargs_vs_config(self, asr_params):
        def run(eng):
            r = TranscribeRequest(
                rid=0, audio=synthetic_audio(jax.random.PRNGKey(1),
                                             ASR_CFG),
                prompt=[1, 2, 3, 4, 5], max_new=6)
            eng.submit(r)
            eng.run()
            return r.out

        old = AsrEngine(asr_params, ASR_CFG, slots=1, max_len=32,
                        audio_chunk=16, prefill_chunk=4,
                        fused_prefill=False)
        new = AsrEngine(
            asr_params, ASR_CFG,
            config=EngineConfig(asr=AsrEngineConfig(
                slots=1, max_len=32, audio_chunk=16, prefill_chunk=4,
                fused_prefill=False)))
        assert run(old) == run(new)

    def test_diffusion_old_kwargs_vs_config(self, sd_params):
        def run(eng):
            toks = jax.random.randint(jax.random.PRNGKey(1), (77,),
                                      0, 512)
            h = eng.submit(GenerateRequest(rid=0, tokens=toks,
                                           sampler="turbo", steps=1,
                                           seed=7))
            return np.asarray(h.result().image, np.float32)

        old = DiffusionEngine(sd_params, TINY_SD, max_batch=2)
        new = DiffusionEngine(
            sd_params, TINY_SD,
            config=EngineConfig(
                diffusion=DiffusionEngineConfig(max_batch=2)))
        np.testing.assert_array_equal(run(old), run(new))


# ---------------------------------------------------------- merge rules
class TestResolutionRules:
    def test_kwargs_override_config(self, params):
        conf = EngineConfig(lm=LMEngineConfig(slots=4, max_len=64,
                                              block_size=16))
        cb = ContinuousBatcher(params, CFG, config=conf,
                               slots=1, max_len=32)
        assert len(cb.slots) == 1
        assert cb.max_len == 32
        assert cb.runtime.block_size == 16   # untouched section field

    def test_shared_fields_flow_from_config(self, params):
        bus = EventBus()
        cm = CostModel()
        conf = EngineConfig(bus=bus, cost_model=cm, edf=False,
                            lm=LMEngineConfig(slots=1, max_len=32))
        cb = ContinuousBatcher(params, CFG, config=conf)
        assert cb.bus is bus
        assert cb.cost_model is cm
        assert cb.edf is False
        assert cb.config.cost_model is cm    # resolved config retained

    def test_unknown_kwarg_raises(self, params):
        with pytest.raises(TypeError, match="max_seq"):
            ContinuousBatcher(params, CFG, slots=1, max_len=32,
                              max_seq=64)

    def test_max_len_required(self, params, asr_params):
        with pytest.raises(ValueError, match="max_len"):
            ContinuousBatcher(params, CFG, slots=1)
        with pytest.raises(ValueError, match="max_len"):
            AsrEngine(asr_params, ASR_CFG, slots=1)


# ---------------------------------------------------------- build_engine
class TestBuildEngine:
    def test_dispatch_lm(self, params):
        conf = EngineConfig(lm=LMEngineConfig(slots=1, max_len=32))
        eng = build_engine("lm", params, CFG, conf)
        assert isinstance(eng, ContinuousBatcher)
        assert len(eng.slots) == 1

    def test_dispatch_asr(self, asr_params):
        conf = EngineConfig(asr=AsrEngineConfig(slots=1, max_len=32))
        eng = build_engine("asr", asr_params, ASR_CFG, conf)
        assert isinstance(eng, AsrEngine)

    def test_dispatch_diffusion(self, sd_params):
        eng = build_engine("diffusion", sd_params, TINY_SD,
                           EngineConfig())
        assert isinstance(eng, DiffusionEngine)

    def test_unknown_kind(self, params):
        with pytest.raises(ValueError, match="unknown engine kind"):
            build_engine("vision", params, CFG, EngineConfig())
