"""Tests for the beyond-paper extensions: Q4_0 format + kernel,
flash-decode kernel, continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.policy import get_policy
from repro.core.qlinear import init_linear, param_bytes, quantize_params
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode, flash_decode_ref
from repro.kernels.q4_matmul import q4_matmul
from repro.models.transformer import init_lm
from repro.serving.scheduler import ContinuousBatcher, Request


class TestQ4:
    def test_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
        y = quant.dequantize_q4_0(quant.quantize_q4_0(x))
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.15, rel

    def test_bpw(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 1024))
        t = quant.quantize_q4_0(x)
        assert t.nbytes() * 8 / x.size == pytest.approx(4.5)

    def test_pack_roundtrip(self):
        q = np.random.default_rng(0).integers(0, 16, (5, 128)).astype(
            np.uint8)
        rt = np.asarray(quant.unpack_q4(quant.pack_q4(jnp.array(q)))) + 8
        np.testing.assert_array_equal(rt, q)

    @pytest.mark.parametrize("m,k,n", [(8, 64, 16), (32, 512, 128)])
    def test_kernel_matches_oracle(self, m, k, n):
        x = jax.random.normal(jax.random.PRNGKey(m), (m, k))
        w = jax.random.normal(jax.random.PRNGKey(m + 1), (n, k)) * 0.05
        wq = quant.quantize_q4_0(w)
        want = ref.q4_matmul_ref(x, wq)
        got = q4_matmul(x, wq.qs, wq.d.astype(jnp.float32),
                        interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-5)

    def test_policy_and_dispatch(self):
        lin = {"l": init_linear(jax.random.PRNGKey(0), 256, 128,
                                role="mlp_up")}
        qp = quantize_params(lin, get_policy("q4_0"))
        assert param_bytes(qp) < param_bytes(lin) * 0.31
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 256),
                              jnp.bfloat16)
        y = ops.quantized_matmul(x, qp["l"].w)
        assert y.shape == (4, 128)
        assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


class TestFlashDecode:
    @pytest.mark.parametrize("kv_len", [1, 33, 256])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, kv_len, dtype):
        b, h, g, c, d = 1, 2, 4, 256, 32
        ks = jax.random.split(jax.random.PRNGKey(kv_len), 3)
        q = jax.random.normal(ks[0], (b, h, g, d), dtype) * 0.4
        k = jax.random.normal(ks[1], (b, h, c, d), dtype) * 0.4
        v = jax.random.normal(ks[2], (b, h, c, d), dtype)
        kl = jnp.array([kv_len], jnp.int32)
        want = flash_decode_ref(q, k, v, kl)
        got = flash_decode(q, k, v, kl, interpret=True, bk=64)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=3e-2 if dtype == jnp.bfloat16 else 2e-5, rtol=1e-2)


class TestContinuousBatching:
    CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=96, head_dim=16)

    def test_more_requests_than_slots(self):
        params = init_lm(jax.random.PRNGKey(0), self.CFG)
        cb = ContinuousBatcher(params, self.CFG, slots=2, max_len=64)
        for r in range(5):
            cb.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new=4))
        done = cb.run()
        assert len(done) == 5
        assert all(len(d.out) == 4 for d in done)

    def test_determinism_matches_greedy(self):
        """A single slot must reproduce the plain greedy loop."""
        from repro.train.serve_step import greedy_generate
        params = init_lm(jax.random.PRNGKey(1), self.CFG)
        prompt = [5, 9, 17]
        cb = ContinuousBatcher(params, self.CFG, slots=1, max_len=32)
        cb.submit(Request(rid=0, prompt=prompt, max_new=6))
        done = cb.run()
        want = greedy_generate(params, self.CFG,
                               jnp.array([prompt], jnp.int32), steps=6)
        assert done[0].out == list(np.asarray(want[0, 3:]))

    def test_eos_frees_slot_early(self):
        params = init_lm(jax.random.PRNGKey(2), self.CFG)
        cb = ContinuousBatcher(params, self.CFG, slots=1, max_len=64)
        # Force EOS on whatever token gets emitted first.
        cb.submit(Request(rid=0, prompt=[3, 4], max_new=50))
        cb.step()  # prompt feed
        cb.step()  # first emission
        first = cb.slots[0].out[0] if cb.slots[0] else None
        cb2 = ContinuousBatcher(params, self.CFG, slots=1, max_len=64)
        cb2.submit(Request(rid=0, prompt=[3, 4], max_new=50, eos=first))
        done = cb2.run()
        assert done and len(done[0].out) < 50
