"""Fault-tolerance: watchdog, elastic meshing, checkpoint-resume
equivalence (restart-stable training)."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault_tolerance import Watchdog, elastic_mesh
from repro.models.transformer import init_lm
from repro.train.train_step import init_train_state, make_train_step

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16)


def test_watchdog_flags_stragglers():
    events = []
    w = Watchdog(threshold=3.0,
                 on_straggler=lambda s, t, e: events.append(s))
    for i in range(10):
        w.observe(i, 0.1)
    assert not events
    assert w.observe(10, 1.0)        # 10x the EWMA -> straggler
    assert events == [10]
    # EWMA not poisoned by the straggler sample.
    assert abs(w.ewma - 0.1) < 1e-6


def test_elastic_mesh_shrinks_gracefully():
    # 1 real device: degenerate but valid mesh.
    m = elastic_mesh(model_parallel=1, pod_size=1)
    assert m.shape["pod"] * m.shape["data"] * m.shape["model"] >= 1
    # Simulated device arrays: losing a pod keeps a valid mesh.
    fake = np.arange(512)
    m512 = elastic_mesh(fake, model_parallel=16, pod_size=256)
    fake_minus_pod = np.arange(256)
    m256 = elastic_mesh(fake_minus_pod, model_parallel=16, pod_size=256)
    assert m512.shape["pod"] == 2 and m256.shape["pod"] == 1
    assert m256.shape["model"] == 16  # TP degree preserved


def test_checkpoint_restart_bitwise_equivalent():
    """train 6 steps straight == train 3, checkpoint, restore, train 3.

    This is the core fault-tolerance contract: a preempted job resumes
    with identical state (params, optimizer, data cursor)."""
    d = "/tmp/repro_test_resume"
    shutil.rmtree(d, ignore_errors=True)
    tcfg = TrainConfig(lr=1e-3)
    step = jax.jit(make_train_step(CFG, tcfg))

    def fresh():
        pipe = TokenPipeline(vocab_size=CFG.vocab_size, seq_len=16,
                             batch=2, seed=3)
        params, opt, comp = init_train_state(jax.random.PRNGKey(0), CFG,
                                             tcfg, init_lm)
        return pipe, params, opt, comp

    # Straight-through run.
    pipe, params, opt, comp = fresh()
    for _ in range(6):
        b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, comp, _ = step(params, opt, comp, b)
    pipe.close()
    ref_leaves = [np.asarray(x) for x in jax.tree.leaves(params)]

    # Interrupted run.
    pipe, params, opt, comp = fresh()
    for _ in range(3):
        b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, comp, _ = step(params, opt, comp, b)
    ckpt.save(d, 3, {"params": params, "opt": opt}, meta=pipe.state())
    pipe.close()

    last = ckpt.latest_step(d)
    restored, man = ckpt.restore(d, last, {"params": params, "opt": opt})
    params, opt = restored["params"], restored["opt"]
    pipe2 = TokenPipeline(vocab_size=CFG.vocab_size, seq_len=16, batch=2,
                          seed=man["seed"], start_step=man["step"])
    for _ in range(3):
        b = {k: jnp.asarray(v) for k, v in next(pipe2).items()}
        params, opt, comp, _ = step(params, opt, comp, b)
    pipe2.close()
    for a, b_ in zip(ref_leaves, jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b_))
    shutil.rmtree(d, ignore_errors=True)
