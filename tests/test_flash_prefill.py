"""Oracle suite for the fused paged flash-prefill kernel.

Proves the chain  fused prefill ≡ decode-step scan ≡ one-shot
attention  at fp32 allclose: kernel-level against an independently
written one-shot reference (chunk lengths 1/3/8 and block-boundary
straddles), model-level against the retained ``lax.scan``-of-decode
oracle path, and end-to-end through the ``ContinuousBatcher`` —
including prefix-shared read-only blocks and CoW-guarded blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.kernels.flash_prefill import (flash_prefill_paged,
                                         flash_prefill_paged_q8,
                                         flash_prefill_paged_q8_ref,
                                         flash_prefill_paged_ref)
from repro.models.transformer import (init_cache, init_lm,
                                      lm_prefill_chunk,
                                      prefill_fused_eligible,
                                      prefill_path)
from repro.serving import ContinuousBatcher, PagedKVRuntime, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=32)
HYBRID = ModelConfig(name="h", family="hybrid", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                     head_dim=32, block_pattern=("attn", "mamba"),
                     ssm_state=8)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompt(seed: int, n: int) -> list[int]:
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _solo(params, cfg, req: Request, **kw) -> list[int]:
    cb = ContinuousBatcher(params, cfg, slots=1,
                           max_len=ContinuousBatcher.required_len(
                               1, 1, len(req.prompt), req.max_new), **kw)
    cb.submit(Request(rid=req.rid, prompt=list(req.prompt),
                      max_new=req.max_new, eos=req.eos))
    return cb.run()[0].out


def _one_shot(q, k_hist, v_hist, k_new, v_new, pos0, *, window=None):
    """Independent reference: contiguous [history; chunk] causal
    attention, no paging involved.  q: (T, Hkv, G, hd);
    k_hist/v_hist: (pos0, Hkv, hd); k_new/v_new: (T, Hkv, hd)."""
    t, h, g, d = q.shape
    k_all = jnp.concatenate([k_hist, k_new], 0)
    v_all = jnp.concatenate([v_hist, v_new], 0)
    logits = jnp.einsum("thgd,chd->thgc", q.astype(jnp.float32),
                        k_all.astype(jnp.float32)) * d ** -0.5
    qpos = pos0 + jnp.arange(t)[:, None]
    kpos = jnp.arange(pos0 + t)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, -1)
    return jnp.einsum("thgc,chd->thgd", p, v_all.astype(jnp.float32))


def _kernel_case(t, pos0, seed, *, dtype=jnp.float32):
    h, g, d, bs, nb, mb = 2, 2, 32, 8, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (t, h, g, d), dtype) * 0.5
    kn = jax.random.normal(ks[1], (t, h, d), dtype) * 0.5
    vn = jax.random.normal(ks[2], (t, h, d), dtype)
    kp = jax.random.normal(ks[3], (nb, h, bs, d), dtype) * 0.5
    vp = jax.random.normal(ks[4], (nb, h, bs, d), dtype)
    tbl = jnp.array([3, 1, 4, 2], jnp.int32)   # non-monotonic on purpose
    idx = jnp.arange(pos0)
    k_hist = kp[tbl[idx // bs], :, idx % bs]
    v_hist = vp[tbl[idx // bs], :, idx % bs]
    return q, kn, vn, kp, vp, tbl, k_hist, v_hist


# ---------------------------------------------------------- kernel level
class TestKernelOracle:
    # Chunk lengths 1 / 3 / 8; pos0 placements: start, mid-block,
    # block-aligned, and chunks straddling one or two block boundaries.
    CASES = [(1, 0), (1, 7), (3, 5), (3, 8), (8, 0), (8, 5), (8, 13)]

    @pytest.mark.parametrize("t,pos0", CASES)
    def test_fused_equals_oracle_and_one_shot(self, t, pos0):
        q, kn, vn, kp, vp, tbl, kh, vh = _kernel_case(t, pos0,
                                                      seed=31 * t + pos0)
        got, kpo, vpo = flash_prefill_paged(q, kn, vn, kp, vp, tbl, pos0,
                                            interpret=True)
        ref, kpr, vpr = flash_prefill_paged_ref(q, kn, vn, kp, vp, tbl,
                                                pos0)
        shot = _one_shot(q, kh, vh, kn, vn, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(shot), atol=2e-5, rtol=1e-4)
        # In-kernel KV writes land exactly where the oracle scatter does.
        np.testing.assert_array_equal(np.asarray(kpo), np.asarray(kpr))
        np.testing.assert_array_equal(np.asarray(vpo), np.asarray(vpr))

    @pytest.mark.parametrize("t,pos0", [(3, 5), (8, 13)])
    def test_sliding_window(self, t, pos0):
        q, kn, vn, kp, vp, tbl, kh, vh = _kernel_case(t, pos0, seed=9)
        got, _, _ = flash_prefill_paged(q, kn, vn, kp, vp, tbl, pos0,
                                        window=6, interpret=True)
        shot = _one_shot(q, kh, vh, kn, vn, pos0, window=6)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(shot), atol=2e-5, rtol=1e-4)

    def test_unlisted_and_stale_blocks_are_inert(self):
        """NaN in pool blocks outside the table AND in the stale tail
        beyond the chunk's last position must not reach the output, and
        blocks not named by the table must come back bit-unchanged."""
        t, pos0 = 5, 6
        q, kn, vn, kp, vp, tbl, _, _ = _kernel_case(t, pos0, seed=2)
        poison = jnp.full_like(kp[0], jnp.nan)
        for bid in (5, 6, 7):                    # unlisted blocks
            kp = kp.at[bid].set(poison)
            vp = vp.at[bid].set(poison)
        # Stale tail inside a listed block: positions >= pos0 + t.
        bs = kp.shape[2]
        tail_blk, tail_off = int(tbl[(pos0 + t) // bs]), (pos0 + t) % bs
        kp = kp.at[tail_blk, :, tail_off:].set(jnp.nan)
        vp = vp.at[tail_blk, :, tail_off:].set(jnp.nan)
        got, kpo, vpo = flash_prefill_paged(q, kn, vn, kp, vp, tbl, pos0,
                                            interpret=True)
        assert bool(jnp.isfinite(got.astype(jnp.float32)).all())
        want, _, _ = flash_prefill_paged_ref(
            q, kn, vn, jnp.nan_to_num(kp), jnp.nan_to_num(vp), tbl, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=1e-5)
        for bid in (5, 6, 7):
            np.testing.assert_array_equal(np.asarray(kpo[bid]),
                                          np.asarray(kp[bid]))
            np.testing.assert_array_equal(np.asarray(vpo[bid]),
                                          np.asarray(vp[bid]))

    def test_prior_blocks_read_only(self):
        """History blocks below pos0 (prefix-shared, possibly adopted
        read-only by several slots) must come back bit-identical: the
        in-kernel write touches only the chunk's own positions."""
        t, pos0 = 4, 8                           # history fills block 0
        q, kn, vn, kp, vp, tbl, _, _ = _kernel_case(t, pos0, seed=4)
        _, kpo, vpo = flash_prefill_paged(q, kn, vn, kp, vp, tbl, pos0,
                                          interpret=True)
        hist_bid = int(tbl[0])
        np.testing.assert_array_equal(np.asarray(kpo[hist_bid]),
                                      np.asarray(kp[hist_bid]))
        np.testing.assert_array_equal(np.asarray(vpo[hist_bid]),
                                      np.asarray(vp[hist_bid]))


# ------------------------------------------------------ kernel level, Q8
def _roundtrip(x):
    """Q8_0 quantize-dequantize round trip along the last axis, read at
    bf16 — the precision every pool reader (fused kernel and the scan
    path's _dequantize_kv alike) attends at."""
    return quant.dequantize_q8_0(quant.quantize_q8_0(x),
                                 jnp.bfloat16).astype(jnp.float32)


def _kernel_case_q8(t, pos0, seed):
    """Q8_0 twin of _kernel_case: the fp pools are quantized per row
    (per-32 blocks along hd, exactly like the serving cache), and the
    one-shot history is their *dequantized* content — what any reader
    of the quantized pool actually attends to."""
    q, kn, vn, kp, vp, tbl, _, _ = _kernel_case(t, pos0, seed)
    k8, v8 = quant.quantize_q8_0(kp), quant.quantize_q8_0(vp)
    kq, ks = k8.qs, k8.d
    vq, vs = v8.qs, v8.d
    bs = kp.shape[2]
    idx = jnp.arange(pos0)
    kd = quant.dequantize_q8_0(k8, jnp.bfloat16).astype(jnp.float32)
    vd = quant.dequantize_q8_0(v8, jnp.bfloat16).astype(jnp.float32)
    k_hist = kd[tbl[idx // bs], :, idx % bs]
    v_hist = vd[tbl[idx // bs], :, idx % bs]
    return q, kn, vn, kq, vq, ks, vs, tbl, k_hist, v_hist


class TestKernelOracleQ8:
    """Oracle suite for ``flash_prefill_paged_q8`` per the pattern in
    ``src/repro/kernels/README.md``: interpret-mode kernel vs the XLA
    ref (tight), vs an independent one-shot reference over dequantized
    content (tight — same requantized values), and vs the *unquantized*
    fp32 one-shot at quantization tolerance (the requantization
    round-trip bound)."""
    CASES = [(1, 0), (1, 7), (3, 5), (3, 8), (8, 0), (8, 5), (8, 13)]

    @pytest.mark.parametrize("t,pos0", CASES)
    def test_fused_q8_equals_oracle_and_one_shot(self, t, pos0):
        case = _kernel_case_q8(t, pos0, seed=13 * t + pos0)
        q, kn, vn, kq, vq, ks, vs, tbl, kh, vh = case
        got, kqo, vqo, kso, vso = flash_prefill_paged_q8(
            q, kn, vn, kq, vq, ks, vs, tbl, pos0, interpret=True)
        ref, kqr, vqr, ksr, vsr = flash_prefill_paged_q8_ref(
            q, kn, vn, kq, vq, ks, vs, tbl, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6, rtol=1e-5)
        # Pools: in-kernel requantize + scatter lands exactly where the
        # oracle's quantize_q8_0 + scatter does — quants AND scales.
        np.testing.assert_array_equal(np.asarray(kqo), np.asarray(kqr))
        np.testing.assert_array_equal(np.asarray(vqo), np.asarray(vqr))
        np.testing.assert_array_equal(np.asarray(kso), np.asarray(ksr))
        np.testing.assert_array_equal(np.asarray(vso), np.asarray(vsr))
        # One-shot over dequantized history + the chunk's requantized
        # round trip: same values the kernel attends to, tight bound.
        shot = _one_shot(q, kh, vh, _roundtrip(kn), _roundtrip(vn), pos0)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(shot), atol=2e-5,
                                   rtol=1e-4)

    @pytest.mark.parametrize("t,pos0", [(3, 5), (8, 13)])
    def test_requantization_roundtrip_tolerance_vs_fp32(self, t, pos0):
        """Against the *unquantized* fp32 one-shot the only error is
        the Q8_0 round trip of K/V — bounded by the per-block scale
        (~amax / 127), loose compared to machine eps but tight in
        absolute terms for unit-scale inputs."""
        seed = 13 * t + pos0
        q, kn, vn, kq, vq, ks, vs, tbl, _, _ = _kernel_case_q8(
            t, pos0, seed)
        # fp oracle uses the same underlying fp pools/history.
        qf, knf, vnf, kpf, vpf, _tbl, khf, vhf = _kernel_case(t, pos0,
                                                              seed)
        got, *_ = flash_prefill_paged_q8(q, kn, vn, kq, vq, ks, vs, tbl,
                                         pos0, interpret=True)
        shot_fp = _one_shot(qf, khf, vhf, knf, vnf, pos0)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(shot_fp), atol=0.12,
                                   rtol=0.12)

    @pytest.mark.parametrize("t,pos0", [(3, 5), (8, 13)])
    def test_sliding_window(self, t, pos0):
        q, kn, vn, kq, vq, ks, vs, tbl, kh, vh = _kernel_case_q8(
            t, pos0, seed=7)
        got, *_ = flash_prefill_paged_q8(q, kn, vn, kq, vq, ks, vs, tbl,
                                         pos0, window=6, interpret=True)
        shot = _one_shot(q, kh, vh, _roundtrip(kn), _roundtrip(vn),
                         pos0, window=6)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(shot), atol=2e-5,
                                   rtol=1e-4)

    def test_recycled_block_poison_is_inert(self):
        """Recycled-block stale bytes: poison unlisted blocks and the
        listed stale tail with 127 quants + NaN scales.  The output
        must stay finite and unlisted blocks bit-unchanged (NaN scales
        included)."""
        t, pos0 = 5, 6
        q, kn, vn, kq, vq, ks, vs, tbl, _, _ = _kernel_case_q8(
            t, pos0, seed=2)
        for bid in (5, 6, 7):                    # unlisted blocks
            kq = kq.at[bid].set(127)
            vq = vq.at[bid].set(127)
            ks = ks.at[bid].set(jnp.nan)
            vs = vs.at[bid].set(jnp.nan)
        bs = kq.shape[2]
        tail_blk, tail_off = int(tbl[(pos0 + t) // bs]), (pos0 + t) % bs
        ks = ks.at[tail_blk, :, tail_off:].set(jnp.nan)
        vs = vs.at[tail_blk, :, tail_off:].set(jnp.nan)
        got, kqo, vqo, kso, vso = flash_prefill_paged_q8(
            q, kn, vn, kq, vq, ks, vs, tbl, pos0, interpret=True)
        assert bool(jnp.isfinite(got.astype(jnp.float32)).all())
        want, *_ = flash_prefill_paged_q8_ref(
            q, kn, vn, kq, vq, jnp.nan_to_num(ks), jnp.nan_to_num(vs),
            tbl, pos0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=1e-5)
        for bid in (5, 6, 7):
            np.testing.assert_array_equal(np.asarray(kqo[bid]),
                                          np.asarray(kq[bid]))
            np.testing.assert_array_equal(np.asarray(kso[bid]),
                                          np.asarray(ks[bid]))
            np.testing.assert_array_equal(np.asarray(vso[bid]),
                                          np.asarray(vs[bid]))

    def test_prefix_shared_history_blocks_read_only(self):
        """History blocks below pos0 (possibly adopted read-only by
        several slots) must come back bit-identical in all four pools."""
        t, pos0 = 4, 8                           # history fills block 0
        q, kn, vn, kq, vq, ks, vs, tbl, _, _ = _kernel_case_q8(
            t, pos0, seed=4)
        _, kqo, vqo, kso, vso = flash_prefill_paged_q8(
            q, kn, vn, kq, vq, ks, vs, tbl, pos0, interpret=True)
        hist = int(tbl[0])
        for out, orig in ((kqo, kq), (vqo, vq), (kso, ks), (vso, vs)):
            np.testing.assert_array_equal(np.asarray(out[hist]),
                                          np.asarray(orig[hist]))


# ----------------------------------------------------------- model level
class TestModelOracle:
    @pytest.mark.parametrize("chunks", [(1,), (3,), (8,), (5, 3), (3, 5),
                                        (1, 8, 2)])
    def test_fused_equals_decode_step_scan(self, params, chunks):
        """The tentpole acceptance: feeding the prompt through the
        fused path chunk-by-chunk matches the decode-step-scan oracle —
        final logits AND every KV position written to the pool — at
        fp32 allclose.  Chunk splits cover block-boundary straddles
        (block_size=4)."""
        prompt = _prompt(11, sum(chunks))
        rt = PagedKVRuntime(slots=1, max_len=16, block_size=4)
        cache_f = init_cache(params, CFG, 1, 16, block_size=4,
                             num_blocks=rt.num_blocks)
        cache_s = jax.tree.map(jnp.copy, cache_f)
        rt.admit(0, prompt, 4)
        tbl = jnp.asarray([rt.tables[0]], jnp.int32)
        pos = 0
        for c in chunks:
            toks = jnp.asarray([prompt[pos:pos + c]], jnp.int32)
            pos0 = jnp.full((1,), pos, jnp.int32)
            logits_f, cache_f = lm_prefill_chunk(
                params, CFG, toks, pos0, cache_f, block_tables=tbl,
                fused=True)
            logits_s, cache_s = lm_prefill_chunk(
                params, CFG, toks, pos0, cache_s, block_tables=tbl,
                fused=False)
            pos += c
        np.testing.assert_allclose(
            np.asarray(logits_f, np.float32),
            np.asarray(logits_s, np.float32), atol=3e-2, rtol=2e-2)
        # Every written KV position matches the scan oracle's cache.
        # (Model runs in bf16: layer>0 projections see ~1-ulp rounding
        # noise from the differently-shaped layer-0 attention, so the
        # tolerance is bf16-scale; the tight fp32 check is the
        # kernel-level oracle suite above.)
        idx = jnp.arange(pos)
        bids = tbl[0][idx // 4]
        offs = idx % 4
        for lf, ls in zip(cache_f, cache_s):
            for a, b in zip(jax.tree.leaves(lf.kv), jax.tree.leaves(ls.kv)):
                np.testing.assert_allclose(
                    np.asarray(a[:, bids, :, offs], np.float32),
                    np.asarray(b[:, bids, :, offs], np.float32),
                    atol=6e-2, rtol=6e-2)

    @pytest.mark.parametrize("chunks", [(3,), (8,), (5, 3), (1, 8, 2)])
    def test_fused_q8_matches_scan_at_dequant_reference(self, params,
                                                        chunks):
        """Quantized-KV tentpole acceptance at the model level: the
        fused q8 path matches the decode-step-scan oracle.  Both paths
        quantize each token's KV with the same per-row Q8_0 math, so
        pool contents agree to quantization-step tolerance (the chunk
        projections are computed at different batch shapes, hence not
        bit-exact) and logits agree at dequant-reference precision."""
        prompt = _prompt(17, sum(chunks))
        rt = PagedKVRuntime(slots=1, max_len=16, block_size=4)
        cache_f = init_cache(params, CFG, 1, 16, block_size=4,
                             num_blocks=rt.num_blocks, quantized_kv=True)
        cache_s = jax.tree.map(jnp.copy, cache_f)
        rt.admit(0, prompt, 4)
        tbl = jnp.asarray([rt.tables[0]], jnp.int32)
        pos = 0
        for c in chunks:
            toks = jnp.asarray([prompt[pos:pos + c]], jnp.int32)
            pos0 = jnp.full((1,), pos, jnp.int32)
            logits_f, cache_f = lm_prefill_chunk(
                params, CFG, toks, pos0, cache_f, block_tables=tbl,
                fused=True)
            logits_s, cache_s = lm_prefill_chunk(
                params, CFG, toks, pos0, cache_s, block_tables=tbl,
                fused=False)
            pos += c
        np.testing.assert_allclose(
            np.asarray(logits_f, np.float32),
            np.asarray(logits_s, np.float32), atol=3e-2, rtol=2e-2)
        idx = jnp.arange(pos)
        bids = tbl[0][idx // 4]
        offs = idx % 4
        for lf, ls in zip(cache_f, cache_s):
            # Compare the *dequantized* written positions: quant codes
            # can differ by +/-1 where the two paths' projections round
            # differently, but the decoded values stay within the
            # block-scale quantization step.
            df = jax.tree.map(lambda q, s: np.asarray(
                q[:, bids, :, offs], np.float32)
                * np.asarray(s[:, bids, :, offs],
                             np.float32).repeat(32, -1),
                (lf.kv.k, lf.kv.v), (lf.kv.k_scale, lf.kv.v_scale))
            ds = jax.tree.map(lambda q, s: np.asarray(
                q[:, bids, :, offs], np.float32)
                * np.asarray(s[:, bids, :, offs],
                             np.float32).repeat(32, -1),
                (ls.kv.k, ls.kv.v), (ls.kv.k_scale, ls.kv.v_scale))
            for a, b in zip(df, ds):
                np.testing.assert_allclose(a, b, atol=8e-2, rtol=8e-2)

    def test_eligibility_matrix(self):
        assert prefill_fused_eligible(CFG)
        # Q8_0 pools are fused-eligible now: they take the q8 sibling
        # kernel instead of falling back to the decode-step scan.
        assert prefill_fused_eligible(CFG, quantized_kv=True)
        assert not prefill_fused_eligible(HYBRID)
        assert not prefill_fused_eligible(HYBRID, quantized_kv=True)
        # PR 9: a pure-attention enc-dec decoder is fused-eligible —
        # cross attention is non-causal over FIXED encoder KV, so
        # chunk-at-once equals per-token (oracle: test_asr_serving).
        enc_dec = ModelConfig(
            name="ed", family="audio", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
            head_dim=32, encoder_layers=2, encoder_seq=16,
            pos_embed="sinusoidal")
        assert enc_dec.is_enc_dec
        assert prefill_fused_eligible(enc_dec)
        assert prefill_fused_eligible(enc_dec, quantized_kv=True)

    def test_prefill_path_single_source_of_truth(self):
        """prefill_path backs both lm_prefill_chunk's dispatch and the
        batcher's launch accounting — pin the full matrix."""
        assert prefill_path(CFG) == "fused"
        assert prefill_path(CFG, quantized_kv=True) == "fused"
        assert prefill_path(CFG, fused=False) == "scan"
        assert prefill_path(CFG, batch=2) == "scan"
        assert prefill_path(HYBRID) == "scan"
        assert prefill_path(HYBRID, quantized_kv=True) == "scan"

    def test_batch_gt_one_keeps_documented_contract(self, params):
        """lm_prefill_chunk's (B, C) signature must survive the
        fused=True default: the fused kernel is batch-1 (one slot per
        admission), so batch > 1 silently takes the scan path instead
        of tripping the kernel's batch assertion."""
        rt = PagedKVRuntime(slots=2, max_len=16, block_size=4)
        cache = init_cache(params, CFG, 2, 16, block_size=4,
                           num_blocks=rt.num_blocks)
        p0, p1 = _prompt(1, 6), _prompt(2, 6)
        rt.admit(0, p0, 4)
        rt.admit(1, p1, 4)
        tbl = jnp.asarray(rt.tables, jnp.int32)
        toks = jnp.asarray([p0, p1], jnp.int32)
        pos0 = jnp.zeros((2,), jnp.int32)
        lf, _ = lm_prefill_chunk(params, CFG, toks, pos0, cache,
                                 block_tables=tbl, fused=True)
        ls, _ = lm_prefill_chunk(params, CFG, toks, pos0, cache,
                                 block_tables=tbl, fused=False)
        np.testing.assert_array_equal(np.asarray(lf), np.asarray(ls))


# ------------------------------------------------------------ end to end
class TestServingOracle:
    def test_fused_and_scan_admission_emit_identical_tokens(self, params):
        """Whole-workload equivalence through the batcher, multi-wave
        and ragged prompt lengths (ragged tails straddle chunk and
        block boundaries)."""
        prompts = [_prompt(50 + i, 7 + i % 5) for i in range(5)]
        outs = {}
        for fused in (True, False):
            cb = ContinuousBatcher(params, CFG, slots=2, max_len=20,
                                   block_size=4, prefill_chunk=4,
                                   fused_prefill=fused)
            assert cb.fused_prefill is fused
            for rid, p in enumerate(prompts):
                cb.submit(Request(rid=rid, prompt=list(p), max_new=5))
            outs[fused] = {r.rid: r.out for r in cb.run()}
        assert outs[True] == outs[False]

    def test_fused_admission_uses_fewer_launches(self, params):
        launches = {}
        for fused in (True, False):
            cb = ContinuousBatcher(params, CFG, slots=1, max_len=20,
                                   fused_prefill=fused)
            cb.submit(Request(rid=0, prompt=_prompt(1, 12), max_new=3))
            cb.run()
            launches[fused] = cb.prefill_launches
        assert launches[True] == 2       # ceil(12 / prefill_chunk=8)
        assert launches[False] == 12     # one decode step per token
        assert launches[True] < launches[False]

    def test_fused_q8_admission_uses_fewer_launches(self, params):
        """Quantized-KV admission is 1 launch per chunk now — the last
        1-launch-per-token path is gone."""
        launches = {}
        for fused in (True, False):
            cb = ContinuousBatcher(params, CFG, slots=1, max_len=20,
                                   fused_prefill=fused,
                                   quantized_kv=True)
            assert cb.fused_prefill is fused   # no silent downgrade
            cb.submit(Request(rid=0, prompt=_prompt(1, 12), max_new=3))
            cb.run()
            launches[fused] = cb.prefill_launches
        assert launches[True] == 2
        assert launches[False] == 12

    def test_quantized_fused_and_scan_admission_agree(self, params):
        """Fused-q8 vs decode-step-scan through the batcher: same
        requests, tokens identical at dequant-reference precision
        (pool contents agree to the quantization step; greedy argmax
        is stable under that perturbation for these workloads)."""
        prompts = [_prompt(70 + i, 7 + i % 5) for i in range(4)]
        outs = {}
        for fused in (True, False):
            cb = ContinuousBatcher(params, CFG, slots=2, max_len=20,
                                   block_size=4, prefill_chunk=4,
                                   fused_prefill=fused,
                                   quantized_kv=True)
            assert cb.fused_prefill is fused
            for rid, p in enumerate(prompts):
                cb.submit(Request(rid=rid, prompt=list(p), max_new=5))
            outs[fused] = {r.rid: r.out for r in cb.run()}
        assert outs[True] == outs[False]

    def test_fallback_launch_accounting_counts_per_token(self):
        """Auto-fallback paths (recurrent/hybrid here; enc-dec and
        batch>1 share the same init-time downgrade) must count one
        launch per *token*, not per chunk — the fused-vs-scan gate in
        benchmarks/serving_cache.py divides by this."""
        hp = init_lm(jax.random.PRNGKey(3), HYBRID)
        cb = ContinuousBatcher(hp, HYBRID, slots=1, max_len=20,
                               fused_prefill=True, prefill_chunk=8)
        assert cb.fused_prefill is False         # silently downgraded
        cb.submit(Request(rid=0, prompt=_prompt(4, 11), max_new=2))
        cb.run()
        assert cb.prefill_launches == 11         # 1 per prompt token
        assert cb.prefill_quanta == 2            # ceil(11 / 8) chunks

    def test_cost_model_keys_match_executed_path(self, params):
        """Satellite: estimate keys must be keyed on the path actually
        executed, so calibrate() seeds what production quanta observe."""
        from repro.engine.costmodel import CostModel
        cm = CostModel()
        hp = init_lm(jax.random.PRNGKey(3), HYBRID)
        cases = [
            (ContinuousBatcher(params, CFG, slots=1, max_len=20,
                               quantized_kv=True), True),
            (ContinuousBatcher(params, CFG, slots=1, max_len=20,
                               fused_prefill=False), False),
            (ContinuousBatcher(hp, HYBRID, slots=1, max_len=20), False),
        ]
        for cb, want_fused in cases:
            kp, kd = cm.lm_keys(cb)
            assert kp[3] is cb.fused_prefill is want_fused
            assert kp[4] is cb.quantized_kv and kd[3] is cb.quantized_kv
            assert kp[5] is None and kd[4] is None  # no weight quant
            # The key's fused dim predicts the launch pattern exactly.
            cb.submit(Request(rid=0, prompt=_prompt(6, 9), max_new=2))
            cb.run()
            expect = cb.prefill_quanta if kp[3] else 9
            assert cb.prefill_launches == expect

    def test_fused_downgrades_for_hybrid_but_not_quantized(self, params):
        hp = init_lm(jax.random.PRNGKey(3), HYBRID)
        assert not ContinuousBatcher(hp, HYBRID, slots=1,
                                     max_len=8).fused_prefill
        # Quantized KV no longer downgrades: the q8 sibling kernel
        # keeps admission on the 1-launch-per-chunk path.
        assert ContinuousBatcher(params, CFG, slots=1, max_len=8,
                                 quantized_kv=True).fused_prefill
        assert ContinuousBatcher(params, CFG, slots=1,
                                 max_len=8).fused_prefill

    def test_prefix_shared_blocks_stay_read_only(self, params):
        """Fused prefill over an adopted (refcount>1, read-only) prefix:
        adoption changes nothing — the adopting request emits the same
        tokens as the donor — and the shared physical blocks' bytes are
        untouched by the second admission.  Checked for both prefill
        paths (the donor/adopter caches are written by the same path,
        so token equality is exact per mode)."""
        prompt = _prompt(9, 12)
        for fused in (True, False):
            cb = ContinuousBatcher(params, CFG, slots=1, max_len=20,
                                   block_size=4, prefill_chunk=4,
                                   prefix_share=True, fused_prefill=fused)
            cb.submit(Request(rid=0, prompt=list(prompt), max_new=5))
            donor_out = cb.run()[-1].out
            shared = [bid for bid in range(cb.runtime.num_blocks)
                      if cb.runtime.alloc.refcount(bid) >= 1]
            snap = [jax.tree.map(lambda x: np.asarray(x[:, shared]), c.kv)
                    for c in cb.cache]
            before = cb.prefill_quanta
            cb.submit(Request(rid=1, prompt=list(prompt), max_new=5))
            assert cb.run()[-1].out == donor_out, fused
            assert cb.prefill_quanta - before == 1   # 2 blocks adopted
            after = [jax.tree.map(lambda x: np.asarray(x[:, shared]),
                                  c.kv) for c in cb.cache]
            for s, a in zip(snap, after):
                jax.tree.map(np.testing.assert_array_equal, s, a)

    def test_cow_guarded_block_before_fused_prefill(self, params):
        """A destination block with an external reader (refcount > 1)
        must be CoW-copied before the fused kernel scatters into it;
        the shared original's bytes survive and tokens match solo."""
        req = Request(rid=0, prompt=_prompt(5, 7), max_new=4)
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=16,
                               block_size=4)
        cb.submit(Request(rid=0, prompt=list(req.prompt), max_new=4))
        cb._admit()
        bid = cb.runtime.tables[0][0]
        cb.runtime.alloc.share(bid)              # artificial reader
        snap = [jax.tree.map(lambda x: np.asarray(x[:, bid]), c.kv)
                for c in cb.cache]
        out = cb.run()[0].out
        assert cb.runtime.cow_copies == 1
        assert out == _solo(params, CFG, req)
        after = [jax.tree.map(lambda x: np.asarray(x[:, bid]), c.kv)
                 for c in cb.cache]
        for s, a in zip(snap, after):
            jax.tree.map(np.testing.assert_array_equal, s, a)
        cb.runtime.alloc.release(bid)            # drop the reader
