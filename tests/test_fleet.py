"""Replica fleet serving: cost-balanced dispatch, health-gated
eviction, and bit-exact request migration.

The LM replicas run the real paged runtime (tiny dense config), so
eviction paths exercise actual KV-block release — including
prefix-shared copy-on-write blocks on the dying replica.  Bit-exact
migration leans on the decode-step-scan prefill path
(``fused_prefill=False``), which PR 2/3 oracle tests pin to decode.
Watchdog escalation tests run on a virtual clock (measured quanta are
0 s, so injector-synthesized durations are the only signal) and are
therefore fully deterministic.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.distributed.fault_tolerance import (DRAINING, EVICTED, HEALTHY,
                                               SUSPECT, ReplicaHealth,
                                               Watchdog)
from repro.engine import (TINY_SD, Admitted, Cancelled, CostModel,
                          DiffusionEngine, EngineRouter, FaultInjector,
                          Finished, FleetManager, GenerateRequest, Preempted,
                          Progress, ReplicaFault, ReplicaSpec, init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)

# Parked-high watchdog threshold: these tests drive eviction through
# the injector (kill) or through synthesized durations on a virtual
# clock — real CPU timing must never evict a replica under test.
NO_WD = dict(watchdog_threshold=1e9)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _lm_spec(name, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("fused_prefill", False)
    return ReplicaSpec(name,
                       lambda: ContinuousBatcher(params, CFG, **kw))


def _tokens_by_rid(log):
    return {e.rid: list(e.result.out) for e in log
            if isinstance(e, Finished)}


def _reference_tokens(params, reqs, **kw):
    """Single-replica run of the same seeds: the bit-exactness oracle."""
    fleet = FleetManager([_lm_spec("solo", params, **kw)], **NO_WD)
    for r in reqs:
        fleet.submit(r)
    return _tokens_by_rid(fleet.stream())


# ---------------------------------------------------- health machine
class TestReplicaHealth:
    def test_straggler_escalation_and_recovery(self):
        h = ReplicaHealth(Watchdog(threshold=3.0), suspect_limit=2)
        assert h.observe_step(0, 1.0) == HEALTHY     # seeds EWMA
        assert h.observe_step(1, 1.0) == HEALTHY
        assert h.observe_step(2, 10.0) == SUSPECT    # one straggler
        assert h.consecutive_suspects == 1
        assert h.observe_step(3, 1.0) == HEALTHY     # clean step clears
        assert h.consecutive_suspects == 0

    def test_consecutive_stragglers_evict(self):
        h = ReplicaHealth(Watchdog(threshold=3.0), suspect_limit=2)
        h.observe_step(0, 1.0)
        assert h.observe_step(1, 10.0) == SUSPECT
        assert h.observe_step(2, 10.0) == EVICTED
        assert "watchdog" in h.reason
        assert not h.live and not h.dispatchable
        # terminal: nothing revives an evicted replica
        assert h.observe_step(3, 1.0) == EVICTED

    def test_drain_is_not_dispatchable_but_live(self):
        h = ReplicaHealth()
        h.drain()
        assert h.state == DRAINING
        assert h.live and not h.dispatchable
        h.evict("gone")      # a draining replica can still die
        assert h.state == EVICTED

    def test_evict_records_first_reason_only(self):
        h = ReplicaHealth()
        h.evict("first")
        h.evict("second")
        assert h.reason == "first"


# ---------------------------------------------------- fault injector
class TestFaultInjector:
    def test_kill_fires_exactly_at_step(self):
        inj = FaultInjector().kill("a", 3)
        inj.check("a", 2)
        inj.check("b", 3)
        with pytest.raises(ReplicaFault, match="kill of a at step 3"):
            inj.check("a", 3)

    def test_hang_and_slow_windows(self):
        inj = (FaultInjector().hang("h", 2)
               .slow("s", 1, 0.5, for_steps=2))
        assert inj.extra_s("h", 1) == 0.0
        assert inj.extra_s("h", 2) == float("inf")
        assert inj.extra_s("h", 99) == float("inf")
        assert inj.extra_s("s", 0) == 0.0
        assert inj.extra_s("s", 1) == 0.5
        assert inj.extra_s("s", 2) == 0.5
        assert inj.extra_s("s", 3) == 0.0
        assert inj.extra_s("other", 1) == 0.0


# --------------------------------------------------------- dispatch
class TestDispatch:
    def test_least_outstanding_fallback_spreads(self, params):
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)], **NO_WD)
        assert fleet.cost_model is None
        for rid in range(4):
            fleet.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                 max_new=3))
        outs = {r["name"]: r["outstanding"]
                for r in fleet.stats()["replicas"]}
        assert outs == {"a": 2, "b": 2}
        assert len(_tokens_by_rid(fleet.stream())) == 4

    def test_cost_balanced_dispatch_prefers_cheap_replica(self, params):
        """With per-replica cost models, placement is least estimated
        completion time: everything lands on the fast replica until
        its backlog exceeds one slow-replica request."""
        fast, slow = CostModel(), CostModel()
        specs = []
        for name, cm, cost in (("fast", fast, 0.01), ("slow", slow, 1.0)):
            def build(cm=cm):
                return ContinuousBatcher(params, CFG, slots=2, max_len=32,
                                         fused_prefill=False,
                                         cost_model=cm)
            specs.append(ReplicaSpec(name, build))
        probe = ContinuousBatcher(params, CFG, slots=2, max_len=32,
                                  fused_prefill=False)
        for cm, cost in ((fast, 0.01), (slow, 1.0)):
            kp, kd = cm.lm_keys(probe)
            cm.seed(kp, cost)
            cm.seed(kd, cost)
        fleet = FleetManager(specs, **NO_WD)
        for rid in range(3):
            fleet.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                 max_new=3))
        outs = {r["name"]: r["outstanding"]
                for r in fleet.stats()["replicas"]}
        # est(fast) = 4 quanta * 0.01; three requests stack to 0.12,
        # still far below one slow-replica request (~4.0).
        assert outs == {"fast": 3, "slow": 0}

    def test_duplicate_rid_rejected_fleet_wide(self, params):
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)], **NO_WD)
        fleet.submit(Request(rid=7, prompt=_prompt(0, 4), max_new=2))
        with pytest.raises(ValueError, match="duplicate rid 7"):
            fleet.submit(Request(rid=7, prompt=_prompt(1, 4), max_new=2))

    def test_no_replica_for_type_raises(self, params):
        fleet = FleetManager([_lm_spec("a", params)], **NO_WD)
        with pytest.raises(RuntimeError, match="no dispatchable"):
            fleet.submit(GenerateRequest(rid=0, tokens=[1] * 8, steps=1,
                                         seed=0))

    def test_handle_pumps_whole_fleet(self, params):
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)], **NO_WD)
        h = fleet.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=3))
        fleet.submit(Request(rid=1, prompt=_prompt(1, 4), max_new=3))
        assert h.result().outcome == "finished"
        # waiting on a handle placed on one replica still progressed
        # the other (the handle pumps FleetManager.step, not a replica)
        steps = {r["name"]: r["steps"] for r in fleet.stats()["replicas"]}
        assert all(s > 0 for s in steps.values())

    def test_unique_names_required(self, params):
        with pytest.raises(ValueError, match="unique"):
            FleetManager([_lm_spec("a", params), _lm_spec("a", params)])


# -------------------------------------------------------- migration
class TestMigration:
    def test_kill_migrates_bit_exact(self, params):
        reqs = lambda: [Request(rid=i, prompt=_prompt(i, 4), max_new=5)
                        for i in range(4)]
        want = _reference_tokens(params, reqs())
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             injector=FaultInjector().kill("a", 2),
                             **NO_WD)
        for r in reqs():
            fleet.submit(r)
        log = list(fleet.stream())
        stats = fleet.stats()
        assert ("a", "injected kill of a at step 2") in stats["evictions"]
        assert stats["migrations"] == 2 and not stats["lost"]
        assert _tokens_by_rid(log) == want
        # migrated rids resumed, never re-admitted
        admits = [e.rid for e in log if isinstance(e, Admitted)]
        assert sorted(admits) == sorted(set(admits))
        resumed = {e.rid for e in log
                   if isinstance(e, Progress) and e.phase == "resume"}
        preempted = {e.rid for e in log if isinstance(e, Preempted)}
        assert preempted and preempted <= resumed

    def test_replace_evicted_respawns_capacity(self, params):
        """``replace_evicted=True``: a kill respawns a fresh replica
        from the evicted spec's build before migration, so capacity
        recovers and the replacement can absorb evacuated work."""
        reqs = lambda: [Request(rid=i, prompt=_prompt(i, 4), max_new=5)
                        for i in range(8)]
        want = _reference_tokens(params, reqs())
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             injector=FaultInjector().kill("a", 2),
                             replace_evicted=True, **NO_WD)
        for r in reqs():
            fleet.submit(r)
        log = list(fleet.stream())
        stats = fleet.stats()
        assert _tokens_by_rid(log) == want and not stats["lost"]
        assert stats["replacements"] == [("a", "a~0")]
        live = [r for r in stats["replicas"] if r["state"] != EVICTED]
        assert sorted(r["name"] for r in live) == ["a~0", "b"]
        assert next(r for r in stats["replicas"]
                    if r["name"] == "a~0")["steps"] > 0

    def test_drained_replica_not_replaced(self, params):
        """Draining is the operator shrinking the fleet on purpose:
        no respawn even with ``replace_evicted=True``."""
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             replace_evicted=True, **NO_WD)
        fleet.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=4))
        fleet.drain("a")
        fleet.run()
        stats = fleet.stats()
        assert stats["replacements"] == []
        assert not stats["lost"]

    def test_mid_prefill_eviction_resumes_bit_exact(self, params):
        """Kill a replica after exactly one prefill chunk of a
        multi-chunk prompt: the survivor re-prefills from scratch and
        must land on identical tokens."""
        reqs = lambda: [Request(rid=0, prompt=_prompt(3, 12), max_new=4)]
        want = _reference_tokens(params, reqs())
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             injector=FaultInjector().kill("a", 1),
                             **NO_WD)
        for r in reqs():
            fleet.submit(r)       # placement tie -> replica "a" first
        log = list(fleet.stream())
        stats = fleet.stats()
        # one quantum ran (one 8-token chunk of the 12-token prompt),
        # so the kill caught the request genuinely mid-prefill
        assert stats["migrations"] == 1 and not stats["lost"]
        assert _tokens_by_rid(log) == want
        assert any(isinstance(e, Preempted) for e in log)

    def test_prefix_shared_blocks_on_dead_replica(self, params):
        """Requests whose KV blocks are copy-on-write prefix-shared on
        the dying replica migrate and finish bit-exactly; the
        survivor's pool stays consistent."""
        shared = _prompt(5, 8)
        reqs = lambda: [Request(rid=i, prompt=list(shared), max_new=4)
                        for i in range(4)]
        kw = dict(prefix_share=True, slots=2, max_len=32)
        want = _reference_tokens(params, reqs(), **kw)
        fleet = FleetManager([_lm_spec("a", params, **kw),
                              _lm_spec("b", params, **kw)],
                             injector=FaultInjector().kill("a", 3),
                             **NO_WD)
        for r in reqs():
            fleet.submit(r)
        log = list(fleet.stream())
        stats = fleet.stats()
        assert stats["migrations"] > 0 and not stats["lost"]
        assert _tokens_by_rid(log) == want
        survivor = fleet._by_name("b").engine
        survivor.runtime.check_consistency()
        assert survivor.runtime.allocated_blocks == 0

    def test_cancel_racing_eviction(self, params):
        """Cancelling a request right after its replica died must
        land on the adopting replica: terminal Cancelled, everything
        else still finishes."""
        reqs = lambda: [Request(rid=i, prompt=_prompt(i, 4), max_new=6)
                        for i in range(4)]
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             injector=FaultInjector().kill("a", 2),
                             **NO_WD)
        handles = {r.rid: fleet.submit(r) for r in reqs()}
        while not fleet.evictions:
            fleet.step()
        moved = [rid for rid, rep in fleet._owner.items()
                 if rep.spec.name == "b" and rid % 2 == 0]
        victim = moved[0]     # originally placed on "a" (even rids)
        assert fleet.cancel(victim)
        log = list(fleet.stream())
        assert handles[victim].state == "CANCELLED"
        done = _tokens_by_rid(log)
        assert set(done) == {r.rid for r in reqs()} - {victim}
        assert not fleet.stats()["lost"]

    def test_no_survivor_emits_cancelled_not_hang(self, params):
        fleet = FleetManager([_lm_spec("only", params)],
                             injector=FaultInjector().kill("only", 1),
                             **NO_WD)
        h = fleet.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=4))
        log = list(fleet.stream())
        assert fleet.stats()["lost"] == [0]
        assert h.state == "CANCELLED"
        assert isinstance(log[-1], Cancelled)

    def test_mixed_router_replicas_migrate_both_types(self, params,
                                                      sd_params):
        toks = [1] * TINY_SD.text_len

        def build():
            return EngineRouter(
                diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
                lm=ContinuousBatcher(params, CFG, slots=2, max_len=32,
                                     fused_prefill=False))
        def reqs():
            return [GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                    steps=2, seed=0),
                    GenerateRequest(rid=1, tokens=toks, sampler="ddim",
                                    steps=2, seed=1),
                    Request(rid=2, prompt=_prompt(2, 4), max_new=4),
                    Request(rid=3, prompt=_prompt(3, 4), max_new=4)]

        ref = FleetManager([ReplicaSpec("solo", build)], **NO_WD)
        for r in reqs():
            ref.submit(r)
        ref_log = list(ref.stream())
        want_img = {e.rid: np.asarray(e.result.image) for e in ref_log
                    if isinstance(e, Finished) and hasattr(e.result,
                                                           "image")}
        want_tok = {e.rid: e.result.out for e in ref_log
                    if isinstance(e, Finished) and hasattr(e.result,
                                                           "out")}
        fleet = FleetManager([ReplicaSpec("a", build),
                              ReplicaSpec("b", build)],
                             injector=FaultInjector().kill("a", 2),
                             **NO_WD)
        for r in reqs():
            fleet.submit(r)
        log = list(fleet.stream())
        assert not fleet.stats()["lost"]
        got_img = {e.rid: np.asarray(e.result.image) for e in log
                   if isinstance(e, Finished) and hasattr(e.result,
                                                          "image")}
        got_tok = {e.rid: e.result.out for e in log
                   if isinstance(e, Finished) and hasattr(e.result,
                                                          "out")}
        assert got_tok == want_tok
        assert set(got_img) == set(want_img)
        for rid in want_img:
            assert np.array_equal(got_img[rid], want_img[rid])


# -------------------------------------------------- watchdog + drain
class TestHealthGating:
    def _virtual_fleet(self, params, injector, **kw):
        t = [0.0]
        kw.setdefault("suspect_limit", 2)
        kw.setdefault("watchdog_threshold", 3.0)
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)],
                             clock=lambda: t[0], injector=injector, **kw)
        return fleet, t

    def test_hang_escalates_to_eviction(self, params):
        """A wedged replica (infinite observed quanta from step 2 on)
        walks SUSPECT -> EVICTED via the watchdog; its requests finish
        on the survivor."""
        fleet, _ = self._virtual_fleet(
            params, FaultInjector().hang("a", 2))
        for rid in range(4):
            fleet.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                 max_new=4))
        done = fleet.run()
        stats = fleet.stats()
        assert [n for n, _ in stats["evictions"]] == ["a"]
        assert "watchdog" in stats["evictions"][0][1]
        assert len(done) == 4 and not stats["lost"]

    def test_slow_window_suspects_then_recovers(self, params):
        """A bounded straggler window (one slow quantum) marks the
        replica SUSPECT, a clean quantum clears it: no eviction, no
        migration."""
        fleet, _ = self._virtual_fleet(
            params, FaultInjector().slow("a", 1, 0.5, for_steps=1))
        for rid in range(4):
            fleet.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                 max_new=4))
        done = fleet.run()
        stats = fleet.stats()
        a = fleet._by_name("a")
        assert len(a.health.watchdog.suspects) == 1
        assert a.health.state == HEALTHY
        assert not stats["evictions"] and stats["migrations"] == 0
        assert len(done) == 4

    def test_drain_stops_dispatch_and_retires(self, params):
        fleet = FleetManager([_lm_spec("a", params),
                              _lm_spec("b", params)], **NO_WD)
        fleet.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=3))
        fleet.drain("a")
        for rid in range(1, 4):
            fleet.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                 max_new=3))
        done = fleet.run()
        stats = fleet.stats()
        assert len(done) == 4
        # nothing new landed on the draining replica...
        outs = {r["name"]: r for r in stats["replicas"]}
        assert outs["b"]["steps"] > 0
        # ...its in-flight work ran to completion (no migration), and
        # it retired as a planned removal
        assert stats["migrations"] == 0 and not stats["lost"]
        assert stats["evictions"] == [("a", "drained")]
        assert outs["a"]["state"] == EVICTED

    def test_drain_unknown_name_raises(self, params):
        fleet = FleetManager([_lm_spec("a", params)], **NO_WD)
        with pytest.raises(KeyError, match="nope"):
            fleet.drain("nope")


# ------------------------------------------------- cost-model extras
class TestCostModelPersistence:
    def test_save_load_roundtrip_preserves_key_types(self, tmp_path):
        cm = CostModel(alpha=0.4)
        keys = [("lm", "t", "prefill", False, True),
                ("lm", "t", "decode", True),
                ("diff", "sd", "fused", "ddim", 8, 8, False, 2)]
        for i, k in enumerate(keys):
            cm.seed(k, 0.1 * (i + 1))
            cm.observe(k, 0.1 * (i + 1))
        p = str(tmp_path / "cm.json")
        cm.save(p)
        back = CostModel.load(p)
        assert back.alpha == 0.4
        assert back.snapshot() == cm.snapshot()
        for k in keys:       # tuple keys with exact element types
            assert back.cost(k) == cm.cost(k)

    def test_load_rejects_unknown_version(self, tmp_path):
        p = tmp_path / "cm.json"
        p.write_text('{"version": 99, "alpha": 0.3, "entries": []}')
        with pytest.raises(ValueError, match="version"):
            CostModel.load(str(p))


class TestCoBatchDiscount:
    def test_queued_same_group_amortizes_cost(self, sd_params):
        cm = CostModel()
        eng = DiffusionEngine(sd_params, TINY_SD, max_batch=2,
                              cost_model=cm)
        toks = [1] * TINY_SD.text_len
        mk = lambda rid: GenerateRequest(rid=rid, tokens=toks,
                                         sampler="ddim", steps=4, seed=rid)
        k = cm._diff_keys(eng, mk(0))
        cm.seed(k["fused"], 1.0)
        solo = cm.estimate_diffusion(eng, mk(100))
        assert solo == 1.0                       # empty queue: no sharing
        eng.submit(mk(0))
        half = cm.estimate_diffusion(eng, mk(101))
        assert half == 0.5                       # shares one launch
        eng.submit(mk(1))
        eng.submit(mk(2))
        capped = cm.estimate_diffusion(eng, mk(102))
        assert capped == 0.5                     # capped at max_batch=2
        other = GenerateRequest(rid=103, tokens=toks, sampler="ddim",
                                steps=8, seed=3)  # different group key
        ko = cm._diff_keys(eng, other)
        cm.seed(ko["fused"], 1.0)
        assert cm.estimate_diffusion(eng, other) == 1.0
