"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes per the deliverable contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.q3k_matmul import q3k_matmul
from repro.kernels.q8_matmul import q8_matmul, q8_matmul_w8a8


def _xw(m, k, n, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (n, k), dtype) * 0.05
    return x, w


@pytest.mark.parametrize("m,k,n", [(8, 64, 16), (32, 256, 64),
                                   (128, 1024, 256), (17, 512, 96)])
def test_q8_dequant_kernel_matches_oracle(m, k, n):
    x, w = _xw(m, k, n, seed=m)
    wq = quant.quantize_q8_0(w)
    want = ref.q8_matmul_ref(x, wq)
    got = q8_matmul(x, wq.qs, wq.d.astype(jnp.float32), interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("m,k,n", [(8, 64, 16), (64, 512, 128)])
def test_q8_w8a8_kernel_matches_oracle(m, k, n):
    x, w = _xw(m, k, n, seed=m + 1)
    wq = quant.quantize_q8_0(w)
    xa = quant.quantize_q8_0(x)
    xs = xa.d.astype(jnp.float32)
    want = ref.q8_matmul_w8a8_ref(xa.qs, xs, wq)
    got = q8_matmul_w8a8(xa.qs, xs, wq.qs, wq.d.astype(jnp.float32),
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("m,k,n", [(8, 256, 16), (32, 1024, 64)])
@pytest.mark.parametrize("scale_bits", [6, 5])
def test_q3k_kernel_matches_oracle(m, k, n, scale_bits):
    x, w = _xw(m, k, n, seed=m + 2)
    wq = quant.quantize_q3_k(w, scale_bits=scale_bits)
    want = ref.q3k_matmul_ref(x, wq)
    sc = quant.unpack_scales6(wq.scales).reshape(n, -1)
    got = q3k_matmul(x, wq.ql, wq.qh, sc, wq.d.astype(jnp.float32),
                     interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_oracle(dtype, causal, window):
    b, h, s, d = 2, 4, 256, 32
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(kq, (b, h, s, d), dtype) * 0.5
    k = jax.random.normal(kk, (b, h, s, d), dtype) * 0.5
    v = jax.random.normal(kv, (b, h, s, d), dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5, rtol=1e-2)


def test_flash_attention_cross_lengths():
    """Sq != Sk (decode-style suffix attention)."""
    b, h, sq, sk, d = 1, 2, 64, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d)) * 0.3
    k = jax.random.normal(ks[1], (b, h, sk, d)) * 0.3
    v = jax.random.normal(ks[2], (b, h, sk, d))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_chunked_attention_matches_ref():
    b, h, s, d = 1, 2, 512, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, h, s, d)) * 0.4
    k = jax.random.normal(ks[1], (b, h, s, d)) * 0.4
    v = jax.random.normal(ks[2], (b, h, s, d))
    want = ref.flash_attention_ref(q, k, v, causal=True)
    got = ops._chunked_attention(q, k, v, causal=True, window=None,
                                 scale=d ** -0.5, q_chunk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_quantized_matmul_dispatch_gqa_and_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 3, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(9), (64, 128)) * 0.1
    wq = quant.quantize_q8_0(w)
    y = ops.quantized_matmul(x, wq)
    assert y.shape == (2, 3, 64) and y.dtype == jnp.bfloat16
    # GQA fold in ops.attention
    q = jax.random.normal(jax.random.PRNGKey(10), (1, 8, 16, 32))
    k = jax.random.normal(jax.random.PRNGKey(11), (1, 2, 16, 32))
    v = jax.random.normal(jax.random.PRNGKey(12), (1, 2, 16, 32))
    out = ops.attention(q, k, v)
    assert out.shape == q.shape
