"""Paged KV-cache runtime tests: allocator/prefix-cache units, paged
flash-decode kernel vs oracle, multi-wave bit-exactness (recycled slots
equal solo decode, incl. quantized KV), chunked-vs-one-shot prefill,
decode-quanta accounting, stale-read poisoning, prefix reuse,
round-robin fairness, and exact ``required_len`` sizing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.flash_decode import (flash_decode_paged,
                                        flash_decode_paged_ref)
from repro.models.transformer import init_lm
from repro.serving import (BlockAllocator, ContinuousBatcher,
                           PagedKVRuntime, Request)

pytestmark = pytest.mark.serving

# head_dim 32 so quantized KV (Q8_0 blocks of 32) applies.
CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=32)
HYBRID = ModelConfig(name="h", family="hybrid", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                     head_dim=32, block_pattern=("attn", "mamba"),
                     ssm_state=8)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def hybrid_params():
    return init_lm(jax.random.PRNGKey(3), HYBRID)


def _prompt(seed: int, n: int) -> list[int]:
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _solo(params, cfg, req: Request, **kw) -> list[int]:
    cb = ContinuousBatcher(params, cfg, slots=1,
                           max_len=ContinuousBatcher.required_len(
                               1, 1, len(req.prompt), req.max_new), **kw)
    cb.submit(Request(rid=req.rid, prompt=list(req.prompt),
                      max_new=req.max_new, eos=req.eos))
    return cb.run()[0].out


# ------------------------------------------------------------ allocator
class TestAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(8)            # block 0 reserved
        assert a.num_free == 7
        got = a.alloc(3)
        assert got is not None and len(set(got)) == 3
        assert 0 not in got and a.num_free == 4
        assert a.alloc(5) is None        # atomic: all-or-nothing
        assert a.num_free == 4
        for bid in got:
            assert a.release(bid)
        assert a.num_free == 7

    def test_refcounted_sharing(self):
        a = BlockAllocator(4)
        (bid,) = a.alloc(1)
        a.share(bid)
        assert a.refcount(bid) == 2
        assert not a.release(bid)        # one reader left
        assert a.release(bid)            # now actually freed
        with pytest.raises(ValueError):
            a.release(bid)

    def test_null_block_never_allocated(self):
        a = BlockAllocator(3)
        assert set(a.alloc(2)) == {1, 2}

    def test_is_free_tracks_lifecycle(self):
        a = BlockAllocator(4)
        (bid,) = a.alloc(1)
        assert not a.is_free(bid)
        a.share(bid)
        a.release(bid)                   # one reader left: still live
        assert not a.is_free(bid)
        a.release(bid)
        assert a.is_free(bid)
        with pytest.raises(ValueError):  # free block has no refs to add
            a.share(bid)


class TestRuntime:
    def test_admit_release_recycles_blocks(self):
        rt = PagedKVRuntime(slots=2, max_len=32, block_size=8)
        assert rt.admit(0, _prompt(0, 10), 6) == 0
        used = rt.allocated_blocks
        assert used == 2                 # ceil((10+6-1)/8)
        rt.release(0)
        assert rt.allocated_blocks == 0
        assert rt.pos[0] == 0
        assert all(b == 0 for b in rt.tables[0])
        assert rt.admit(1, _prompt(1, 4), 4) == 0
        assert rt.allocated_blocks == 1

    def test_copy_on_write(self):
        copies = []
        rt = PagedKVRuntime(slots=2, max_len=16, block_size=8,
                            copy_block=lambda s, d: copies.append((s, d)))
        rt.admit(0, _prompt(0, 8), 4)
        # Artificially share slot 0's first block into slot 1's table.
        bid = rt.tables[0][0]
        rt.alloc.share(bid)
        rt.tables[1][0] = bid
        rt._owned[1] = 1
        new = rt.ensure_writable(1, 0)
        assert new != bid and copies == [(bid, new)]
        assert rt.alloc.refcount(bid) == 1          # slot 0 keeps its copy
        assert rt.ensure_writable(0, 0) == bid      # no further copy
        assert rt.cow_copies == 1

    def test_consistency_guard_catches_freed_live_block(self):
        """The refcount/free-ordering invariant: a block must never sit
        in the free list while a live table still points at it."""
        rt = PagedKVRuntime(slots=2, max_len=32, block_size=8)
        rt.admit(0, _prompt(0, 10), 6)
        rt.check_consistency()           # normal flow: invariant holds
        bid = rt.tables[0][0]
        rt.alloc.release(bid)            # freed under the table's feet
        with pytest.raises(AssertionError, match="AND free"):
            rt.check_consistency()

    def test_consistency_guard_runs_on_admit_and_release(self):
        rt = PagedKVRuntime(slots=2, max_len=32, block_size=8)
        rt.admit(0, _prompt(0, 10), 6)
        bid = rt.tables[0][0]
        rt.alloc.release(bid)
        with pytest.raises(AssertionError):
            rt.admit(1, _prompt(1, 4), 4)   # guard fires inside admit


# ---------------------------------------------------------- paged kernel
class TestPagedFlashDecode:
    @pytest.mark.parametrize("positions", [[0, 5], [17, 9], [23, 23]])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, positions, dtype):
        b, h, g, d, bs, nb = 2, 2, 4, 32, 8, 9
        ks = jax.random.split(jax.random.PRNGKey(sum(positions)), 3)
        q = jax.random.normal(ks[0], (b, h, g, d), dtype) * 0.4
        kp = jax.random.normal(ks[1], (nb, h, bs, d), dtype) * 0.4
        vp = jax.random.normal(ks[2], (nb, h, bs, d), dtype)
        tbl = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
        pos = jnp.asarray(positions, jnp.int32)
        want = flash_decode_paged_ref(q, kp, vp, tbl, pos)
        got = flash_decode_paged(q, kp, vp, tbl, pos, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=3e-2 if dtype == jnp.bfloat16 else 2e-5, rtol=1e-2)

    def test_gather_ignores_unlisted_blocks(self):
        """Poisoned pool blocks outside the table must not leak in."""
        b, h, g, d, bs, nb = 1, 2, 4, 32, 8, 6
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, g, d))
        kp = jax.random.normal(ks[1], (nb, h, bs, d))
        vp = jax.random.normal(ks[2], (nb, h, bs, d))
        poison = jnp.full((h, bs, d), jnp.nan)
        kp = kp.at[4].set(poison).at[5].set(poison)
        vp = vp.at[4].set(poison).at[5].set(poison)
        tbl = jnp.array([[1, 2, 3]], jnp.int32)
        out = flash_decode_paged(q, kp, vp, tbl,
                                 jnp.array([20], jnp.int32),
                                 interpret=True)
        assert bool(jnp.isfinite(out.astype(jnp.float32)).all())

    def test_masked_tail_of_listed_block_is_neutralized(self):
        """A recycled block's stale tail (positions past the row's
        position, *inside* a listed block) must not poison the output:
        masked p is 0 but 0 * NaN = NaN without value neutralization."""
        b, h, g, d, bs, nb = 1, 2, 4, 32, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, h, g, d))
        kp = jax.random.normal(ks[1], (nb, h, bs, d))
        vp = jax.random.normal(ks[2], (nb, h, bs, d))
        pos = 10                          # block 1 offsets 3.. are stale
        kp = kp.at[2, :, 3:].set(jnp.nan)
        vp = vp.at[2, :, 3:].set(jnp.nan)
        tbl = jnp.array([[1, 2]], jnp.int32)
        want = flash_decode_paged_ref(
            q, jnp.nan_to_num(kp), jnp.nan_to_num(vp), tbl,
            jnp.array([pos], jnp.int32))
        got = flash_decode_paged(q, kp, vp, tbl,
                                 jnp.array([pos], jnp.int32),
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-5)


# ----------------------------------------------------- multi-wave decode
class TestMultiWaveExactness:
    @pytest.mark.parametrize("quantized_kv", [False, True])
    def test_recycled_slot_matches_solo(self, params, quantized_kv):
        """Second-wave requests (recycled slots) must be token-for-token
        identical to decoding each request alone — the seed's documented
        stale-KV hole."""
        reqs = [Request(rid=r, prompt=_prompt(r, 5 + r % 3), max_new=6)
                for r in range(5)]
        cb = ContinuousBatcher(params, CFG, slots=2, max_len=16,
                               quantized_kv=quantized_kv)
        for r in reqs:
            cb.submit(Request(rid=r.rid, prompt=list(r.prompt),
                              max_new=r.max_new))
        done = {r.rid: r.out for r in cb.run()}
        assert sorted(done) == [0, 1, 2, 3, 4]
        for r in reqs:
            assert done[r.rid] == _solo(params, CFG, r,
                                        quantized_kv=quantized_kv), r.rid

    def test_recycled_slot_matches_solo_hybrid(self, hybrid_params):
        """Recurrent (mamba) state must be reset on admission too."""
        reqs = [Request(rid=r, prompt=_prompt(10 + r, 4), max_new=5)
                for r in range(3)]
        cb = ContinuousBatcher(hybrid_params, HYBRID, slots=1, max_len=12)
        for r in reqs:
            cb.submit(Request(rid=r.rid, prompt=list(r.prompt),
                              max_new=r.max_new))
        done = {r.rid: r.out for r in cb.run()}
        for r in reqs:
            assert done[r.rid] == _solo(hybrid_params, HYBRID, r), r.rid

    def test_freed_blocks_poisoned_no_stale_reads(self, params):
        """Regression: a freed-and-reused slot never reads bytes written
        by its previous occupant.  After wave 1 retires, poison every
        free pool block with NaN; wave 2 must still match solo decode —
        any stale/out-of-table read would surface as NaN garbage."""
        first = Request(rid=0, prompt=_prompt(0, 6), max_new=4)
        second = Request(rid=1, prompt=_prompt(1, 6), max_new=4)
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=16,
                               block_size=4)
        cb.submit(Request(rid=0, prompt=list(first.prompt), max_new=4))
        cb.run()
        free = cb.runtime.free_block_ids()
        assert free                       # wave 1's blocks came back
        idx = jnp.asarray(free, jnp.int32)
        cb.cache = [c._replace(kv=jax.tree.map(
            lambda x: x.at[:, idx].set(
                jnp.full_like(x[:, idx], jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.floating) else 127),
            c.kv)) for c in cb.cache]
        cb.submit(Request(rid=1, prompt=list(second.prompt), max_new=4))
        out = cb.run()[-1].out
        assert out == _solo(params, CFG, second)

    def test_mid_wave_recycled_block_clean_in_fused_prefill(self, params):
        """Regression for the fused-prefill path: a block freed when a
        request retires MID-wave (another slot still decoding) and then
        reallocated to a newly admitted, prefilling slot must not leak
        the previous occupant's KV into the fused kernel's output.
        Free blocks are NaN/127-poisoned at the recycle point; any
        stale read would surface as NaN garbage or wrong tokens."""
        short = Request(rid=0, prompt=_prompt(20, 4), max_new=2)
        long = Request(rid=1, prompt=_prompt(21, 6), max_new=7)
        late = Request(rid=2, prompt=_prompt(22, 9), max_new=4)
        # Pool sized so `late` (3 blocks) can only be admitted by
        # taking `short`'s recycled blocks (7 allocatable: short 2,
        # long 3, 1 spare).
        cb = ContinuousBatcher(params, CFG, slots=2, max_len=12,
                               block_size=4)
        assert cb.fused_prefill
        for r in (short, long, late):
            cb.submit(Request(rid=r.rid, prompt=list(r.prompt),
                              max_new=r.max_new))
        while not cb.finished:           # run until `short` retires
            assert cb.step()
        assert cb.finished[0].rid == 0
        assert cb.slots[1] is not None   # `long` still mid-decode
        free = cb.runtime.free_block_ids()
        assert free                      # short's blocks came back
        idx = jnp.asarray(free, jnp.int32)
        cb.cache = [c._replace(kv=jax.tree.map(
            lambda x: x.at[:, idx].set(
                jnp.full_like(x[:, idx], jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.floating) else 127),
            c.kv)) for c in cb.cache]
        cb.step()                        # admits `late` mid-wave
        owned = cb.runtime.tables[0][:3]
        assert set(owned) & set(free)    # genuinely recycled blocks
        done = {r.rid: r.out for r in cb.run()}
        assert done[2] == _solo(params, CFG, late)   # fused over recycled
        assert done[1] == _solo(params, CFG, long)

    def test_chunked_prefill_equals_one_shot(self, params):
        """Chunk boundaries must not change anything: prefill in chunks
        of 2 == one-shot prefill of the whole prompt."""
        req = Request(rid=0, prompt=_prompt(7, 9), max_new=5)
        outs = []
        for chunk in (2, 4, len(req.prompt)):
            cb = ContinuousBatcher(params, CFG, slots=1, max_len=16,
                                   prefill_chunk=chunk)
            cb.submit(Request(rid=0, prompt=list(req.prompt), max_new=5))
            outs.append(cb.run()[0].out)
            assert cb.prefill_quanta == -(-len(req.prompt) // chunk)
        assert outs[0] == outs[1] == outs[2]

    def test_prefill_does_not_consume_decode_quanta(self, params):
        """The acceptance criterion: for a fixed workload the decode
        step count drops vs the old replay-through-decode admission,
        which burned (prompt_len - 1) + max_new decode steps per
        request (prompt feed was teacher-forced decode)."""
        prompt, max_new = _prompt(3, 12), 6
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=20,
                               prefill_chunk=4)
        cb.submit(Request(rid=0, prompt=list(prompt), max_new=max_new))
        (req,) = cb.run()
        replay_decode_steps = (len(prompt) - 1) + max_new
        assert cb.decode_quanta == max_new - 1 < replay_decode_steps
        assert cb.prefill_quanta == 3     # ceil(12 / 4)
        assert req.prefill_steps == 3 and req.decode_steps == max_new - 1
        assert cb.last_quantum == ("decode", 1)


# ---------------------------------------------------------- prefix reuse
class TestPrefixReuse:
    def test_shared_prefix_skips_prefill(self, params):
        """A retired prompt's full blocks are adopted by the next
        request with the same prefix: fewer prefill quanta, identical
        output."""
        prompt = _prompt(9, 12)
        outs, quanta = [], []
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=20,
                               block_size=4, prefill_chunk=4,
                               prefix_share=True)
        for rid in range(2):
            before = cb.prefill_quanta
            cb.submit(Request(rid=rid, prompt=list(prompt), max_new=5))
            outs.append(cb.run()[-1].out)
            quanta.append(cb.prefill_quanta - before)
        assert outs[0] == outs[1]
        # 12 tokens: full blocks 0..1 reusable (block 2 holds the last
        # prompt token -> always recomputed): 3 chunks down to 1.
        assert quanta == [3, 1]
        assert cb.runtime.prefix is not None
        assert cb.runtime.prefix.hits == 2

    def test_prefix_share_rejects_recurrent_models(self, hybrid_params):
        with pytest.raises(ValueError, match="pure-attention"):
            ContinuousBatcher(hybrid_params, HYBRID, slots=1, max_len=8,
                              prefix_share=True)


# -------------------------------------------------------------- fairness
class TestFairness:
    def test_round_robin_across_groups(self, params):
        """ROADMAP head-of-line item: group 1 must not wait for ALL of
        group 0's backlog (strict FIFO would admit a,b,c before x)."""
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=8)
        for rid, group in ((0, 0), (1, 0), (2, 0), (3, 1), (4, 1)):
            cb.submit(Request(rid=rid, prompt=_prompt(rid, 3), max_new=2,
                              group=group))
        done = [r.rid for r in cb.run()]
        assert sorted(done) == [0, 1, 2, 3, 4]
        # Interleaved: one from each group alternately.
        assert done.index(3) < done.index(1)
        assert done.index(1) < done.index(4) < done.index(2)

    def test_single_group_keeps_fifo(self, params):
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=8)
        for rid in range(3):
            cb.submit(Request(rid=rid, prompt=_prompt(rid, 3), max_new=2))
        assert [r.rid for r in cb.run()] == [0, 1, 2]


# ---------------------------------------------------------------- sizing
class TestRequiredLen:
    def test_wave_independent_and_exact(self):
        # Old sizing multiplied by admission waves; per-slot positions
        # make capacity a per-request quantity.
        assert ContinuousBatcher.required_len(1, 1, 8, 4) == 11
        assert ContinuousBatcher.required_len(100, 2, 8, 4) == 11

    def test_exact_capacity_completes_all_waves(self, params):
        """max_len == required_len must serve every wave full-length —
        the seed silently truncated late waves when undersized."""
        prompt_len, max_new = 6, 4
        cb = ContinuousBatcher(
            params, CFG, slots=2,
            max_len=ContinuousBatcher.required_len(5, 2, prompt_len,
                                                   max_new))
        for rid in range(5):
            cb.submit(Request(rid=rid, prompt=_prompt(rid, prompt_len),
                              max_new=max_new))
        done = cb.run()
        assert len(done) == 5
        assert all(len(r.out) == max_new for r in done)

    def test_oversized_prompt_rejected(self, params):
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=8)
        with pytest.raises(ValueError, match="capacity"):
            cb.submit(Request(rid=0, prompt=_prompt(0, 9), max_new=2))

    def test_over_budget_request_rejected_not_truncated(self, params):
        """prompt + max_new beyond capacity is a sizing bug: reject at
        submit instead of retiring a silently truncated output."""
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=16)
        with pytest.raises(ValueError, match="capacity"):
            cb.submit(Request(rid=0, prompt=_prompt(0, 15), max_new=16))
        # Exactly at budget is fine.
        cb.submit(Request(rid=1, prompt=_prompt(1, 13), max_new=4))
        (req,) = cb.run()
        assert len(req.out) == 4
