"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: instantiate the reduced same-family config, run
one forward and one train step on CPU, assert output shapes + no NaNs
(deliverable f).  Decode-vs-forward exactness is checked per family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced, smoke_inputs
from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.models.transformer import init_cache, init_lm, lm_decode_step, \
    lm_forward
from repro.train.train_step import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    batch = smoke_inputs(key, cfg, batch=2, seq=16)
    logits, aux = lm_forward(params, cfg, batch["tokens"],
                             enc_embeds=batch.get("enc_embeds"),
                             prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"

    tcfg = TrainConfig()
    params, opt, comp = init_train_state(key, cfg, tcfg, init_lm)
    step = jax.jit(make_train_step(cfg, tcfg))
    params, opt, comp, metrics = step(params, opt, comp, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["loss"]) > 0


_FAMILY_CFGS = {
    "dense_gqa": ModelConfig(
        name="t-dense", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96, head_dim=16),
    "swa": ModelConfig(
        name="t-swa", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96, head_dim=16,
        sliding_window=8),
    # capacity_factor=4: no token drops, so decode (seq=1 groups, never
    # drops) and forward (seq-level capacity) match exactly — parity is
    # only defined for dropless routing.
    "moe": ModelConfig(
        name="t-moe", family="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=96, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=1, expert_ff=64,
                      capacity_factor=4.0)),
    "hybrid": ModelConfig(
        name="t-hyb", family="hybrid", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96, head_dim=16,
        block_pattern=("attn", "mamba", "mamba", "mamba"), ssm_state=8),
    "xlstm": ModelConfig(
        name="t-xl", family="ssm", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=96, head_dim=16,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm")),
    "encdec": ModelConfig(
        name="t-wh", family="audio", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=96, head_dim=16,
        encoder_layers=2, encoder_seq=32, pos_embed="sinusoidal",
        norm="layernorm", activation="gelu"),
}


@pytest.mark.parametrize("family", list(_FAMILY_CFGS))
def test_decode_matches_forward(family):
    """Sequential one-token decode must reproduce the full forward
    logits exactly (KV cache, ring buffer, recurrent states, cross-KV
    are all exercised)."""
    cfg = _FAMILY_CFGS[family]
    key = jax.random.PRNGKey(1)
    params = init_lm(key, cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    enc = None
    if cfg.is_enc_dec:
        enc = jax.random.normal(jax.random.PRNGKey(3),
                                (b, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    full, _ = lm_forward(params, cfg, toks, enc_embeds=enc)
    cache = init_cache(params, cfg, b, max_len=32, enc_embeds=enc)
    outs = []
    for t in range(s):
        lg, cache = lm_decode_step(params, cfg, toks[:, t:t + 1],
                                   jnp.int32(t), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    # Decode computes attention products on bf16 operands (f32 accum) —
    # the production cache dtype — so allow bf16-rounding-scale drift
    # but require near-total greedy-token agreement.
    assert float(jnp.max(jnp.abs(dec - full))) < 0.08, family
    agree = float(jnp.mean(jnp.argmax(dec, -1) == jnp.argmax(full, -1)))
    assert agree >= 0.95, (family, agree)


def test_quantized_kv_cache_decode_close():
    cfg = _FAMILY_CFGS["dense_gqa"]
    # head_dim must divide the Q8 block for quantized KV.
    cfg = dataclasses.replace(cfg, head_dim=32)
    params = init_lm(jax.random.PRNGKey(4), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0,
                              cfg.vocab_size)
    full, _ = lm_forward(params, cfg, toks)
    cache = init_cache(params, cfg, b, max_len=16, quantized_kv=True)
    outs = []
    for t in range(s):
        lg, cache = lm_decode_step(params, cfg, toks[:, t:t + 1],
                                   jnp.int32(t), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    # int8 KV: small, bounded divergence.
    rel = float(jnp.linalg.norm(dec - full) / jnp.linalg.norm(full))
    assert rel < 0.05, rel


def test_sliding_window_ring_buffer_bounded():
    cfg = _FAMILY_CFGS["swa"]
    params = init_lm(jax.random.PRNGKey(6), cfg)
    cache = init_cache(params, cfg, 1, max_len=64)
    # Capacity must be the window, not max_len.
    assert cache[0].kv.k.shape[3] == cfg.sliding_window
