"""MoE routing/dispatch unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not in the minimal CPU image
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import apply_moe, init_moe


def _cfg(e=4, k=2, shared=0, cf=2.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
        moe=MoEConfig(num_experts=e, top_k=k, num_shared=shared,
                      expert_ff=48, capacity_factor=cf))


def test_moe_shapes_and_finite():
    cfg = _cfg(shared=1)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.bfloat16)
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())
    assert float(aux) >= 0


def test_moe_differentiable():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.bfloat16)

    def loss(pp):
        y, aux = apply_moe(pp, cfg, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux
    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(jnp.abs(t.astype(jnp.float32))))
             for t in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_capacity_dropping_bounds_work():
    """With a tiny capacity factor most tokens drop, output stays
    finite and bounded (dropped tokens contribute zero)."""
    cfg = _cfg(cf=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32), jnp.bfloat16)
    y, _ = apply_moe(p, cfg, x)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_identical_tokens_identical_outputs():
    """Permutation-consistency: identical token vectors must produce
    identical outputs (unless differentially dropped, so use cf big
    enough that nothing drops)."""
    cfg = _cfg(cf=4.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    tok = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 32), jnp.bfloat16)
    x = jnp.tile(tok, (1, 6, 1))
    y, _ = apply_moe(p, cfg, x)
    y = np.asarray(y.astype(jnp.float32))
    np.testing.assert_allclose(y[0, 1:], np.tile(y[0, :1], (5, 1)),
                               atol=1e-3)


@given(st.integers(2, 8), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_router_aux_loss_scales(e, k):
    k = min(k, e)
    cfg = _cfg(e=e, k=k)
    p = init_moe(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 32), jnp.bfloat16)
    _, aux = apply_moe(p, cfg, x)
    # Switch aux loss >= coef (perfect balance gives exactly coef).
    assert float(aux) >= cfg.moe.router_aux_coef * 0.99
