"""Unit tests for the `repro.obs` telemetry layer.

Registry semantics (labels, kinds, buckets, exposition formats),
trace assembly from synthetic bus events, the Telemetry facade's
event-derived metrics, the cost-model estimate-vs-actual error
histogram (and that the error *shrinks* as the EWMA refines), the
injectable `StepTimer` clock, and `ReplicaHealth` transition
counters.  End-to-end engine consistency (bit-identical events with
telemetry attached, span/counter reconciliation) is gated by
`benchmarks/obs_smoke.py`.
"""
import dataclasses
import json

import pytest

from benchmarks.common import parse_row, validate_record
from repro.distributed.fault_tolerance import (EVICTED, HEALTHY,
                                               SUSPECT, ReplicaHealth,
                                               StepTimer, Watchdog)
from repro.engine.costmodel import CostModel
from repro.obs import (DEFAULT_ERROR_BUCKETS, MetricsRegistry,
                       Telemetry, TraceRecorder)


# Synthetic bus events: the recorder/telemetry dispatch on class
# *names*, so these stand in for repro.engine.events without jax.
def _ev(name, rid, ts, **fields):
    cls = dataclasses.make_dataclass(name, ["rid", "ts", "seq",
                                            *fields])
    return cls(rid, ts, 0, *fields.values())


class _Bus:
    def __init__(self):
        self._subs = []
        self.log = []

    def subscribe(self, fn):
        self._subs.append(fn)
        return fn

    def emit(self, ev):
        self.log.append(ev)
        for fn in self._subs:
            fn(ev)


class TestRegistry:
    def test_counter_labels_and_values(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests", labels=("engine",))
        c.inc(engine="lm")
        c.inc(2, engine="lm")
        c.inc(engine="diffusion")
        assert c.value(engine="lm") == 3
        assert c.value(engine="diffusion") == 1
        assert c.value(engine="never") == 0
        assert c.samples() == {("lm",): 3.0, ("diffusion",): 1.0}

    def test_counter_rejects_negative(self):
        c = MetricsRegistry().counter("c")
        with pytest.raises(ValueError, match="negative"):
            c.inc(-1)

    def test_label_mismatch_raises(self):
        c = MetricsRegistry().counter("c", labels=("a", "b"))
        with pytest.raises(ValueError, match="labels"):
            c.inc(a="x")                       # missing b
        with pytest.raises(ValueError, match="labels"):
            c.inc(a="x", b="y", z="typo")

    def test_get_or_create_and_kind_conflicts(self):
        reg = MetricsRegistry()
        c = reg.counter("m", labels=("a",))
        assert reg.counter("m", labels=("a",)) is c
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("m", labels=("a",))      # kind conflict
        with pytest.raises(ValueError, match="already registered"):
            reg.counter("m", labels=("b",))    # label-set conflict

    def test_gauge_set_inc_dec(self):
        g = MetricsRegistry().gauge("depth")
        g.set(5)
        g.inc()
        g.dec(2)
        assert g.value() == 4

    def test_histogram_buckets_and_moments(self):
        h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.1, 0.5, 2.0):        # 0.1 lands in le=0.1
            h.observe(v)
        assert h.count() == 4
        assert h.sum() == pytest.approx(2.65)
        assert h.buckets() == {0.1: 2, 1.0: 3, float("inf"): 4}

    def test_histogram_bad_buckets_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="strictly increasing"):
            reg.histogram("h", buckets=(1.0, 1.0))
        with pytest.raises(ValueError, match="strictly increasing"):
            reg.histogram("h2", buckets=())

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", "all requests",
                    labels=("engine",)).inc(engine="lm")
        reg.histogram("lat", "latency", buckets=(0.1,)).observe(0.05)
        text = reg.to_prometheus()
        assert "# HELP reqs_total all requests" in text
        assert "# TYPE reqs_total counter" in text
        assert 'reqs_total{engine="lm"} 1' in text
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_sum 0.05" in text and "lat_count 1" in text

    def test_snapshot_matches_bench_schema(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("c", "help, with comma",
                    labels=("k",)).inc(k="v")
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        reg.histogram("ph", labels=("engine", "phase"),
                      buckets=(1.0,)).observe(0.5, engine="lm",
                                              phase="decode")
        rec = reg.snapshot_record(suite="obs", bench="metrics")
        validate_record(rec)                   # benchmarks/common.py
        names = {e["name"] for e in rec["entries"]}
        assert {'c{k="v"}', "h_count", "h_sum",
                'ph_count{engine="lm";phase="decode"}'} <= names
        # multi-label names must stay comma-free (parse_row 2-split)
        assert all("," not in n for n in names)
        # The printed-row form parses like any benchmark row.
        for row in reg.rows():
            parse_row(row, bench="metrics")
        path = str(tmp_path / "snap.json")
        reg.write_snapshot(path)
        with open(path) as f:
            validate_record(json.load(f))


class TestTraceRecorder:
    def test_lifecycle_spans_from_bus_events(self):
        tr = TraceRecorder()
        bus = _Bus()
        tr.attach(bus)
        tr.note_submit(7, 0.0, kind="lm")
        bus.emit(_ev("Admitted", 7, 0.02, slot=1))
        bus.emit(_ev("TokenDelta", 7, 0.03, token=5, pos=0))
        bus.emit(_ev("Finished", 7, 0.04, result=None))
        root, children = tr.request_tree(7)
        assert root.start == 0.0 and root.end == 0.04
        assert root.args["outcome"] == "finished"
        assert [s.name for s in children] == ["queue_wait"]
        assert children[0].end == 0.02 and children[0].cat == "lm"
        assert [m.name for m in tr.markers] == ["token"]
        assert tr.outcome(7) == "finished" and tr.rids() == [7]

    def test_unsubmitted_rid_and_rejection(self):
        tr = TraceRecorder()
        tr.on_event(_ev("Rejected", 3, 0.5, reason="infeasible",
                        estimated_s=1.0, budget_s=0.1))
        root, children = tr.request_tree(3)
        assert root.args["outcome"] == "rejected" and not children
        assert root.start == root.end == 0.5   # no submit mark: first ev

    def test_phase_emits_engine_and_rid_spans(self):
        tr = TraceRecorder()
        tr.note_submit(1, 0.0)
        tr.phase("lm", "decode", 0.1, 0.2, rids=(1, 2),
                 args={"batch": 2})
        eng = [s for s in tr.spans if s.rid is None]
        assert len(eng) == 1 and eng[0].args["rids"] == [1, 2]
        assert [s.name for s in tr.request_spans(1)] == ["decode"]
        assert [s.name for s in tr.request_spans(2)] == ["decode"]
        # phase marks upgrade the rid's engine kind for thread naming
        assert tr._req[1]["kind"] == "lm"

    def test_chrome_export_structure(self, tmp_path):
        tr = TraceRecorder()
        tr.note_submit(0, 0.0, kind="lm")
        tr.on_event(_ev("Admitted", 0, 0.01, slot=0))
        tr.phase("lm", "decode", 0.01, 0.02, rids=(0,))
        tr.on_event(_ev("Finished", 0, 0.02, result=None))
        path = str(tmp_path / "trace.json")
        tr.export(path)
        with open(path) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        phs = [e["ph"] for e in evs]
        assert phs.count("X") == len(tr.spans)
        assert "M" in phs                      # thread_name metadata
        xs = [e for e in evs if e["ph"] == "X"]
        assert all(e["ts"] >= 0 and e["dur"] >= 0 and "pid" in e
                   for e in xs)
        # engine-track span rides a synthetic tid, not a rid row
        eng_x = [e for e in xs if e["name"] == "decode"
                 and e["tid"] >= 1_000_000]
        assert len(eng_x) == 1


class TestTelemetry:
    def test_event_derived_metrics(self):
        tele = Telemetry(tracer=TraceRecorder())
        bus = _Bus()
        tele.attach(bus)
        tele.request_submitted(0, "lm", 0.0)
        bus.emit(_ev("Admitted", 0, 0.05, slot=0))
        bus.emit(_ev("TokenDelta", 0, 0.06, token=1, pos=0))
        bus.emit(_ev("Preempted", 0, 0.07, reason="budget"))
        bus.emit(_ev("Finished", 0, 0.08, result=None))
        reg = tele.registry
        assert reg.get("requests_submitted_total").value(engine="lm") \
            == 1
        assert reg.get("events_total").value(type="TokenDelta") == 1
        assert reg.get("tokens_emitted_total").value() == 1
        assert reg.get("preemptions_total").value() == 1
        assert reg.get("requests_terminal_total").value(
            engine="lm", outcome="finished") == 1
        qw = reg.get("queue_wait_seconds")
        assert qw.count(engine="lm") == 1
        assert qw.sum(engine="lm") == pytest.approx(0.05)
        # one subscription also fed the tracer
        root, _ = tele.tracer.request_tree(0)
        assert root is not None

    def test_unsubmitted_terminal_counts_as_unknown(self):
        tele = Telemetry()
        bus = _Bus()
        tele.attach(bus)
        bus.emit(_ev("Cancelled", 9, 1.0))
        assert tele.registry.get("requests_terminal_total").value(
            engine="unknown", outcome="cancelled") == 1

    def test_phase_feeds_histogram_and_tracer(self):
        tele = Telemetry(tracer=TraceRecorder())
        tele.phase("diffusion", "unet_step", 1.0, 1.25, rids=(4,),
                   args={"step": 1})
        h = tele.registry.get("phase_seconds")
        assert h.count(engine="diffusion", phase="unet_step") == 1
        assert h.sum(engine="diffusion",
                     phase="unet_step") == pytest.approx(0.25)
        assert [s.name for s in tele.tracer.request_spans(4)] == \
            ["unet_step"]


class TestCostModelErrorHistogram:
    """Satellite: estimate-vs-actual relative error is recorded per
    phase key and *shrinks* as the EWMA refines a bad seed."""

    KEY = ("lm", "m", "decode", False)

    def _errors(self, seed, actuals, alpha=0.3):
        """Relative errors the histogram should have observed."""
        cur, errs = seed, []
        for a in actuals:
            errs.append(abs(a - cur) / cur)
            cur = (1 - alpha) * cur + alpha * a
        return errs

    def test_error_recorded_and_shrinks(self):
        reg = MetricsRegistry()                # bare registry sink
        cm = CostModel()
        cm.metrics = reg
        cm.seed(self.KEY, 0.100)               # 5x over-estimate
        actuals = [0.020] * 20
        for a in actuals:
            cm.observe(self.KEY, a)
        h = reg.get("cost_model_rel_error")
        assert h.count(engine="lm", model="m", phase="decode") == 20
        assert h.bucket_bounds == DEFAULT_ERROR_BUCKETS
        errs = self._errors(0.100, actuals)
        assert h.sum(engine="lm", model="m",
                     phase="decode") == pytest.approx(sum(errs))
        assert errs[0] > 0.5 and errs[-1] < 0.01   # EWMA converged
        cum = h.buckets(engine="lm", model="m", phase="decode")
        assert cum[0.05] == sum(e <= 0.05 for e in errs) >= 7
        assert cum[float("inf")] - cum[0.5] >= 1   # the bad first ones

    def test_first_observation_has_no_estimate(self):
        reg = MetricsRegistry()
        cm = CostModel()
        cm.metrics = reg
        cm.observe(("lm", "m", "prefill", True), 0.01)  # no prior
        assert reg.get("cost_model_rel_error") is None
        cm.observe(("lm", "m", "prefill", True), 0.01)
        h = reg.get("cost_model_rel_error")
        assert h.count(engine="lm", model="m", phase="prefill") == 1

    def test_metrics_none_is_default(self):
        cm = CostModel()
        cm.observe(("lm", "m", "decode", False), 0.01)
        cm.observe(("lm", "m", "decode", False), 0.02)  # no sink: no-op


class TestStepTimer:
    def test_injectable_clock(self):
        ticks = iter([10.0, 10.5, 20.0, 20.25])
        wd = Watchdog(threshold=100.0)
        timer = StepTimer(wd, clock=lambda: next(ticks))
        with timer:
            pass
        with timer:
            pass
        assert wd.ewma == pytest.approx(0.5 * 0.8 + 0.25 * 0.2)
        assert timer._step == 2

    def test_default_clock_is_wall(self):
        wd = Watchdog()
        with StepTimer(wd):
            pass
        assert wd.ewma is not None and wd.ewma >= 0


class TestReplicaHealthTransitions:
    def _health(self, reg):
        return ReplicaHealth(watchdog=Watchdog(threshold=3.0),
                             suspect_limit=2, name="r1", metrics=reg)

    def test_suspect_recover_and_evict_counted(self):
        reg = MetricsRegistry()
        h = self._health(reg)
        h.observe_step(0, 1.0)                 # seeds EWMA, clean
        h.observe_step(1, 10.0)                # straggler -> SUSPECT
        h.observe_step(2, 1.0)                 # clean -> HEALTHY
        h.observe_step(3, 10.0)                # SUSPECT again
        h.observe_step(4, 10.0)                # 2nd consecutive -> EVICTED
        assert h.state == EVICTED and not h.live
        c = reg.get("replica_health_transitions_total")
        assert c.value(replica="r1", src=HEALTHY, dst=SUSPECT) == 2
        assert c.value(replica="r1", src=SUSPECT, dst=HEALTHY) == 1
        assert c.value(replica="r1", src=SUSPECT, dst=EVICTED) == 1
        # terminal: further steps change nothing
        h.observe_step(5, 1.0)
        assert sum(c.samples().values()) == 4

    def test_same_state_not_counted(self):
        reg = MetricsRegistry()
        h = self._health(reg)
        h.observe_step(0, 1.0)
        h.observe_step(1, 1.0)                 # stays HEALTHY
        assert reg.get("replica_health_transitions_total") is None

    def test_metrics_none_still_works(self):
        h = ReplicaHealth(watchdog=Watchdog(threshold=3.0),
                          suspect_limit=1)
        h.observe_step(0, 1.0)
        h.observe_step(1, 10.0)
        assert h.state == EVICTED              # limit 1: straight out
