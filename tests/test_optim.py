"""Optimizer + gradient-compression tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optim import adamw, compression


def _quad_target():
    # size 64: divisible by the Q8 block so quantized moments engage
    w_star = jnp.array([1.5, -2.0, 0.5] * 21 + [0.25])
    def loss(w):
        return jnp.sum((w - w_star) ** 2)
    return w_star, loss


def test_adam_converges_quadratic():
    w_star, loss = _quad_target()
    tcfg = TrainConfig(lr=5e-2, weight_decay=0.0)
    params = {"w": jnp.zeros_like(w_star)}
    state = adamw.init_adam(params, tcfg)
    for _ in range(300):
        g = jax.grad(lambda p: loss(p["w"]))(params)
        params, state = adamw.adam_update(g, state, params, tcfg)
    assert float(loss(params["w"])) < 1e-2


def test_quantized_moments_track_exact():
    w_star, loss = _quad_target()
    outs = {}
    for qz in (False, True):
        tcfg = TrainConfig(lr=5e-2, weight_decay=0.0, quantized_moments=qz)
        params = {"w": jnp.zeros_like(w_star)}
        state = adamw.init_adam(params, tcfg)
        for _ in range(150):
            g = jax.grad(lambda p: loss(p["w"]))(params)
            params, state = adamw.adam_update(g, state, params, tcfg)
        outs[qz] = params["w"]
    err = float(jnp.max(jnp.abs(outs[True] - outs[False])))
    assert err < 0.15, err  # quantized moments stay on-trajectory


def test_quantized_moment_memory():
    tcfg = TrainConfig(quantized_moments=True)
    params = {"w": jnp.zeros((1024, 256), jnp.bfloat16)}
    st = adamw.init_adam(params, tcfg)
    m = st.m["w"]
    bytes_q = m.nbytes()
    assert bytes_q < 1024 * 256 * 4 * 0.6  # ~2.1 B/param vs 4 B f32


def test_grad_clip():
    tcfg = TrainConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros((8,))}
    state = adamw.init_adam(params, tcfg)
    g = {"w": jnp.full((8,), 1e6)}
    new_params, _ = adamw.adam_update(g, state, params, tcfg)
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 1.0


def test_compression_error_feedback_unbiased():
    """Error feedback: the *cumulative* compressed signal must track
    the cumulative true gradient (residual stays bounded)."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (4, 64)) * 0.1
    state = compression.init_compression({"g": g_true})
    acc = jnp.zeros_like(g_true)
    for i in range(50):
        out, state = compression.apply_compression({"g": g_true}, state)
        acc = acc + out["g"]
    drift = float(jnp.max(jnp.abs(acc / 50 - g_true)))
    assert drift < 5e-3, drift
    assert float(jnp.max(jnp.abs(state.residual["g"]))) < 0.05


def test_compression_ratio():
    assert compression.compression_ratio() > 1.8
