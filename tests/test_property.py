"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not in the minimal CPU image
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models.transformer import init_lm, lm_forward

SETTINGS = dict(max_examples=8, deadline=None)

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64,
                  head_dim=16)
PARAMS = init_lm(jax.random.PRNGKey(0), CFG)


@given(st.integers(2, 14))
@settings(**SETTINGS)
def test_lm_causality(t):
    """Logits at position < t are invariant to tokens at >= t."""
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    l1, _ = lm_forward(PARAMS, CFG, toks)
    toks2 = toks.at[0, t:].set((toks[0, t:] + 7) % 64)
    l2, _ = lm_forward(PARAMS, CFG, toks2)
    np.testing.assert_allclose(np.asarray(l1[0, :t]),
                               np.asarray(l2[0, :t]), atol=1e-4)


@given(st.integers(1, 4), st.integers(16, 128), st.booleans())
@settings(**SETTINGS)
def test_attention_rowsum_and_range(h, s, causal):
    """Attention outputs are convex combinations of values: each output
    coordinate lies within [min(v), max(v)]."""
    s = (s // 16) * 16
    ks = jax.random.split(jax.random.PRNGKey(h * 100 + s), 3)
    q = jax.random.normal(ks[0], (1, h, s, 8))
    k = jax.random.normal(ks[1], (1, h, s, 8))
    v = jax.random.normal(ks[2], (1, h, s, 8))
    out = np.asarray(ref.flash_attention_ref(q, k, v, causal=causal))
    vmin, vmax = float(v.min()), float(v.max())
    assert out.min() >= vmin - 1e-4 and out.max() <= vmax + 1e-4


@given(st.integers(0, 1000))
@settings(**SETTINGS)
def test_quantized_matmul_scale_equivariance(seed):
    """q8 path: scaling weights scales outputs (approximately —
    requantization is scale-covariant for exact powers of two)."""
    from repro.core import quant
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 64)) * 0.1
    y1 = ops.quantized_matmul(x, quant.quantize_q8_0(w), force="xla")
    y2 = ops.quantized_matmul(x, quant.quantize_q8_0(w * 4.0),
                              force="xla")
    np.testing.assert_allclose(np.asarray(y2, np.float32),
                               4 * np.asarray(y1, np.float32),
                               atol=1e-2, rtol=1e-2)


@given(st.integers(0, 50))
@settings(**SETTINGS)
def test_q8_dequantize_quantize_fixpoint(seed):
    """Q8_0: dequantize(quantize(x)) is a fixpoint of the quantizer."""
    from repro.core import quant
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 256))
    y1 = quant.dequantize(quant.quantize(x, "q8_0"))
    y2 = quant.dequantize(quant.quantize(y1, "q8_0"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=1e-3)


# ------------------------------------------------------- paged kernels

def _paged_case(seed, b, mb, bs, nb, h, g, d):
    """Random pools + per-row block tables of distinct physical ids."""
    rng = np.random.default_rng(seed)
    tbl = np.stack([rng.permutation(np.arange(1, nb))[:mb]
                    for _ in range(b)])
    kp = rng.standard_normal((nb, h, bs, d)).astype(np.float32) * 0.5
    vp = rng.standard_normal((nb, h, bs, d)).astype(np.float32)
    return rng, jnp.asarray(tbl, jnp.int32), jnp.asarray(kp), jnp.asarray(vp)


@given(st.integers(0, 10**6), st.lists(st.integers(0, 11), min_size=2,
                                       max_size=2))
@settings(max_examples=8, deadline=None)
def test_paged_flash_decode_matches_oracle_random(seed, positions):
    """flash_decode_paged ≡ oracle under random block tables and
    per-row positions (the fixed-case check generalized)."""
    from repro.kernels.flash_decode import (flash_decode_paged,
                                            flash_decode_paged_ref)
    b, mb, bs, nb, h, g, d = 2, 3, 4, 9, 2, 2, 8
    rng, tbl, kp, vp = _paged_case(seed, b, mb, bs, nb, h, g, d)
    q = jnp.asarray(rng.standard_normal((b, h, g, d)).astype(np.float32))
    pos = jnp.asarray(positions, jnp.int32)
    want = flash_decode_paged_ref(q, kp, vp, tbl, pos)
    got = flash_decode_paged(q, kp, vp, tbl, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@given(st.integers(0, 10**6), st.integers(1, 6), st.integers(0, 11))
@settings(max_examples=8, deadline=None)
def test_paged_flash_prefill_matches_oracle_random(seed, t, pos0):
    """flash_prefill_paged ≡ oracle under random block tables, random
    chunk starts, and ragged chunk tails (t not a block multiple)."""
    from repro.kernels.flash_prefill import (flash_prefill_paged,
                                             flash_prefill_paged_ref)
    mb, bs, nb, h, g, d = 3, 4, 9, 2, 2, 8
    pos0 = min(pos0, mb * bs - t)
    rng, tbl, kp, vp = _paged_case(seed, 1, mb, bs, nb, h, g, d)
    q = jnp.asarray(rng.standard_normal((t, h, g, d)).astype(np.float32))
    kn = jnp.asarray(rng.standard_normal((t, h, d)).astype(np.float32))
    vn = jnp.asarray(rng.standard_normal((t, h, d)).astype(np.float32))
    got, kpo, vpo = flash_prefill_paged(q, kn, vn, kp, vp, tbl[0], pos0,
                                        interpret=True)
    want, kpr, vpr = flash_prefill_paged_ref(q, kn, vn, kp, vp, tbl[0],
                                             pos0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(kpo), np.asarray(kpr))
    np.testing.assert_array_equal(np.asarray(vpo), np.asarray(vpr))


@given(st.integers(0, 50))
@settings(**SETTINGS)
def test_q3k_requantization_error_stable(seed):
    """Q3_K is not bit-exact under requantization (sub-scales are
    re-estimated), but the error w.r.t. the original must not inflate."""
    from repro.core import quant
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 256))
    y1 = quant.dequantize(quant.quantize(x, "q3_k"))
    y2 = quant.dequantize(quant.quantize(y1, "q3_k"))
    e1 = float(jnp.linalg.norm(y1 - x))
    e2 = float(jnp.linalg.norm(y2 - x))
    # Empirical worst over seeds 0..50 is 1.39x; 1.5 = regression guard.
    assert e2 <= e1 * 1.5 + 1e-6, (e1, e2)
