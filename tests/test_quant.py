"""Quantization-format unit + property tests (Q8_0 / Q3_K / Q8_K)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not in the minimal CPU image; only the property tests at
# the bottom need it — the unit/regression classes must still run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the minimal image
    HAVE_HYPOTHESIS = False

    def given(*a, **kw):  # noqa: D103 - no-op decorator stand-ins
        return lambda fn: pytest.mark.skip("hypothesis not installed")(fn)

    def settings(*a, **kw):
        return lambda fn: fn

    class st:  # type: ignore[no-redef]
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def floats(*a, **kw):
            return None

from repro.core import quant

SETTINGS = dict(max_examples=20, deadline=None) if HAVE_HYPOTHESIS else {}


def _rand(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32) * scale


class TestQ80:
    def test_roundtrip_error_bound(self):
        x = _rand((8, 256))
        t = quant.quantize_q8_0(x)
        y = quant.dequantize_q8_0(t)
        # Per-block error bounded by half a quantization step.
        d = np.asarray(t.d, np.float32).repeat(32, -1).reshape(x.shape)
        assert np.all(np.abs(np.asarray(y - x)) <= d / 2 + 1e-7)

    def test_idempotent(self):
        t = quant.quantize_q8_0(_rand((4, 64)))
        t2 = quant.quantize_q8_0(quant.dequantize_q8_0(t))
        np.testing.assert_array_equal(np.asarray(t.qs), np.asarray(t2.qs))

    def test_zeros(self):
        t = quant.quantize_q8_0(jnp.zeros((2, 32)))
        assert np.all(np.asarray(quant.dequantize_q8_0(t)) == 0)

    def test_bpw(self):
        x = _rand((16, 1024))
        t = quant.quantize_q8_0(x)
        assert t.nbytes() * 8 / x.size == pytest.approx(8.5)

    def test_kquant_ragged_still_raises(self):
        # K-quants keep GGML's hard divisibility requirement.
        with pytest.raises(ValueError):
            quant.quantize_q3_k(jnp.zeros((2, 255)))
        with pytest.raises(ValueError):
            quant.quantize_q8_k(jnp.zeros((2, 255)))


class TestBlockEdgeCases:
    """Regression tests for degenerate Q8_0/Q4_0 blocks (ISSUE 8)."""

    @pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
    def test_all_zero_block(self, fmt):
        t = quant.quantize(jnp.zeros((2, 64)), fmt)
        assert np.all(np.asarray(t.d) == 0)
        assert np.all(np.asarray(quant.dequantize(t)) == 0)

    @pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
    def test_all_equal_block(self, fmt):
        x = jnp.full((2, 32), 3.25)
        y = np.asarray(quant.dequantize(quant.quantize(x, fmt)))
        np.testing.assert_allclose(y, 3.25, rtol=1e-2)

    @pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
    def test_huge_block_does_not_nan(self, fmt):
        # amax/q_max overflows fp16 without saturation: d = inf, all
        # codes 0, dequant 0 * inf = NaN.  Must stay finite instead.
        x = jnp.array([[1e9, -5e8] + [0.0] * 30])
        y = np.asarray(quant.dequantize(quant.quantize(x, fmt)))
        assert np.all(np.isfinite(y)), y
        assert np.sign(y[0, 0]) == 1 and np.sign(y[0, 1]) == -1

    @pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
    def test_tiny_block_not_flushed_to_zero(self, fmt):
        # amax/q_max underflows fp16's subnormal range without the
        # floor: d = 0 and a representable block silently zeroes.
        x = jnp.full((1, 32), 2.0 ** -24)
        t = quant.quantize(x, fmt)
        assert np.all(np.asarray(t.d, np.float32) > 0)
        assert np.any(np.asarray(quant.dequantize(t)) != 0)

    def test_max_negative_no_int8_wrap(self):
        # fp16 rounding of d can push round(x / d) past -127; the cast
        # to int8 must clip, not wrap to +positive via -128.
        x = -_rand((8, 256), seed=5, scale=100.0).__abs__()
        t = quant.quantize_q8_0(x)
        q = np.asarray(t.qs, np.int32)
        assert q.min() >= -127
        assert np.all(np.asarray(quant.dequantize_q8_0(t)) <= 0)

    def test_max_negative_no_nibble_wrap_q4(self):
        x = -jnp.abs(_rand((8, 256), seed=6, scale=100.0))
        t = quant.quantize_q4_0(x)
        q = np.asarray(quant.unpack_q4(t.qs), np.int32)
        assert q.min() >= -8 and q.max() <= 7
        assert np.all(np.asarray(quant.dequantize_q4_0(t)) <= 0)

    @pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
    @pytest.mark.parametrize("k", [1, 31, 33, 63])
    def test_tail_block_roundtrip(self, fmt, k):
        x = _rand((3, k), seed=k)
        t = quant.quantize(x, fmt)
        assert t.shape == x.shape
        y = np.asarray(quant.dequantize(t))
        assert y.shape == x.shape
        tol = {"q8_0": 0.02, "q4_0": 0.25}[fmt]
        rel = np.linalg.norm(y - np.asarray(x)) / np.linalg.norm(
            np.asarray(x))
        assert rel < tol, rel

    def test_tail_survives_pytree_roundtrip(self):
        t = quant.quantize_q8_0(_rand((2, 40)))
        leaves, treedef = jax.tree_util.tree_flatten(t)
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert t2.logical == 40 and t2.shape == (2, 40)

    def test_aligned_has_no_logical(self):
        assert quant.quantize_q8_0(_rand((2, 64))).logical is None
        assert quant.quantize_q4_0(_rand((2, 64))).logical is None


class TestQ3K:
    def test_pack_unpack_q3_exact(self):
        q = np.random.default_rng(0).integers(0, 8, (5, 512)).astype(np.uint8)
        ql, qh = quant.pack_q3(jnp.array(q))
        rt = np.asarray(quant.unpack_q3(ql, qh)) + 4
        np.testing.assert_array_equal(rt, q)

    def test_pack_unpack_scales_exact(self):
        sc = np.random.default_rng(1).integers(0, 64, (3, 4, 16)).astype(
            np.uint8)
        rt = np.asarray(quant.unpack_scales6(quant.pack_scales6(
            jnp.array(sc))))
        np.testing.assert_array_equal(rt, sc)

    def test_roundtrip_error(self):
        x = _rand((8, 512))
        y = quant.dequantize_q3_k(quant.quantize_q3_k(x))
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.25, rel  # ~3-bit quantization error regime

    def test_bpw_packed(self):
        x = _rand((16, 1024))
        t = quant.quantize_q3_k(x)
        assert t.nbytes() * 8 / x.size == pytest.approx(3.4375)

    def test_scale5_approximation_claim(self):
        """Paper: converting 6-bit scales to 5 bits has almost no
        effect on results (OP_CVT53)."""
        x = _rand((32, 1024), seed=3)
        e6 = float(jnp.linalg.norm(
            quant.dequantize_q3_k(quant.quantize_q3_k(x)) - x))
        e5 = float(jnp.linalg.norm(
            quant.dequantize_q3_k(quant.quantize_q3_k(x, scale_bits=5))
            - x))
        assert e5 <= e6 * 1.15, (e5, e6)

    def test_values_in_range(self):
        t = quant.quantize_q3_k(_rand((4, 256), scale=10.0))
        q = np.asarray(quant.unpack_q3(t.ql, t.qh))
        assert q.min() >= -4 and q.max() <= 3


class TestQ8K:
    def test_roundtrip(self):
        x = _rand((4, 512))
        y = quant.dequantize_q8_k(quant.quantize_q8_k(x))
        assert float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x)) < 0.02


@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_q8_roundtrip_property(rows, blocks, scale):
    x = _rand((rows, 32 * blocks), seed=rows * 7 + blocks, scale=scale)
    t = quant.quantize_q8_0(x)
    y = quant.dequantize_q8_0(t)
    rel = float(jnp.linalg.norm(y - x) / (jnp.linalg.norm(x) + 1e-9))
    assert rel < 0.02


@given(st.integers(1, 4), st.integers(1, 3))
@settings(**SETTINGS)
def test_q3k_sign_preservation_property(rows, sblocks):
    """Large-magnitude entries must keep their sign through Q3_K."""
    x = _rand((rows, 256 * sblocks), seed=rows + 13 * sblocks)
    y = quant.dequantize_q3_k(quant.quantize_q3_k(x))
    big = np.abs(np.asarray(x)) > 2.0
    if big.any():
        assert np.all(np.sign(np.asarray(y))[big]
                      == np.sign(np.asarray(x))[big])
