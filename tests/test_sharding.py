"""Sharding-rule unit tests (mesh.shape-only stub, no devices needed)."""
import types

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.qlinear import Linear, init_linear, quantize_params
from repro.core.policy import Q8_0_POLICY
from repro.distributed import sharding


MESH = types.SimpleNamespace(shape={"data": 16, "model": 16})
MESH3 = types.SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_role_rules_dense():
    lin = init_linear(jax.random.PRNGKey(0), 4096, 8192, role="mlp_up")
    spec = sharding.linear_specs(lin, MESH)
    assert spec.w == P("model", "data")
    lin2 = init_linear(jax.random.PRNGKey(0), 8192, 4096, role="mlp_down")
    assert sharding.linear_specs(lin2, MESH).w == P("data", "model")


def test_nondivisible_falls_back_replicated():
    lin = init_linear(jax.random.PRNGKey(0), 100, 24, role="mlp_up")
    spec = sharding.linear_specs(lin, MESH)
    assert spec.w == P(None, None)


def test_quantized_side_tensors_inherit():
    lin = init_linear(jax.random.PRNGKey(0), 2048, 4096, role="attn_qkv")
    qlin = quantize_params(lin, Q8_0_POLICY)
    spec = sharding.linear_specs(qlin, MESH)
    assert spec.w.qs == P("model", "data")
    assert spec.w.d == P("model", "data")  # 2048/32=64 divides 16


def test_fsdp_off_drops_data_axis():
    lin = init_linear(jax.random.PRNGKey(0), 4096, 8192, role="mlp_up")
    specs = sharding.param_specs({"l": lin}, MESH, fsdp=False)
    assert specs["l"].w == P("model", None)


def test_expert_weights_ep():
    w = jnp.zeros((64, 128, 2048), jnp.bfloat16)  # (E, ff, d)
    lin = Linear(w, role="expert_up")
    spec = sharding.linear_specs(lin, MESH)
    assert spec.w[0] == "model"  # EP on the model axis


def test_batch_specs_multi_pod():
    batch = {"tokens": jnp.zeros((256, 128), jnp.int32)}
    spec = sharding.batch_specs(batch, MESH3)
    assert spec["tokens"][0] == ("pod", "data")
    small = sharding.batch_specs({"t": jnp.zeros((3, 4))}, MESH3)
    assert small["t"] == P(None, None)


def test_cache_specs_long_context_batch1():
    """batch=1 decode: sequence must shard over model AND data axes."""
    cache = {"k": jnp.zeros((9, 1, 8, 4096, 128), jnp.bfloat16)}
    spec = sharding.cache_specs(cache, MESH)
    assert spec["k"][3] in (("model", "data"), ("model",), "model")
    # batch divisible: batch->data, seq->model
    cache2 = {"k": jnp.zeros((4, 128, 8, 4096, 128), jnp.bfloat16)}
    spec2 = sharding.cache_specs(cache2, MESH)
    assert spec2["k"][1] == "data" and spec2["k"][3] == "model"
