"""Speculative decoding on the paged KV runtime (PR 10).

Greedy acceptance makes speculation a pure *latency* transform: the
emitted stream must be token-bit-exact with plain decode, and rollback
must be a position rewind that can never dirty a refcount-shared
block.  Gates:

* self-draft speculation (100% acceptance) is token-bit-exact vs
  baseline decode, with per-request ``proposed``/``accepted``
  accounting that reconciles with the scheduler counters;
* on the fused verify path, speculation strictly beats
  one-launch-per-token (``decode_launches``);
* an adversarial draft (0% acceptance) degenerates to *exactly* the
  baseline launch count and tokens — speculation is never worse;
* rejection whose rollback window crosses a block boundary, and whose
  write window lands on a CoW-shared block, leaves the shared block
  byte-pristine (the copy-on-write + truncate contract);
* preempt/evacuate mid-speculation frees both pools (target + draft)
  and resumes bit-exact;
* :meth:`PagedKVRuntime.truncate` unit properties: bounds check and
  the shared-block rollback assertion.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.engine import (EngineConfig, Finished, LMEngineConfig,
                          SpecDecodeConfig)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, PagedKVRuntime, Request
from repro.serving.scheduler import make_paged_decode

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _anti_draft():
    """A draft that is always wrong: proposes (greedy + 1) mod V, so
    the target rejects every proposal (acceptance rate 0)."""
    inner = make_paged_decode(CFG)

    def step(dparams, toks, poss, tab, cache):
        nxt, cache = inner(dparams, toks, poss, tab, cache)
        return (nxt + 1) % CFG.vocab_size, cache

    return step


def _mk(params, spec=None, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    conf = EngineConfig(lm=LMEngineConfig(spec_decode=spec, **kw))
    return ContinuousBatcher(params, CFG, config=conf)


def _self_draft(params, k=3, **kw):
    """Draft == target: greedy proposals always match, acceptance 1.0."""
    return SpecDecodeConfig(draft_params=params, draft_cfg=CFG, k=k, **kw)


def _run(cb, n_req=2, plen=5, max_new=8):
    reqs = [Request(rid=i, prompt=_prompt(i, plen), max_new=max_new)
            for i in range(n_req)]
    for r in reqs:
        cb.submit(r)
    cb.run()
    return reqs


def _prefill_done(cb, slot=0):
    """Step until the slot is admitted and both target and draft
    prefill streams are fully ingested (next quantum is speculative)."""
    while cb.slots[slot] is None or cb._pending[slot] \
            or cb._draft_pending[slot]:
        cb.step()


# -------------------------------------------------------- bit-exactness
class TestBitExactness:
    def test_self_draft_tokens_match_baseline_scan(self, params):
        """Scan verify path: mathematically identical to the decode
        step, so the gate is exact token equality."""
        base = _run(_mk(params, fused_prefill=False), n_req=3)
        spec = _run(_mk(params, _self_draft(params),
                        fused_prefill=False), n_req=3)
        assert [r.out for r in spec] == [r.out for r in base]
        assert all(r.accepted == r.proposed > 0 for r in spec)

    def test_self_draft_tokens_match_baseline_fused(self, params):
        """Fused verify: the verification launch reduces with prefill-
        kernel shapes, so its logits can differ from the decode step in
        low-order bits; a greedy near-tie can then flip a token.  The
        gate therefore runs a tie-stable workload (same policy as the
        fused-vs-scan transcript gate in the ASR smoke); the *scan*
        test above is the mathematical bit-exactness oracle."""
        base = _run(_mk(params, fused_prefill=True), n_req=2)
        spec = _run(_mk(params, _self_draft(params),
                        fused_prefill=True), n_req=2)
        assert [r.out for r in spec] == [r.out for r in base]

    def test_anti_draft_tokens_still_exact(self, params):
        """Acceptance 0: every proposal rejected, every round emits
        only the bonus token — output must still be bit-exact."""
        sp = _self_draft(params, draft_step_fn=_anti_draft())
        base = _run(_mk(params, fused_prefill=False), n_req=2)
        spec = _run(_mk(params, sp, fused_prefill=False), n_req=2)
        assert [r.out for r in spec] == [r.out for r in base]
        assert all(r.accepted == 0 and r.proposed > 0 for r in spec)


# ---------------------------------------------------- launch accounting
class TestLaunchAccounting:
    def test_spec_beats_one_launch_per_token(self, params):
        """Fused verify, full acceptance: decode launches must be
        strictly below the baseline's one-per-quantum."""
        base = _mk(params, fused_prefill=True)
        _run(base, n_req=2)
        spec = _mk(params, _self_draft(params), fused_prefill=True)
        _run(spec, n_req=2)
        assert spec.decode_launches < base.decode_launches
        assert spec.spec_rounds > 0
        assert spec.draft_launches > 0      # drafting is extra launches,
        assert spec.spec_tokens_per_round() > 1.0   # amortised per round

    def test_acceptance_zero_degenerates_to_baseline(self, params):
        """Anti-draft on the fused path, one slot: every spec round
        costs exactly one verify launch and emits exactly one token —
        the same launches-per-token as plain decode, so the totals must
        be *equal*, not merely close."""
        base = _mk(params, slots=1, fused_prefill=True)
        _run(base, n_req=1)
        sp = _self_draft(params, draft_step_fn=_anti_draft())
        spec = _mk(params, sp, slots=1, fused_prefill=True)
        _run(spec, n_req=1)
        assert spec.decode_launches == base.decode_launches
        assert spec.spec_accepted == 0

    def test_counters_reconcile_with_requests(self, params):
        cb = _mk(params, _self_draft(params), fused_prefill=True)
        hs = [cb.submit(Request(rid=i, prompt=_prompt(i, 5), max_new=8))
              for i in range(3)]
        cb.run()
        reqs = [next(e.result for e in cb.bus.log
                     if isinstance(e, Finished) and e.rid == i)
                for i in range(3)]
        assert sum(r.proposed for r in reqs) == cb.spec_proposed
        assert sum(r.accepted for r in reqs) == cb.spec_accepted
        assert cb.spec_accepted <= cb.spec_proposed
        # satellite 3: the typed result carries the same accounting
        for h, r in zip(hs, reqs):
            res = h.result()
            assert res.outcome == "finished"
            assert res.stats.proposed == r.proposed
            assert res.stats.accepted == r.accepted


# ------------------------------------------------------------- rollback
class TestRollback:
    def test_rejection_across_block_boundary(self, params):
        """block_size=4 with an anti-draft: rollback windows repeatedly
        straddle block boundaries (pos walks one token per round while
        the k=3 tail spills into the next block); tokens stay exact and
        the pool invariants hold after every truncate."""
        sp = _self_draft(params, k=3, draft_step_fn=_anti_draft())
        base = _run(_mk(params, slots=1, block_size=4,
                        fused_prefill=False), n_req=1, plen=6,
                    max_new=10)
        spec = _run(_mk(params, sp, slots=1, block_size=4,
                        fused_prefill=False), n_req=1, plen=6,
                    max_new=10)
        assert spec[0].out == base[0].out
        assert spec[0].accepted == 0

    def test_shared_block_stays_pristine(self, params):
        """A refcount-shared block at the speculative write position
        must be CoW-copied before the verify launch writes, so a
        rejected speculation can never have dirtied the shared bytes."""
        cb = _mk(params, _self_draft(params), slots=1, block_size=4,
                 fused_prefill=True)
        req = Request(rid=0, prompt=_prompt(3, 7), max_new=6)
        cb.submit(req)
        _prefill_done(cb)
        rt = cb.runtime
        pos = rt.pos[0]
        bi = pos // rt.block_size
        bid = rt.tables[0][bi]
        rt.alloc.share(bid)           # simulate a prefix-cache share
        nb = rt.num_blocks
        before = [np.asarray(leaf[:, bid])
                  for leaf in jax.tree.leaves(cb.cache)
                  if leaf.ndim >= 2 and leaf.shape[1] == nb]
        assert before
        cows = rt.cow_copies
        cb.step()                     # one speculative round
        assert rt.cow_copies == cows + 1
        assert rt.tables[0][bi] != bid          # write moved off-shared
        assert rt.alloc.refcount(bid) == 1      # our artificial share
        after = [np.asarray(leaf[:, bid])
                 for leaf in jax.tree.leaves(cb.cache)
                 if leaf.ndim >= 2 and leaf.shape[1] == nb]
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)
        # the CoW copy preserved the prefix rows: decode stays exact
        cb.run()
        ref = _mk(params, slots=1, block_size=4, fused_prefill=True)
        ref.submit(Request(rid=0, prompt=_prompt(3, 7), max_new=6))
        assert req.out == ref.run()[0].out

    def test_preempt_mid_speculation_resumes_bit_exact(self, params):
        ref = _mk(params, slots=1, fused_prefill=False)
        ref.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        expect = ref.run()[0].out

        sp = _self_draft(params)
        cb = _mk(params, sp, slots=1, fused_prefill=False)
        cb.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        while len(cb.slots[0].out if cb.slots[0] else []) < 4:
            cb.step()
        assert cb.preempt(0)
        assert cb.runtime.allocated_blocks == 0       # target pool free
        assert cb.draft_runtime.allocated_blocks == 0  # draft pool free
        assert cb.run()[0].out == expect


# ----------------------------------------------------- truncate (units)
class TestTruncate:
    def test_bounds(self):
        rt = PagedKVRuntime(slots=1, max_len=32, block_size=8)
        rt.admit(0, _prompt(0, 10), 6)
        rt.pos[0] = 12
        rt.truncate(0, 12)            # no-op rewind allowed
        rt.truncate(0, 10)
        assert rt.pos[0] == 10
        with pytest.raises(ValueError, match="outside"):
            rt.truncate(0, 11)        # forward "truncate" is not
        with pytest.raises(ValueError, match="outside"):
            rt.truncate(0, -1)

    def test_rollback_through_shared_block_asserts(self):
        rt = PagedKVRuntime(slots=2, max_len=32, block_size=8)
        rt.admit(0, _prompt(0, 10), 6)
        rt.pos[0] = 12
        bid = rt.tables[0][1]         # block covering positions 8..15
        rt.alloc.share(bid)
        with pytest.raises(AssertionError, match="shared"):
            rt.truncate(0, 9)
        rt.alloc.release(bid)
        rt.truncate(0, 9)             # exclusively owned again: fine
        assert rt.pos[0] == 9


# ----------------------------------------------------------- validation
class TestSpecConfigValidation:
    def test_vocab_mismatch_rejected(self, params):
        bad = ModelConfig(name="d", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=64, head_dim=16)
        sp = SpecDecodeConfig(
            draft_params=init_lm(jax.random.PRNGKey(1), bad),
            draft_cfg=bad)
        with pytest.raises(ValueError, match="vocab"):
            _mk(params, sp)

    def test_k_must_be_positive(self, params):
        with pytest.raises(ValueError, match="k"):
            _mk(params, _self_draft(params, k=0))

    def test_recurrent_target_rejected(self, params):
        hyb = ModelConfig(name="h", family="hybrid", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=96, head_dim=16,
                          block_pattern=("attn", "mamba"), ssm_state=8)
        hp = init_lm(jax.random.PRNGKey(2), hyb)
        conf = EngineConfig(lm=LMEngineConfig(
            slots=1, max_len=32,
            spec_decode=_self_draft(hp)))
        with pytest.raises(ValueError, match="pure-attention"):
            ContinuousBatcher(hp, hyb, config=conf)
