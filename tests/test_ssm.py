"""SSM block tests: mamba chunking invariance, xLSTM parallel==recurrent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm

CFG = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=0, vocab_size=64,
                  head_dim=16, ssm_state=8)


def test_mamba_chunk_invariance():
    """Chunked-parallel scan must not depend on the chunk size."""
    p = ssm.init_mamba(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.bfloat16)
    y_full = ssm.mamba_fwd(p, dataclasses.replace(CFG, mamba_chunk=64), x)
    y_8 = ssm.mamba_fwd(p, dataclasses.replace(CFG, mamba_chunk=8), x)
    np.testing.assert_allclose(
        np.asarray(y_full, np.float32), np.asarray(y_8, np.float32),
        atol=2e-2, rtol=1e-2)


def test_mamba_decode_matches_fwd():
    p = ssm.init_mamba(jax.random.PRNGKey(0), CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32), jnp.bfloat16)
    y = ssm.mamba_fwd(p, CFG, x)
    st = ssm.init_mamba_state(1, CFG)
    outs = []
    for t in range(16):
        yt, st = ssm.mamba_decode(p, CFG, x[:, t:t + 1], st)
        outs.append(yt[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(y, np.float32),
                               atol=3e-2, rtol=1e-2)


def test_mlstm_decode_matches_parallel():
    p = ssm.init_mlstm(jax.random.PRNGKey(3), CFG)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, 32), jnp.bfloat16)
    y = ssm.mlstm_fwd(p, CFG, x)
    st = ssm.init_mlstm_state(2, CFG)
    outs = []
    for t in range(12):
        yt, st = ssm.mlstm_decode(p, CFG, x[:, t:t + 1], st)
        outs.append(yt[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(y, np.float32),
                               atol=3e-2, rtol=1e-2)


def test_slstm_decode_matches_fwd():
    p = ssm.init_slstm(jax.random.PRNGKey(5), CFG)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 10, 32), jnp.bfloat16)
    y = ssm.slstm_fwd(p, CFG, x)
    st = ssm.init_slstm_state(2, CFG)
    outs = []
    for t in range(10):
        yt, st = ssm.slstm_decode(p, CFG, x[:, t:t + 1], st)
        outs.append(yt[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(y, np.float32),
                               atol=2e-2, rtol=1e-2)


def test_mamba_causality():
    """Perturbing the future must not change past outputs."""
    p = ssm.init_mamba(jax.random.PRNGKey(7), CFG)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 32, 32), jnp.bfloat16)
    y1 = ssm.mamba_fwd(p, CFG, x)
    x2 = x.at[:, 20:].set(0.0)
    y2 = ssm.mamba_fwd(p, CFG, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :17], np.float32),
                               np.asarray(y2[:, :17], np.float32),
                               atol=1e-3)
